#!/usr/bin/env python
"""Single-cell profiler dumps, two modes:

Dry-run mode (default) — compile one production cell and dump top
instructions by bytes and the collective breakdown (the compile-side
counterpart of a trace):

    PYTHONPATH=src python scripts/dump_cell.py --arch X --shape Y [--opt]
        [--rules '{"act_seq": ["model"]}'] [--top 15]

Measured mode (``--profile``) — run one *measured* cell through the
BenchmarkRunner with profiling on and dump its phase timeline + op-class
attribution JSON (interactive debugging for a regression: see at a glance
whether compute, data movement, dispatch, or idle moved):

    PYTHONPATH=src python scripts/dump_cell.py --profile --arch gemma-2b
        [--task train] [--batch 2] [--seq 32] [--dtype fp32]
        [--mode jit_donated] [--runs 3] [--json-out prof.json] [--trace]

``--trace`` (measured mode, implies a measured process like --profile)
additionally span-traces the cell and prints its span tree — the
build/compile/warm/measure timeline of exactly this run, same spans a
``benchmarks.run --trace-out`` Chrome trace would show.

The two modes need incompatible processes: the dry run forces 512
placeholder host devices via XLA_FLAGS *before* jax initializes, while a
measured run must keep the single real device — so the dryrun module is
imported only on the dry-run path (``--trace`` alone also selects the
measured process).
"""
import sys

_PROFILE_MODE = "--profile" in sys.argv or "--trace" in sys.argv

if not _PROFILE_MODE:
    import os
    from repro.launch import dryrun  # sets XLA_FLAGS incl. the dump dir
    _DUMP = dryrun._DUMP_DIR

import argparse
import json


def profile_cell(args) -> dict:
    """One profiled measured cell -> its prof payload (JSON-able)."""
    from repro.runner import BenchmarkRunner, Scenario
    sc = Scenario(arch=args.arch, task=args.task, batch=args.batch,
                  seq=args.seq, dtype=args.dtype, mode=args.mode)
    runner = BenchmarkRunner(runs=args.runs)
    if args.trace:
        from repro.telemetry.spans import Tracer
        runner.tracer = Tracer()
    rr = runner.run(sc, record=False, profile=args.profile)
    if rr.status != "ok":
        raise SystemExit(f"{sc.name}: {rr.status}: {rr.error}")
    payload = {
        "scenario": sc.to_dict(),
        "name": rr.name,
        "median_us": rr.median_us,
        "mean_us": rr.mean_us,
        "compile_us": rr.compile_us,
        "profile": {k: v for k, v in rr.extra.items()
                    if k.startswith("prof_")},
    }
    if args.trace:
        payload["spans"] = runner.tracer.export()
    return payload


def profile_main(args) -> None:
    payload = profile_cell(args)
    text = json.dumps(payload, indent=1)
    if args.json_out:
        with open(args.json_out, "w") as f:
            f.write(text + "\n")
    print(text)
    if args.trace:
        from repro.telemetry.export import flame_summary
        print("# span tree:", file=sys.stderr)
        for ln in flame_summary(payload["spans"]).splitlines():
            print(f"#   {ln}", file=sys.stderr)
    prof = payload["profile"]
    fr = {k.replace("prof_frac_", ""): v for k, v in prof.items()
          if k.startswith("prof_frac_")}
    if fr:
        print(f"# {payload['name']}: median {payload['median_us']:.0f}us | "
              + " ".join(f"{k}={v:.2f}" for k, v in sorted(fr.items()))
              + f" (sum {sum(fr.values()):.3f})", file=sys.stderr)


def dryrun_main(args) -> None:
    import glob
    import os
    import re

    from repro.core import hloanalysis as H

    rules = json.loads(args.rules) if args.rules else None
    compiled, mesh = compile_cell(args.arch, args.shape, args.opt, rules,
                                  args.multi_pod)

    files = sorted(glob.glob(os.path.join(_DUMP, "*after_spmd-partitioning*.txt")), key=os.path.getmtime)
    text = open(files[-1]).read() if files else compiled.as_text()
    print("source:", "post-spmd" if files else "compiled")
    mod = H._Module(text, fused_bytes=bool(files))
    rows, colls = [], []

    def walk(comp, mult):
        for ins in mod.computations.get(comp, ()):
            ob, _ = H._shape_info(ins.type_str)
            if ins.op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                walk(bm.group(1), mult * (mod.trip_count(cm.group(1)) or 1))
                continue
            if ins.op in H._SKIP_BYTES_OPS or ins.op.endswith("-done"):
                continue
            if mod.fused_bytes and ins.op in H._ELEMENTWISE_OPS:
                continue
            inb = mod._operand_bytes(comp, ins)
            rows.append(((ob + inb) * mult, ins.op, mult, ins.type_str[:58]))
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in H.COLLECTIVE_OPS:
                colls.append(((ob + inb) * mult, base, mult, ins.type_str[:58]))

    walk(mod.entry, 1)
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total bytes/dev {total/1e12:.2f} TB")
    for b, op, mult, t in rows[: args.top]:
        print(f"  {b/1e12:7.3f}TB x{mult:5d} {op:10s} {t}")
    colls.sort(reverse=True)
    print("top collectives:")
    for b, op, mult, t in colls[:8]:
        print(f"  {b/1e9:8.2f}GB x{mult:5d} {op:12s} {t}")


def compile_cell(arch, shape_name, opt, rules_override=None, multi_pod=False):
    import dataclasses as dc

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_arch, get_shape
    from repro.distributed import merge_rules, sharding_ctx, spec_tree
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (TrainHyper, make_decode_step,
                                    make_prefill_step, make_state_defs,
                                    make_train_step)
    from repro.models.layers import abstract_tree

    cfg = get_arch(arch)
    if opt:
        cfg = dc.replace(cfg, **dryrun.OPT_CFG)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = merge_rules(dryrun.cell_rules(cfg, shape, opt), rules_override)
    with sharding_ctx(mesh, rules):
        dspec = ("pod", "data") if "pod" in mesh.shape else "data"
        if shape.kind == "train":
            step, model = make_train_step(cfg, TrainHyper(microbatches=dryrun.TRAIN_MICROBATCHES.get(arch, 1)))
            sd = make_state_defs(model)
            batch = dryrun.input_specs(cfg, shape)
            jitted = jax.jit(step, in_shardings=(spec_tree(sd, mesh, rules),
                                                 {k: NamedSharding(mesh, P(dspec)) for k in batch}),
                             out_shardings=(spec_tree(sd, mesh, rules), None), donate_argnums=(0,))
            return jitted.lower(abstract_tree(sd), batch).compile(), mesh
        model = make_decode_step(cfg)[1]
        cache_defs = model.cache_defs(shape.global_batch, shape.seq_len + (cfg.n_prefix or 0))
        pdefs = model.param_defs()
        csh = spec_tree(cache_defs, mesh, rules)
        psh = spec_tree(pdefs, mesh, rules)
        if shape.kind == "prefill":
            step, _ = make_prefill_step(cfg, shape.seq_len)
            batch = dryrun.input_specs(cfg, shape)
            jitted = jax.jit(step, in_shardings=(psh, {k: NamedSharding(mesh, P(dspec)) for k in batch}, csh),
                             out_shardings=(None, csh), donate_argnums=(2,))
            return jitted.lower(abstract_tree(pdefs), batch, abstract_tree(cache_defs)).compile(), mesh
        step, _ = make_decode_step(cfg)
        toks = dryrun.input_specs(cfg, shape)["tokens"]
        tsh = NamedSharding(mesh, P(dspec if shape.global_batch >= 16 else None))
        jitted = jax.jit(step, in_shardings=(psh, tsh, csh), out_shardings=(None, csh),
                         donate_argnums=(2,))
        return jitted.lower(abstract_tree(pdefs), toks, abstract_tree(cache_defs)).compile(), mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--profile", action="store_true",
                    help="measured mode: profiled BenchmarkRunner cell "
                         "instead of a dry-run compile")
    # dry-run mode
    ap.add_argument("--shape", default=None)
    ap.add_argument("--opt", action="store_true")
    ap.add_argument("--rules", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=14)
    # measured mode
    ap.add_argument("--task", default="train")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--dtype", default="fp32")
    ap.add_argument("--mode", default="jit_donated")
    ap.add_argument("--runs", type=int, default=3)
    ap.add_argument("--json-out", default=None,
                    help="also write the profile JSON here")
    ap.add_argument("--trace", action="store_true",
                    help="measured mode: span-trace the cell and print "
                         "its build/compile/warm/measure span tree")
    args = ap.parse_args()
    if args.profile or args.trace:
        profile_main(args)
    else:
        if not args.shape:
            ap.error("dry-run mode needs --shape (or use --profile)")
        dryrun_main(args)


if __name__ == "__main__":
    main()
