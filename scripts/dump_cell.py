#!/usr/bin/env python
"""Hillclimb profiler: compile one cell and dump top instructions by bytes
and the collective breakdown.  (The dry-run-profile counterpart of a trace.)

    PYTHONPATH=src python scripts/dump_cell.py --arch X --shape Y [--opt]
        [--rules '{"act_seq": ["model"]}'] [--top 15]
"""
import os
from repro.launch import dryrun  # sets XLA_FLAGS incl. the dump dir
_DUMP = dryrun._DUMP_DIR
import argparse
import dataclasses as dc
import json
import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch, get_shape
from repro.core import hloanalysis as H
from repro.distributed import merge_rules, sharding_ctx, spec_tree
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import TrainHyper, make_decode_step, make_prefill_step, make_state_defs, make_train_step
from repro.models.layers import abstract_tree


def compile_cell(arch, shape_name, opt, rules_override=None, multi_pod=False):
    cfg = get_arch(arch)
    if opt:
        cfg = dc.replace(cfg, **dryrun.OPT_CFG)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = merge_rules(dryrun.cell_rules(cfg, shape, opt), rules_override)
    with sharding_ctx(mesh, rules):
        dspec = ("pod", "data") if "pod" in mesh.shape else "data"
        if shape.kind == "train":
            step, model = make_train_step(cfg, TrainHyper(microbatches=dryrun.TRAIN_MICROBATCHES.get(arch, 1)))
            sd = make_state_defs(model)
            batch = dryrun.input_specs(cfg, shape)
            jitted = jax.jit(step, in_shardings=(spec_tree(sd, mesh, rules),
                                                 {k: NamedSharding(mesh, P(dspec)) for k in batch}),
                             out_shardings=(spec_tree(sd, mesh, rules), None), donate_argnums=(0,))
            return jitted.lower(abstract_tree(sd), batch).compile(), mesh
        model = make_decode_step(cfg)[1]
        cache_defs = model.cache_defs(shape.global_batch, shape.seq_len + (cfg.n_prefix or 0))
        pdefs = model.param_defs()
        csh = spec_tree(cache_defs, mesh, rules)
        psh = spec_tree(pdefs, mesh, rules)
        if shape.kind == "prefill":
            step, _ = make_prefill_step(cfg, shape.seq_len)
            batch = dryrun.input_specs(cfg, shape)
            jitted = jax.jit(step, in_shardings=(psh, {k: NamedSharding(mesh, P(dspec)) for k in batch}, csh),
                             out_shardings=(None, csh), donate_argnums=(2,))
            return jitted.lower(abstract_tree(pdefs), batch, abstract_tree(cache_defs)).compile(), mesh
        step, _ = make_decode_step(cfg)
        toks = dryrun.input_specs(cfg, shape)["tokens"]
        tsh = NamedSharding(mesh, P(dspec if shape.global_batch >= 16 else None))
        jitted = jax.jit(step, in_shardings=(psh, tsh, csh), out_shardings=(None, csh),
                         donate_argnums=(2,))
        return jitted.lower(abstract_tree(pdefs), toks, abstract_tree(cache_defs)).compile(), mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--opt", action="store_true")
    ap.add_argument("--rules", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=14)
    args = ap.parse_args()
    rules = json.loads(args.rules) if args.rules else None
    compiled, mesh = compile_cell(args.arch, args.shape, args.opt, rules, args.multi_pod)

    import glob
    files = sorted(glob.glob(os.path.join(_DUMP, "*after_spmd-partitioning*.txt")), key=os.path.getmtime)
    text = open(files[-1]).read() if files else compiled.as_text()
    print("source:", "post-spmd" if files else "compiled")
    mod = H._Module(text, fused_bytes=bool(files))
    rows, colls = [], []

    def walk(comp, mult):
        for ins in mod.computations.get(comp, ()):
            ob, _ = H._shape_info(ins.type_str)
            if ins.op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                walk(bm.group(1), mult * (mod.trip_count(cm.group(1)) or 1))
                continue
            if ins.op in H._SKIP_BYTES_OPS or ins.op.endswith("-done"):
                continue
            if mod.fused_bytes and ins.op in H._ELEMENTWISE_OPS:
                continue
            inb = mod._operand_bytes(comp, ins)
            rows.append(((ob + inb) * mult, ins.op, mult, ins.type_str[:58]))
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in H.COLLECTIVE_OPS:
                colls.append(((ob + inb) * mult, base, mult, ins.type_str[:58]))

    walk(mod.entry, 1)
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total bytes/dev {total/1e12:.2f} TB")
    for b, op, mult, t in rows[: args.top]:
        print(f"  {b/1e12:7.3f}TB x{mult:5d} {op:10s} {t}")
    colls.sort(reverse=True)
    print("top collectives:")
    for b, op, mult, t in colls[:8]:
        print(f"  {b/1e9:8.2f}GB x{mult:5d} {op:12s} {t}")


if __name__ == "__main__":
    main()
