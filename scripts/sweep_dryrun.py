#!/usr/bin/env python
"""Run the full dry-run sweep, one subprocess per cell (bounded memory),
merging per-cell JSON into results/dryrun_single.json / dryrun_multi.json.

    PYTHONPATH=src python scripts/sweep_dryrun.py [--multi-pod] [--cells a:s,b:t]
"""
import argparse
import json
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.configs import ARCHS, SHAPES  # noqa: E402


def run_cell(arch, shape, multi_pod, rules=None, timeout=2400, opt=False):
    out = os.path.join(REPO, "results", f"_cell_{arch}_{shape}{'_mp' if multi_pod else ''}.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--json", out]
    if multi_pod:
        cmd.append("--multi-pod")
    if opt:
        cmd.append("--opt")
    if rules:
        cmd += ["--rules", json.dumps(rules)]
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    t0 = time.time()
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=timeout, cwd=REPO)
    dt = time.time() - t0
    if r.returncode != 0 or not os.path.exists(out):
        return {"arch": arch, "shape": shape,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "error": (r.stderr.strip().splitlines() or ["?"])[-1][:300],
                "wall_s": round(dt, 1)}
    with open(out) as f:
        cell = json.load(f)[0]
    cell["wall_s"] = round(dt, 1)
    os.remove(out)
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", action="store_true")
    ap.add_argument("--cells", default=None, help="comma list arch:shape; default all")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.cells:
        cells = [tuple(c.split(":")) for c in args.cells.split(",")]
    else:
        cells = [(a, s) for a in sorted(ARCHS) for s in SHAPES]
    name = ("dryrun_multi" if args.multi_pod else "dryrun_single") + ("_opt" if args.opt else "")
    out_path = args.out or os.path.join(REPO, "results", name + ".json")

    results = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"]) for r in results if "error" not in r}

    nerr = 0
    for arch, shape in cells:
        if (arch, shape) in done:
            print(f"[sweep] {arch} x {shape}: cached", flush=True)
            continue
        try:
            cell = run_cell(arch, shape, args.multi_pod, opt=args.opt)
        except subprocess.TimeoutExpired:
            cell = {"arch": arch, "shape": shape, "error": "timeout"}
        status = "SKIP" if "skipped" in cell else ("ERR " + cell["error"][:120] if "error" in cell else
                 f"ok in {cell.get('wall_s', '?')}s dom={cell['roofline']['dominant']}")
        print(f"[sweep] {arch} x {shape}: {status}", flush=True)
        nerr += 1 if "error" in cell else 0
        results = [r for r in results if not (r["arch"] == arch and r["shape"] == shape)]
        results.append(cell)
        with open(out_path, "w") as f:   # checkpoint after every cell
            json.dump(results, f, indent=1)
    print(f"[sweep] done: {len(results)} cells, {nerr} errors -> {out_path}")
    return 1 if nerr else 0


if __name__ == "__main__":
    raise SystemExit(main())
