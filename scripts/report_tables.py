#!/usr/bin/env python
"""Render the §Roofline / §Dry-run tables from the sweep JSONs, plus the
measured-suite table from the BenchmarkRunner's ResultStore (markdown)."""
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(name):
    p = os.path.join(REPO, "results", name)
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return json.load(f)


def table(results, title):
    rows = [r for r in results if "roofline" in r]
    rows.sort(key=lambda r: (SHAPE_ORDER.get(r["shape"], 9), r["arch"]))
    out = [f"### {title}", "",
           "| arch | shape | compute | memory | collective | dominant | useful | GB/dev | fits 16G |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rl = r["roofline"]
        m = r["memory"]
        gb = (m["argument_bytes"] + m["temp_bytes"]) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']*1e3:.0f} ms | "
            f"{rl['memory_s']*1e3:.0f} ms | {rl['collective_s']*1e3:.0f} ms | "
            f"{rl['dominant']} | {rl['useful_ratio']:.2f} | {gb:.1f} | "
            f"{'yes' if gb <= 16 else 'NO'} |")
    skips = [r for r in results if "skipped" in r]
    for r in skips:
        out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — |")
    errs = [r for r in results if "error" in r]
    for r in errs:
        out.append(f"| {r['arch']} | {r['shape']} | ERROR: {r['error'][:60]} | | | | | | |")
    out.append("")
    return "\n".join(out)


def improvement(base, opt):
    bi = {(r["arch"], r["shape"]): r for r in base if "roofline" in r}
    out = ["### Baseline vs optimized (step-time upper bound, single pod)", "",
           "| arch | shape | baseline | optimized | speedup |", "|---|---|---|---|---|"]
    rows = []
    for r in opt:
        if "roofline" not in r:
            continue
        key = (r["arch"], r["shape"])
        if key not in bi:
            continue
        b = bi[key]["roofline"]["step_time_upper_s"]
        o = r["roofline"]["step_time_upper_s"]
        rows.append((key, b, o))
    rows.sort(key=lambda x: (SHAPE_ORDER.get(x[0][1], 9), x[0][0]))
    import math
    logs = []
    for (a, s), b, o in rows:
        out.append(f"| {a} | {s} | {b:.2f} s | {o:.2f} s | {b/o:.2f}x |")
        logs.append(math.log(b / o))
    if logs:
        out.append(f"| **geomean** | | | | **{math.exp(sum(logs)/len(logs)):.2f}x** |")
    out.append("")
    return "\n".join(out)


def measured_table():
    """Latest measured RunResults from the runner's store (results/store)."""
    from repro.runner.results import ResultStore
    store = ResultStore(os.path.join(REPO, "results", "store"))
    rows = [r for r in store.results()
            if r.status == "ok" and not r.extra.get("derived")]
    if not rows:
        return None
    out = ["### Measured suite — latest BenchmarkRunner results", "",
           "| scenario | median | p90 | compile | host peak | runs | reused |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r.name} | {r.median_us/1e3:.2f} ms | {r.p90_us/1e3:.2f} ms | "
            f"{r.compile_us/1e3:.0f} ms | {r.host_peak_bytes/1e6:.1f} MB | "
            f"{r.runs} | {'exec' if r.cache.get('executable_reused') else ('model' if r.cache.get('model_reused') else '—')} |")
    errs = [r for r in store.results() if r.status == "error"]
    for r in errs:
        out.append(f"| {r.name} | ERROR: {(r.error or '')[:60]} | | | | | |")
    out.append("")
    return "\n".join(out)


def main():
    base = load("dryrun_single.json")
    opt = load("dryrun_single_opt.json")
    mp = load("dryrun_multi.json")
    parts = []
    measured = measured_table()
    if measured:
        parts.append(measured)
    if base:
        parts.append(table(base, "Baseline roofline — single pod 16x16 (paper-faithful)"))
    if opt:
        parts.append(table(opt, "Optimized roofline — single pod 16x16 (--opt)"))
        parts.append(improvement(base, opt))
    if mp:
        parts.append(table(mp, "Multi-pod dry-run — 2x16x16 (512 chips)"))
    text = "\n".join(parts)
    print(text)
    if "--write" in sys.argv:
        with open(os.path.join(REPO, "results", "tables.md"), "w") as f:
            f.write(text)


if __name__ == "__main__":
    main()
