#!/usr/bin/env python
"""Render the §Roofline / §Dry-run tables from the sweep JSONs (markdown)."""
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(name):
    p = os.path.join(REPO, "results", name)
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return json.load(f)


def table(results, title):
    rows = [r for r in results if "roofline" in r]
    rows.sort(key=lambda r: (SHAPE_ORDER.get(r["shape"], 9), r["arch"]))
    out = [f"### {title}", "",
           "| arch | shape | compute | memory | collective | dominant | useful | GB/dev | fits 16G |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rl = r["roofline"]
        m = r["memory"]
        gb = (m["argument_bytes"] + m["temp_bytes"]) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']*1e3:.0f} ms | "
            f"{rl['memory_s']*1e3:.0f} ms | {rl['collective_s']*1e3:.0f} ms | "
            f"{rl['dominant']} | {rl['useful_ratio']:.2f} | {gb:.1f} | "
            f"{'yes' if gb <= 16 else 'NO'} |")
    skips = [r for r in results if "skipped" in r]
    for r in skips:
        out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — |")
    errs = [r for r in results if "error" in r]
    for r in errs:
        out.append(f"| {r['arch']} | {r['shape']} | ERROR: {r['error'][:60]} | | | | | | |")
    out.append("")
    return "\n".join(out)


def improvement(base, opt):
    bi = {(r["arch"], r["shape"]): r for r in base if "roofline" in r}
    out = ["### Baseline vs optimized (step-time upper bound, single pod)", "",
           "| arch | shape | baseline | optimized | speedup |", "|---|---|---|---|---|"]
    rows = []
    for r in opt:
        if "roofline" not in r:
            continue
        key = (r["arch"], r["shape"])
        if key not in bi:
            continue
        b = bi[key]["roofline"]["step_time_upper_s"]
        o = r["roofline"]["step_time_upper_s"]
        rows.append((key, b, o))
    rows.sort(key=lambda x: (SHAPE_ORDER.get(x[0][1], 9), x[0][0]))
    import math
    logs = []
    for (a, s), b, o in rows:
        out.append(f"| {a} | {s} | {b:.2f} s | {o:.2f} s | {b/o:.2f}x |")
        logs.append(math.log(b / o))
    if logs:
        out.append(f"| **geomean** | | | | **{math.exp(sum(logs)/len(logs)):.2f}x** |")
    out.append("")
    return "\n".join(out)


def main():
    base = load("dryrun_single.json")
    opt = load("dryrun_single_opt.json")
    mp = load("dryrun_multi.json")
    parts = []
    if base:
        parts.append(table(base, "Baseline roofline — single pod 16x16 (paper-faithful)"))
    if opt:
        parts.append(table(opt, "Optimized roofline — single pod 16x16 (--opt)"))
        parts.append(improvement(base, opt))
    if mp:
        parts.append(table(mp, "Multi-pod dry-run — 2x16x16 (512 chips)"))
    text = "\n".join(parts)
    print(text)
    if "--write" in sys.argv:
        with open(os.path.join(REPO, "results", "tables.md"), "w") as f:
            f.write(text)


if __name__ == "__main__":
    main()
