#!/usr/bin/env python
"""The fleet perf-CI service CLI: supervised scheduled sweeps with live
metrics, drift triage, and automatic re-measure + bisect.

    PYTHONPATH=src python scripts/fleet.py --ticks N [--fast] [--jobs N]
        [--cluster SPEC] [--results-dir DIR] [--interval-s S]

``--fast`` is the bounded demo/CI mode on a virtual clock: a 2-cell
matrix (gemma-2b train, fp32 + bf16), an injected ``RegressionHook``
slowdown from tick 2 onwards, a synthetic 12-commit day (c00..c11, bad
from c08) measured through the same runner for the bisection stage, and
one pre-enqueued tuning job so the stride-gated autotuner drain has
work.  After the run the triage report, status heartbeat, and
Prometheus snapshot are under ``--results-dir``:

* ``fleet_status.json``  — schema-tagged liveness probe: last tick,
  open findings, restarts, per-tick counter snapshots, full metrics
  snapshot (rewritten after every tick);
* ``fleet_report.json``  — ranked triage outcomes
  (confirmed / refuted / bisected);
* ``fleet_metrics.prom`` — Prometheus text exposition snapshot.

Without ``--fast`` the service runs the default nightly-probe matrix on
a wall clock at ``--interval-s`` between ticks, indefinitely up to
``--ticks``.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core.harness import RegressionHook  # noqa: E402
from repro.core.regression import Commit, MetricStore  # noqa: E402
from repro.fleet.scheduler import FleetConfig, VirtualClock  # noqa: E402
from repro.fleet.service import FleetService  # noqa: E402
from repro.profiler.report import format_table  # noqa: E402
from repro.runner import BenchmarkRunner  # noqa: E402

SLOWDOWN_S = 0.05      # the injected regression: ~5x on a ~10ms probe step
BAD_COMMIT = 8         # c08.. are bad in the synthetic 12-commit day


def _fast_hooks(tick: int):
    """Ticks 0..n-2 are healthy baselines; the final ticks carry the
    injected slowdown on every gemma-2b train cell (keyed by bench, so
    both dtype cells regress)."""
    if tick >= 1:
        return {"gemma-2b/train": RegressionHook(slowdown_s=SLOWDOWN_S)}
    return None


def _fast_commits_for(runner):
    """The synthetic commit day for the bisection stage: each commit
    re-measures the flagged cell through the shared runner (cached
    executables — regression_ci's idiom), bad from c08 onwards."""
    def commits_for(finding, scenario):
        def commit_runner(bad):
            def run(_name):
                hook = RegressionHook(slowdown_s=SLOWDOWN_S) if bad else None
                rr = runner.run(scenario, runs=2, hook=hook, record=False)
                return rr.metrics()
            return run
        return [Commit(f"c{i:02d}", i, commit_runner(i >= BAD_COMMIT))
                for i in range(12)]
    return commits_for


def _seed_tuning_queue(queue_path: str) -> None:
    """One small flash-attention job so the demo's stride drain has work
    (profile_report's detectors would enqueue these in production)."""
    from repro.tuning import enqueue_jobs, make_case
    case = make_case("flash_attention", B=1, S=32, H=2, K=2, D=32)
    enqueue_jobs([{"kernel": case.kernel, "case": case.case_id,
                   "signature": case.signature, "dtype": case.dtype,
                   "source_rule": "manual", "severity": "info",
                   "in_db": False}], queue_path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=2,
                    help="supervised scheduler ticks to run")
    ap.add_argument("--fast", action="store_true",
                    help="bounded demo: virtual clock, 2-cell matrix, "
                         "injected regression + synthetic commit day")
    ap.add_argument("--jobs", type=int, default=0,
                    help="shard each tick's matrix across N workers")
    ap.add_argument("--cluster", default="",
                    help="cluster spec for tick dispatch (e.g. local:2)")
    ap.add_argument("--runs", type=int, default=3)
    ap.add_argument("--interval-s", type=float, default=0.0,
                    help="clock sleep between ticks (virtual under --fast)")
    ap.add_argument("--results-dir", default="results")
    args = ap.parse_args(argv)

    os.makedirs(args.results_dir, exist_ok=True)
    store_path = os.path.join(args.results_dir, "fleet_store.json")
    queue_path = os.path.join(args.results_dir, "tuning_queue.json")
    if args.fast:
        # demo determinism: drift on tick 2 must be judged against THIS
        # run's tick-1 baseline, not a previous invocation's history
        for stale in (store_path, store_path[:-len(".json")] + ".jsonl"):
            try:
                os.remove(stale)
            except OSError:
                pass
        cfg = FleetConfig(archs=("gemma-2b",), tasks=("train",),
                          batches=(1,), seqs=(16,),
                          dtypes=("fp32", "bf16"), runs=args.runs,
                          interval_s=args.interval_s or 3600.0,
                          drain_stride=2, drain_max_candidates=2,
                          queue_path=queue_path)
        clock = VirtualClock()
        _seed_tuning_queue(queue_path)
    else:
        cfg = FleetConfig(runs=args.runs, interval_s=args.interval_s,
                          queue_path=queue_path)
        clock = None

    store = MetricStore(store_path)
    runner = BenchmarkRunner(runs=args.runs, jobs=args.jobs,
                             cluster=args.cluster, coverage=True)
    service = FleetService(
        cfg, store=store, runner=runner, results_dir=args.results_dir,
        clock=clock,
        hooks_for_tick=_fast_hooks if args.fast else None,
        commits_for=_fast_commits_for(runner) if args.fast else None,
        backoff_s=0.5)
    try:
        summary = service.run(args.ticks)
    finally:
        runner.close()

    print(f"fleet: {summary['ticks']} ticks, {summary['restarts']} restarts, "
          f"{summary['open_findings']} open findings")
    for ev in summary["events"]:
        print(f"  event: {ev}")
    if service.last_report is not None:
        for line in format_table(service.last_report).splitlines():
            print(f"  {line}")
    print(f"status:  {summary['status_path']}")
    print(f"report:  {summary['report_path']}")
    print(f"metrics: {summary['prom_path']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
