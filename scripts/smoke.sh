#!/usr/bin/env bash
# PR smoke gate: tier-1 tests + the runner-driven table1 path end-to-end.
#
#     bash scripts/smoke.sh [--fast-only]
#
# Fails on the first nonzero exit.  --fast-only skips the pytest tier
# (useful while iterating on the benchmark harness itself).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" != "--fast-only" ]]; then
    echo "== tier-1 tests =="
    python -m pytest -x -q
fi

echo "== runner path: table1_suite --fast =="
python -m benchmarks.run --fast --only table1_suite

echo "smoke OK"
