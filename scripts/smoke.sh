#!/usr/bin/env bash
# PR smoke gate: tier-1 tests + the runner-driven table1 path end-to-end
# + a sharded (--jobs 2) run_matrix smoke.
#
#     bash scripts/smoke.sh [--fast-only]
#
# Fails on the first nonzero exit.  --fast-only skips the pytest tier
# (useful while iterating on the benchmark harness itself).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" != "--fast-only" ]]; then
    echo "== tier-1 tests =="
    python -m pytest -x -q
fi

echo "== runner path: table1_suite --fast =="
python -m benchmarks.run --fast --only table1_suite

echo "== serve smoke: one continuous-batching cell through the runner =="
python - <<'EOF'
from repro.runner import BenchmarkRunner, Scenario

sc = Scenario(arch="gemma-2b", task="serve", batch=4, seq=8, slots=2,
              trace="bursty")
runner = BenchmarkRunner()
rr = runner.run(sc, record=False)
print(f"  {rr.name}: {rr.status} "
      f"({rr.extra.get('tok_per_s', 0):.1f} tok/s, "
      f"ttft_p50={rr.extra.get('ttft_p50', 0):.0f}us)")
assert rr.status == "ok", rr.error
for key in ("ttft_p50", "ttft_p95", "ttft_p99", "tok_lat_p50", "tok_lat_p95",
            "tok_lat_p99", "tok_per_s", "trace", "slots", "tokens_digest"):
    assert key in rr.extra, key
assert len(rr.extra["tokens"]) == 4
print("serve smoke OK")
EOF

echo "== mixed-prompt serve + capture->replay round-trip =="
python - <<'EOF'
import json
import os
import tempfile

from repro.runner import BenchmarkRunner, Scenario

# a bimodal trace: 4 requests spanning 2 distinct prompt lengths in one
# continuous-batching replay (per-slot KV positions)
sc = Scenario(arch="gemma-2b", task="serve", batch=4, seq=8, slots=2,
              trace="bursty+bimodal")
runner = BenchmarkRunner()
rr = runner.run(sc, record=False)
assert rr.status == "ok", rr.error
cap = rr.extra["capture"]
lens = set(cap["prompt_lens"])
print(f"  {rr.name}: {rr.status} prompt_lens={sorted(lens)}")
assert len(lens) >= 2, f"want >= 2 distinct prompt lengths, got {lens}"
assert len(cap["prompt_lens"]) == 4

# round-trip: replay the captured spec via trace="file:..." and demand
# byte-identical tokens
path = os.path.join(tempfile.mkdtemp(prefix="smoke_capture_"), "cap.json")
with open(path, "w") as f:
    json.dump({"trace_spec": 1, **cap}, f)
rr2 = runner.run(Scenario(arch="gemma-2b", task="serve", batch=4, seq=8,
                          slots=2, trace=f"file:{path}"), record=False)
assert rr2.status == "ok", rr2.error
assert rr2.extra["tokens_digest"] == rr.extra["tokens_digest"], \
    (rr.extra["tokens_digest"], rr2.extra["tokens_digest"])
print(f"  capture replay digest match: {rr2.extra['tokens_digest'][:16]}")
print("capture smoke OK")
EOF

echo "== batched admission: digest equality vs single-prefill baseline =="
python - <<'EOF'
from repro.runner import BenchmarkRunner, Scenario

# a queue-forming cell (compressed bursty bimodal arrivals) replayed
# under both admission policies: batched wave prefill must generate the
# byte-identical token streams of the one-prefill-per-request baseline
runner = BenchmarkRunner()
cell = dict(arch="gemma-2b", task="loadgen", batch=6, seq=8, slots=3,
            trace="bursty+bimodal", load=8.0)
rb = runner.run(Scenario(**cell), record=False)
rs = runner.run(Scenario(**cell, admission="single"), record=False)
assert rb.status == "ok", rb.error
assert rs.status == "ok", rs.error
print(f"  batched: {rb.extra['admit_calls']} prefill calls "
      f"(batch max {rb.extra['admit_batch_max']}), "
      f"single: {rs.extra['admit_calls']} calls")
assert rb.extra["tokens_digest"] == rs.extra["tokens_digest"], \
    (rb.extra["tokens_digest"], rs.extra["tokens_digest"])
assert rb.extra["admit_batch_max"] >= 2, rb.extra["admit_batch_max"]
assert rb.extra["admit_calls"] < rs.extra["admit_calls"]
print(f"  admission digest match: {rb.extra['tokens_digest'][:16]}")
print("admission smoke OK")
EOF

echo "== profiled cell: measured timeline + attribution through the runner =="
python - <<'EOF'
from repro.runner import BenchmarkRunner, Scenario

runner = BenchmarkRunner(runs=2)
rr = runner.run(Scenario(arch="gemma-2b", task="train", batch=1, seq=8),
                profile=True, record=False)
assert rr.status == "ok", rr.error
fracs = {k: v for k, v in rr.extra.items() if k.startswith("prof_frac_")}
total = sum(fracs.values())
assert abs(total - 1.0) < 0.05, fracs
assert rr.extra["prof_steps"] == 2 and rr.extra["prof_flops"] > 0
print("  " + rr.name + ": " +
      " ".join(f"{k.replace('prof_frac_', '')}={v:.2f}"
               for k, v in sorted(fracs.items())) +
      f" (sum {total:.3f})")
print("profiled smoke OK")
EOF

echo "== sharded dispatch: 2-cell matrix across --jobs 2 workers =="
python - <<'EOF'
from repro.runner import BenchmarkRunner, ScenarioMatrix

matrix = ScenarioMatrix(archs=["gemma-2b"], tasks=("train",),
                        batches=(1,), seqs=(8,), dtypes=("fp32", "bf16"))
runner = BenchmarkRunner(runs=1, warmup=0, jobs=2)
try:
    results = runner.run_matrix(matrix)
finally:
    runner.close()
for rr in results:
    print(f"  {rr.name}: {rr.status} (shard {rr.extra.get('shard')})")
    assert rr.status == "ok", rr.error
assert {rr.extra.get("shard") for rr in results} == {0, 1}
assert runner.stats.model_builds == 2, runner.stats.to_dict()
print("sharded smoke OK")
EOF

echo "== cluster dispatch: local:2 socket workers match serial, no orphans =="
python - <<'EOF'
import os

from repro.runner import BenchmarkRunner, ScenarioMatrix

matrix = ScenarioMatrix(archs=["gemma-2b"], tasks=("train",),
                        batches=(1,), seqs=(8,), dtypes=("fp32", "bf16"))
serial = BenchmarkRunner(runs=1, warmup=0)
serial_names = [rr.name for rr in serial.run_matrix(matrix)]

runner = BenchmarkRunner(runs=1, warmup=0)
try:
    results = runner.run_matrix(matrix, cluster="local:2")
    pids = runner.cluster_worker_pids()
finally:
    runner.close()
for rr in results:
    print(f"  {rr.name}: {rr.status} (host {rr.extra.get('host')})")
    assert rr.status == "ok", rr.error
    assert rr.extra.get("host", "").startswith("local"), rr.extra
assert [rr.name for rr in results] == serial_names
assert runner.stats.model_builds >= 1, runner.stats.to_dict()
# coordinator shutdown must leave no orphan worker processes
assert len(pids) == 2
for pid in pids:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        continue
    raise AssertionError(f"orphan cluster worker pid {pid}")
print("cluster smoke OK")
EOF

echo "== tuning gate: 2-candidate sweep under --jobs 2, DB hit on next trace =="
python - <<'EOF'
import os
import tempfile

tmp = tempfile.mkdtemp(prefix="smoke_tuning_")
os.environ["REPRO_TUNING_DB"] = os.path.join(tmp, "tuning_db.json")

from repro.runner import BenchmarkRunner
from repro.tuning import make_case, run_sweep
from repro.kernels.flash_attention import ops as fops
import jax
import jax.numpy as jnp

case = make_case("flash_attention", B=1, S=64, H=2, K=2, D=32)
runner = BenchmarkRunner(runs=1, warmup=0, compile_warmup=0, jobs=2,
                         measure_fence=False)
try:
    summary = run_sweep([case], runner, max_candidates=2)
finally:
    runner.close()
row = summary["cases"][0]
assert row["status"] == "ok", row
assert os.path.exists(summary["db_path"]), summary["db_path"]
print(f"  {row['case']}: winner={row['winner']} "
      f"({row['ratio']:.2f}x vs default)")

# a blocks-unspecified trace must now serve the recorded winner
served = {}
orig = fops.flash_attention_bh
def spy(*a, **kw):
    served.update({k: kw[k] for k in ("block_q", "block_k")})
    return orig(*a, **kw)
fops.flash_attention_bh = spy
ks = jax.random.split(jax.random.key(0), 3)
q = jax.random.normal(ks[0], (1, 64, 2, 32), jnp.float32)
k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.float32)
v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.float32)
fops.flash_attention(q, k, v)
fops.flash_attention_bh = orig
assert served == dict(row["winner"]), (served, row["winner"])
print("tuning smoke OK")
EOF

echo "== tuning queue drain: profile_report --drain-queue (serial) =="
python -m benchmarks.profile_report --drain-queue

echo "== trace gate: 1-cell profiled run exports a well-formed Chrome trace =="
python -m benchmarks.run --fast --only table1_suite \
    --filter '^gemma-2b/train/' --profile \
    --trace-out results/smoke_trace.json
python - <<'EOF'
import json

with open("results/smoke_trace.json") as f:
    trace = json.load(f)
events = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
assert events, "no complete events in trace"
by_id = {e["args"]["span_id"]: e for e in events}
cells = [e for e in events if e["args"].get("kind") == "cell"]
assert cells, "no cell spans in trace"
for cell in cells:
    kids = [e for e in events
            if e["args"].get("parent") == cell["args"]["span_id"]
            and e["args"].get("kind") == "phase"]
    assert kids, f"cell {cell['name']} has no phase children"
    cover = sum(k["dur"] for k in kids) / cell["dur"]
    print(f"  {cell['name']}: {len(kids)} phases cover {cover:.1%}")
    assert cover >= 0.95, f"{cell['name']}: phases cover only {cover:.1%}"
print("trace gate OK")
EOF

echo "== history gate: two nightly probes -> 2-point provenance series =="
python - <<'EOF'
import os
import tempfile

from repro.core.ci import run_nightly
from repro.core.regression import MetricStore
from repro.runner import BenchmarkRunner
from repro.telemetry.history import series

store = MetricStore(os.path.join(tempfile.mkdtemp(prefix="smoke_hist_"),
                                 "metrics.json"))
probe = dict(archs=["gemma-2b"], tasks=("train",), batches=(1,), seqs=(8,),
             runs=2)
runner = BenchmarkRunner(runs=2)
try:
    run_nightly(store, update_baseline=True, runner=runner, **probe)
    run_nightly(store, runner=runner, **probe)
finally:
    runner.close()
two_point = {k: pts for k, pts in series(store).items() if len(pts) >= 2}
assert two_point, "no 2-point provenance series after two nights"
for (name, prov), pts in sorted(two_point.items()):
    print(f"  {name} [{prov}]: {len(pts)} points")
print("history gate OK")
EOF

echo "== fleet gate: 2 supervised ticks, injected regression -> confirm + bisect =="
python scripts/fleet.py --ticks 2 --fast --results-dir results
python - <<'EOF'
import json
import re

# status heartbeat: schema-tagged, fresh per tick, counters monotonic
with open("results/fleet_status.json") as f:
    status = json.load(f)
assert status.get("fleet_status") == 1, status.keys()
ticks = status["ticks"]
assert len(ticks) == 2, [t["tick"] for t in ticks]
for t in ticks:
    assert t["ts"] > 0 and t["cells"] >= 2 and "counters" in t, t
c0, c1 = ticks[0]["counters"], ticks[1]["counters"]
for key, v0 in c0.items():
    assert c1.get(key, 0) >= v0, (key, v0, c1.get(key))
assert c1["fleet_ticks_total"] == 2, c1
assert status["open_findings"] >= 1, status["open_findings"]
print(f"  status: {len(ticks)} ticks, counters monotonic "
      f"({len(c1)} tracked), open={status['open_findings']}")

# triage report: the injected tick-2 regression was re-measured,
# confirmed, and bisected to the synthetic culprit
with open("results/fleet_report.json") as f:
    report = json.load(f)
rules = [fd["rule"] for fd in report["findings"]]
assert "regression_confirmed" in rules, rules
bisected = [fd for fd in report["findings"]
            if fd["rule"] == "regression_bisected"]
assert bisected, rules
for fd in bisected:
    assert fd["evidence"]["culprit"] == "c08", fd["evidence"]
print(f"  report: {rules.count('regression_confirmed')} confirmed, "
      f"{len(bisected)} bisected to c08")

# the stride-gated autotuner drain emptied the seeded queue
with open("results/tuning_queue.json") as f:
    queue = json.load(f)
assert queue["jobs"] == [], queue["jobs"]
assert c1.get("fleet_drained_jobs_total", 0) >= 1, c1

# Prometheus exposition parses line-by-line
sample = re.compile(r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? '
                    r'[-+0-9.eE]+(nan|inf)?$')
with open("results/fleet_metrics.prom") as f:
    lines = [ln for ln in f.read().splitlines() if ln]
values = {}
for ln in lines:
    if ln.startswith("#"):
        continue
    assert sample.match(ln), f"bad prometheus line: {ln!r}"
    name = ln.split("{")[0].split(" ")[0]
    values.setdefault(name, float(ln.rsplit(" ", 1)[1]))
assert values.get("fleet_cells_total", 0) > 0, values
print(f"  prometheus: {len(lines)} lines, "
      f"{len(values)} series, cells={values['fleet_cells_total']:.0f}")
print("fleet gate OK")
EOF

echo "smoke OK"
