"""MoE dispatch correctness and properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.layers import ACTIVATIONS, init_tree
from repro.models.moe import moe_defs, moe_ffn


def _setup(cfg, B=2, S=16, seed=0):
    defs = moe_defs(cfg)
    params = init_tree(defs, jax.random.key(seed))
    x = jax.random.normal(jax.random.key(seed + 1), (B, S, cfg.d_model), jnp.float32) * 0.5
    return params, x


def _dense_reference(p, x, cfg):
    """Route per token with a python loop — no capacity, exact."""
    B, S, d = x.shape
    act = ACTIVATIONS[cfg.activation]
    logits = np.asarray(jnp.einsum("bsd,de->bse", x, p["router"].astype(jnp.float32)))
    probs = np.asarray(jax.nn.softmax(jnp.asarray(logits), axis=-1))
    out = np.zeros((B, S, d), np.float32)
    wu, wg, wd = (np.asarray(p["w_up"], np.float32), np.asarray(p["w_gate"], np.float32),
                  np.asarray(p["w_down"], np.float32))
    xf = np.asarray(x, np.float32)
    for b in range(B):
        for s in range(S):
            top = np.argsort(-probs[b, s])[: cfg.top_k]
            w = probs[b, s][top]
            if cfg.name.startswith("deepseek"):
                w = w / w.sum()
            for e, wt in zip(top, w):
                h = np.asarray(act(jnp.asarray(xf[b, s] @ wg[e]))) * (xf[b, s] @ wu[e])
                out[b, s] += wt * (h @ wd[e])
    if cfg.n_shared_experts:
        su, sg, sd = (np.asarray(p["shared_up"], np.float32),
                      np.asarray(p["shared_gate"], np.float32),
                      np.asarray(p["shared_down"], np.float32))
        h = np.asarray(act(jnp.asarray(xf @ sg))) * (xf @ su)
        out += h @ sd
    return out


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "deepseek-v2-236b"])
def test_moe_matches_dense_reference_with_ample_capacity(arch):
    cfg = dataclasses.replace(get_arch(arch).reduced(), capacity_factor=8.0, moe_groups=1)
    params, x = _setup(cfg)
    got = moe_ffn(params, x, cfg)
    want = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got, np.float32), want, atol=2e-2, rtol=2e-2)


def test_moe_capacity_drops_are_bounded_and_reported():
    cfg = dataclasses.replace(get_arch("mixtral-8x7b").reduced(),
                              capacity_factor=0.5, moe_groups=1)
    params, x = _setup(cfg, B=4, S=32)
    y, aux = moe_ffn(params, x, cfg, return_aux=True)
    assert y.shape == x.shape
    assert 0.0 <= float(aux["dropped_frac"]) <= 0.8
    assert float(aux["load_balance"]) > 0.5   # E * sum(f*p) >= 1 at balance


def test_moe_grouping_invariance():
    """Dispatch groups change capacity locality, not (ample-capacity) results."""
    base = dataclasses.replace(get_arch("mixtral-8x7b").reduced(), capacity_factor=8.0)
    params, x = _setup(base, B=4, S=16)
    y1 = moe_ffn(params, x, dataclasses.replace(base, moe_groups=1))
    y4 = moe_ffn(params, x, dataclasses.replace(base, moe_groups=4))
    np.testing.assert_allclose(np.asarray(y1, np.float32), np.asarray(y4, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_moe_is_differentiable():
    cfg = dataclasses.replace(get_arch("mixtral-8x7b").reduced(), moe_groups=1)
    params, x = _setup(cfg)

    def f(p):
        return jnp.sum(moe_ffn(p, x, cfg) ** 2)

    g = jax.grad(f)(params)
    flat = jax.tree.leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in flat)
    assert any(float(jnp.max(jnp.abs(t))) > 0 for t in flat)
