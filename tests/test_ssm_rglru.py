"""SSD + RG-LRU model-layer invariants: chunked == sequential, decode-step
chain == full scan (the cache-correctness property for SSM/hybrid serving)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.rglru import rglru_scan, rglru_step
from repro.models.ssm import ssd_chunked, ssd_sequential, ssd_step


def _ssd_inputs(B=2, S=64, H=3, P=16, N=32, seed=0):
    x = jax.random.normal(jax.random.key(seed), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(seed + 1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.key(seed + 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.key(seed + 3), (B, S, N)) * 0.3
    Cm = jax.random.normal(jax.random.key(seed + 4), (B, S, N)) * 0.3
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk", [8, 16, 64, 128])
def test_ssd_chunked_equals_sequential(chunk):
    x, dt, A, Bm, Cm = _ssd_inputs()
    yc, hc = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    ys, hs = ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(ys), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hs), atol=1e-4, rtol=1e-4)


def test_ssd_decode_chain_matches_scan():
    x, dt, A, Bm, Cm = _ssd_inputs(B=1, S=24)
    ys, hT = ssd_sequential(x, dt, A, Bm, Cm)
    state = jnp.zeros_like(hT)
    outs = []
    for t in range(x.shape[1]):
        y, state = ssd_step(state, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        outs.append(y)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ys), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(hT), atol=1e-4, rtol=1e-4)


def test_ssd_carried_state_across_segments():
    """prefill(S) == prefill(S/2) + continue(S/2) — the serving property."""
    x, dt, A, Bm, Cm = _ssd_inputs(B=1, S=64)
    y_full, h_full = ssd_chunked(x, dt, A, Bm, Cm, 16)
    y1, h1 = ssd_chunked(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32], 16)
    y2, h2 = ssd_chunked(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:], 16, init_state=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-4, rtol=1e-4)


def test_rglru_scan_matches_step_chain():
    B, S, D = 2, 40, 16
    x = jax.random.normal(jax.random.key(0), (B, S, D))
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(1), (B, S, D)) * 2)
    h, h_last = rglru_scan(x, a)
    state = jnp.zeros((B, D))
    for t in range(S):
        bt = jnp.sqrt(jnp.maximum(1 - a[:, t] ** 2, 1e-12)) * x[:, t]
        state = a[:, t] * state + bt
    np.testing.assert_allclose(np.asarray(h[:, -1]), np.asarray(state), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(state), atol=1e-5, rtol=1e-5)


def test_rglru_init_state_continuation():
    B, S, D = 1, 32, 8
    x = jax.random.normal(jax.random.key(0), (B, S, D))
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(1), (B, S, D)))
    h_full, _ = rglru_scan(x, a)
    h1, s1 = rglru_scan(x[:, :16], a[:, :16])
    h2, _ = rglru_scan(x[:, 16:], a[:, 16:], init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(h_full), atol=1e-5, rtol=1e-5)
