"""Autotuning subsystem: DB round-trip + schema tagging, deterministic
valid search spaces, kernel cells through the runner, sweep -> DB -> ops
serving, candidate numerics vs the ref oracles, and the detector bridge."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fops
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru.ops import rglru
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.validate import nearest_valid_block, resolve_interpret, validate_block
from repro.models.ssm import ssd_sequential
from repro.runner import BenchmarkRunner, Scenario
from repro.tuning import db as tdb
from repro.tuning import space
from repro.tuning.bridge import (cases_for_record, cases_from_jobs, enqueue_jobs,
                                 jobs_from_findings, kernels_for_arch, load_queue)
from repro.tuning.db import TuningDB, tuned_params
from repro.tuning.sweep import run_sweep, sweep_matrix


@pytest.fixture
def tmp_db(tmp_path, monkeypatch):
    """Isolate the ambient tuning DB (the path ops.py consults)."""
    path = tmp_path / "tuning_db.json"
    monkeypatch.setenv("REPRO_TUNING_DB", str(path))
    tdb.invalidate_cache()
    yield path
    tdb.invalidate_cache()


# ---- DB ------------------------------------------------------------------

def test_db_roundtrip(tmp_db):
    db = TuningDB.load(tmp_db)
    db.record("flash_attention", "Sq64,Sk64,D32", "fp32",
              params={"block_q": 32, "block_k": 64}, median_us=12.5,
              default_params={"block_q": 64, "block_k": 64}, default_us=20.0,
              case="flash_attention@B1,S64,H2,K2,D32", candidates=4)
    db.save()
    back = TuningDB.load(tmp_db)
    entry = back.lookup("flash_attention", "Sq64,Sk64,D32", "fp32")
    assert entry["params"] == {"block_q": 32, "block_k": 64}
    assert entry["default_us"] == 20.0
    assert back.params("flash_attention", "Sq64,Sk64,D32", "fp32") == \
        {"block_q": 32, "block_k": 64}
    assert back.lookup("flash_attention", "Sq64,Sk64,D32", "bf16") is None


def test_db_schema_tag_rejected(tmp_db):
    tmp_db.write_text(json.dumps({"trace_spec": 1, "entries": {}}))
    with pytest.raises(ValueError, match="tuning_db"):
        TuningDB.load(tmp_db)
    # the trace-time consult degrades to a miss instead of raising
    assert tuned_params("flash_attention", "Sq64,Sk64,D32", "fp32") is None


def test_db_miss_and_broken_file_serve_none(tmp_db):
    assert tuned_params("rglru", "S64,D64", "fp32") is None   # no file
    tmp_db.write_text("{not json")
    assert tuned_params("rglru", "S64,D64", "fp32") is None   # unreadable


def test_db_consult_picks_up_rewrite(tmp_db):
    db = TuningDB(tmp_db)
    db.record("rglru", "S64,D64", "fp32", params={"block_t": 16, "block_d": 64},
              median_us=1.0)
    db.save()
    assert tuned_params("rglru", "S64,D64", "fp32") == {"block_t": 16, "block_d": 64}
    db.record("rglru", "S64,D64", "fp32", params={"block_t": 32, "block_d": 64},
              median_us=0.5)
    db.save()
    assert tuned_params("rglru", "S64,D64", "fp32") == {"block_t": 32, "block_d": 64}


# ---- search space --------------------------------------------------------

def test_case_and_candidate_ids_roundtrip():
    case = space.make_case("flash_attention", B=2, S=128, H=4, K=2, D=64)
    assert case.case_id == "flash_attention@B2,S128,H4,K2,D64"
    assert case.signature == "Sq128,Sk128,D64"
    assert space.parse_case(case.case_id) == case
    params = {"block_q": 64, "block_k": 128}
    cid = space.candidate_id(case, params)
    back_case, back_params = space.parse_candidate(cid)
    assert (back_case, back_params) == (case, params)
    for bad in ("flash_attention@B2", "nope@B1,S64@x=1",
                "flash_attention@B2,S128,H4,K2,D64@block_q=64"):
        with pytest.raises(ValueError):
            space.parse_candidate(bad)


@pytest.mark.parametrize("case", [
    space.make_case("flash_attention", B=1, S=64, H=2, K=2, D=32),
    space.make_case("flash_attention", B=2, S=96, H=4, K=2, D=64, dtype="bf16"),
    space.make_case("rglru", B=1, S=48, D=96),
    space.make_case("rglru", B=2, S=128, D=128),
    space.make_case("ssd", B=1, S=64, H=2, P=16, N=16),
])
def test_candidates_deterministic_and_valid(case):
    cands = space.candidates(case)
    assert cands == space.candidates(case)            # deterministic
    assert cands[0] == space.default_params(case)     # default is #0
    assert len(cands) <= space.MAX_CANDIDATES
    assert len({space.candidate_id(case, p) for p in cands}) == len(cands)
    spec = space.KERNELS[case.kernel]
    for p in cands:
        spec["validate"](dict(case.dims), p)          # no candidate asserts
        assert space.vmem_bytes(case, p) <= space.VMEM_BUDGET_BYTES


def test_candidates_cap():
    case = space.make_case("flash_attention", B=1, S=256, H=2, K=2, D=64)
    assert len(space.candidates(case, max_candidates=3)) == 3
    assert space.candidates(case, max_candidates=3)[0] == space.default_params(case)


# ---- shared block validation (the satellite) -----------------------------

def test_nearest_valid_block():
    assert nearest_valid_block(48, 32, divides=True) == 24
    assert nearest_valid_block(64, 256) == 64
    assert nearest_valid_block(64, 0) == 1


def test_validate_block_messages():
    with pytest.raises(ValueError, match=r"rglru: block_t=32 does not divide "
                                         r"S=48 \(nearest valid: 24\)"):
        validate_block("rglru", "S", 48, "block_t", 32, divides=True)
    with pytest.raises(ValueError, match=r"flash_attention: block_q=256 is "
                                         r"outside \[1, Sq=64\]"):
        validate_block("flash_attention", "Sq", 64, "block_q", 256)
    with pytest.raises(ValueError, match="must be an int"):
        validate_block("ssd", "S", 64, "chunk", 16.0)


def test_kernel_layers_reject_invalid_blocks():
    # ops layer: out-of-bound blocks raise (never clamp); non-divisors
    # are legal there — the ops layer pads, the kernel enforces division
    q = jax.random.normal(jax.random.key(1), (1, 64, 2, 32))
    with pytest.raises(ValueError, match="flash_attention: block_q"):
        fops.flash_attention(q, q[:, :, :2], q[:, :, :2], block_q=256)
    x = jax.random.normal(jax.random.key(2), (1, 48, 64))
    a = jax.nn.sigmoid(x)
    with pytest.raises(ValueError, match="rglru: block_t"):
        rglru(x, a, block_t=64)      # 64 > S=48: outside the bound
    xs = jax.random.normal(jax.random.key(3), (1, 48, 2, 16))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(4), (1, 48, 2)))
    A = -jnp.ones((2,))
    Bm = jax.random.normal(jax.random.key(5), (1, 48, 16))
    with pytest.raises(ValueError, match="ssd: chunk"):
        ssd(xs, dt, A, Bm, Bm, chunk=64)   # 64 > S=48
    # kernel layer: the old silent `assert S % block == 0` is now a clear
    # divisibility error naming the kernel and the nearest valid block
    from repro.kernels.rglru.kernel import rglru_scan_kernel
    with pytest.raises(ValueError, match=r"rglru: block_t=32 does not divide"):
        rglru_scan_kernel(a, x, block_t=32, block_d=64)


def test_resolve_interpret():
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    # auto-detection: interpret unless we are actually on a TPU backend
    assert resolve_interpret(None) is (jax.default_backend() != "tpu")


# ---- kernel cells through the runner -------------------------------------

def test_kernel_scenario_validation():
    case = space.make_case("flash_attention", B=1, S=64, H=2, K=2, D=32)
    cid = space.candidate_id(case, space.default_params(case))
    sc = Scenario(arch=cid, task="kernel", batch=1, seq=64, mode="jit")
    assert sc.name.endswith("/kernel/b1/s64/fp32/jit")
    assert sc.build_key() == ("kernel", cid, "fp32")
    with pytest.raises(ValueError, match="kernel cells"):
        Scenario(arch=cid, task="kernel", mode="eager")
    with pytest.raises(ValueError, match="candidate-id"):
        Scenario(arch="gemma-2b", task="kernel", mode="jit")


def test_sweep_matrix_one_cell_per_candidate():
    cases = [space.make_case("flash_attention", B=1, S=64, H=2, K=2, D=32),
             space.make_case("rglru", B=2, S=32, D=64)]
    matrix = sweep_matrix(cases, max_candidates=2)
    names = [s.name for s in matrix]
    assert len(names) == 4 and len(set(names)) == 4
    assert all(s.task == "kernel" and s.mode == "jit" for s in matrix)
    # the exact-name filters keep each candidate on its own case's axes
    assert sum(1 for n in names if "/b1/s64/" in n) == 2
    assert sum(1 for n in names if "/b2/s32/" in n) == 2


def test_kernel_cell_run_result(tmp_db):
    case = space.make_case("rglru", B=1, S=32, D=64)
    cid = space.candidate_id(case, space.default_params(case))
    runner = BenchmarkRunner(runs=1, warmup=0, compile_warmup=0)
    rr = runner.run(Scenario(arch=cid, task="kernel", batch=1, seq=32,
                             mode="jit"), record=False)
    assert rr.status == "ok", rr.error
    assert rr.extra["tuning_kernel"] == "rglru"
    assert rr.extra["tuning_case"] == case.case_id
    assert rr.extra["tuning_signature"] == "S32,D64"
    assert rr.extra["tuning_default"] is True
    assert rr.median_us > 0


def test_sweep_records_winner_and_ops_serve_it(tmp_db, monkeypatch):
    case = space.make_case("flash_attention", B=1, S=64, H=2, K=2, D=32)
    runner = BenchmarkRunner(runs=1, warmup=0, compile_warmup=0)
    summary = run_sweep([case], runner, max_candidates=2)
    row = summary["cases"][0]
    assert row["status"] == "ok"
    assert summary["recorded"] == 1 and tmp_db.exists()
    assert row["ratio"] >= 1.0        # default is a candidate; argmin wins
    assert tuned_params("flash_attention", case.signature, "fp32") == row["winner"]

    served = {}
    orig = fops.flash_attention_bh
    def spy(*a, **kw):
        served.update({k: kw[k] for k in ("block_q", "block_k")})
        return orig(*a, **kw)
    monkeypatch.setattr(fops, "flash_attention_bh", spy)
    q = jax.random.normal(jax.random.key(1), (1, 64, 2, 32))
    k = jax.random.normal(jax.random.key(2), (1, 64, 2, 32))
    v = jax.random.normal(jax.random.key(3), (1, 64, 2, 32))
    fops.flash_attention(q, k, v)                  # no explicit blocks
    assert served == row["winner"]
    served.clear()
    fops.flash_attention(q, k, v, block_q=16, block_k=16)
    assert served == {"block_q": 16, "block_k": 16}   # explicit wins over DB


def test_stale_db_entry_falls_back_to_defaults(tmp_db):
    db = TuningDB(tmp_db)
    # a winner swept for some OTHER shape: invalid for S=64
    db.record("flash_attention", "Sq64,Sk64,D32", "fp32",
              params={"block_q": 256, "block_k": 256}, median_us=1.0)
    db.save()
    q = jax.random.normal(jax.random.key(1), (1, 64, 2, 32))
    out = fops.flash_attention(q, q, q)            # must not raise
    assert out.shape == q.shape


# ---- candidate numerics vs the ref oracles -------------------------------

def _fa_ref(q, k, v, **kw):
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, k.shape[1], D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, k.shape[1], D)
    return attention_ref(qf, kf, vf, **kw).reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("dtype", ["fp32", "bf16"])
def test_flash_candidates_match_ref(dtype):
    case = space.make_case("flash_attention", B=1, S=64, H=2, K=2, D=32,
                           dtype=dtype)
    dt = jnp.bfloat16 if dtype == "bf16" else jnp.float32
    q = jax.random.normal(jax.random.key(1), (1, 64, 2, 32), dt)
    k = jax.random.normal(jax.random.key(2), (1, 64, 2, 32), dt)
    v = jax.random.normal(jax.random.key(3), (1, 64, 2, 32), dt)
    ref = _fa_ref(q, k, v)
    tol = 2e-2 if dtype == "bf16" else 2e-5
    for p in space.candidates(case, max_candidates=4):
        out = fops.flash_attention(q, k, v, **p)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol, err_msg=str(p))


def test_rglru_candidates_match_ref():
    case = space.make_case("rglru", B=1, S=64, D=64)
    x = jax.random.normal(jax.random.key(9), (1, 64, 64))
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(10), (1, 64, 64)) * 2)
    hr = rglru_ref(a, jnp.sqrt(1 - a ** 2) * x)
    for p in space.candidates(case, max_candidates=4):
        hk = rglru(x, a, **p)
        np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                                   atol=2e-5, rtol=2e-5, err_msg=str(p))


def test_ssd_candidates_match_ref():
    case = space.make_case("ssd", B=1, S=64, H=2, P=16, N=16)
    x = jax.random.normal(jax.random.key(4), (1, 64, 2, 16))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(5), (1, 64, 2)))
    A = -jnp.exp(jax.random.normal(jax.random.key(6), (2,)) * 0.3)
    Bm = jax.random.normal(jax.random.key(7), (1, 64, 16)) * 0.3
    Cm = jax.random.normal(jax.random.key(8), (1, 64, 16)) * 0.3
    yr, _ = ssd_sequential(x, dt, A, Bm, Cm)
    for p in space.candidates(case, max_candidates=4):
        yk = ssd(x, dt, A, Bm, Cm, **p)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                                   atol=5e-5, rtol=5e-5, err_msg=str(p))


# ---- detector bridge -----------------------------------------------------

def test_kernels_for_arch():
    assert kernels_for_arch("gemma-2b") == ["flash_attention"]
    assert kernels_for_arch("mamba2-2.7b") == ["ssd"]
    assert kernels_for_arch("recurrentgemma-9b") == ["flash_attention", "rglru"]
    assert kernels_for_arch("no-such-arch") == []


def test_cases_for_record_skips_kernel_cells_and_unknown():
    assert cases_for_record({"arch": "gemma-2b", "task": "kernel",
                             "batch": 1, "seq": 64}) == []
    assert cases_for_record({"arch": "no-such-arch", "task": "train",
                             "batch": 1, "seq": 64}) == []
    cases = cases_for_record({"arch": "recurrentgemma-9b", "task": "train",
                              "batch": 2, "seq": 64, "dtype": "fp32"})
    assert [c.kernel for c in cases] == ["flash_attention", "rglru"]
    assert all(c.dim("B") == 2 and c.dim("S") == 64 for c in cases)


def test_jobs_from_findings_dedup_and_queue(tmp_db, tmp_path):
    recs = [{"name": "gemma-2b/train/b1/s32/fp32/jit", "arch": "gemma-2b",
             "task": "train", "batch": 1, "seq": 32, "dtype": "fp32"}]
    findings = [
        {"rule": "low_util", "cell": recs[0]["name"], "severity": "warn"},
        {"rule": "data_movement_bound", "cell": recs[0]["name"],
         "severity": "info"},                       # same case: deduped
        {"rule": "dispatch_bound", "cell": recs[0]["name"],
         "severity": "crit"},                       # not a tune rule
    ]
    jobs = jobs_from_findings(findings, recs)
    assert len(jobs) == 1
    job = jobs[0]
    assert job["kernel"] == "flash_attention"
    assert job["source_rule"] == "low_util"         # first (strongest) kept
    assert job["in_db"] is False

    qp = tmp_path / "queue.json"
    enqueue_jobs(jobs, qp)
    enqueue_jobs(jobs, qp)                          # merge is idempotent
    back = load_queue(qp)
    assert len(back) == 1 and back[0]["case"] == job["case"]
    cases = cases_from_jobs(back + [{"case": "bogus"}, {"nope": 1}])
    assert len(cases) == 1 and cases[0].kernel == "flash_attention"


def test_load_queue_schema_tag_rejected(tmp_path):
    qp = tmp_path / "queue.json"
    qp.write_text(json.dumps({"tuning_db": 1, "jobs": []}))
    with pytest.raises(ValueError, match="tuning_queue"):
        load_queue(qp)
    assert load_queue(tmp_path / "missing.json") == []
