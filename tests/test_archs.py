"""Per-architecture smoke tests (assignment requirement): reduced config,
one forward/train step on CPU, output shapes + finiteness; plus
prefill+decode consistency against the cache-free forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import build_model

ALL_ARCHS = list(list_archs())


def _batch_for(cfg, B, S, key=2):
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(jax.random.key(key), (B, cfg.enc_seq, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(jax.random.key(key), (B, cfg.n_prefix, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 64
    batch = _batch_for(cfg, B, S)
    logits = model.forward(params, batch)
    expect_s = S + (cfg.n_prefix or 0)
    assert logits.shape == (B, expect_s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step_no_nans(arch):
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import adamw_init
    cfg = get_arch(arch).reduced()
    step, model = make_train_step(cfg)
    params = model.init(jax.random.key(0))
    state = (params, adamw_init(params))
    batch = _batch_for(cfg, 2, 32)
    (params2, opt2), metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 48
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab)
    batch = _batch_for(cfg, B, S)
    batch["tokens"] = toks[:, :S]
    full = model.forward(params, {**batch, "tokens": toks})
    cache = model.init_cache(B, S + 8 + (cfg.n_prefix or 0))
    lg_pre, cache = model.prefill(params, batch, cache)
    lg_dec, cache = model.decode_step(params, toks[:, S:S + 1], cache)
    npfx = cfg.n_prefix or 0
    np.testing.assert_allclose(
        np.asarray(lg_pre[:, 0], np.float32), np.asarray(full[:, npfx + S - 1], np.float32),
        atol=0.35, rtol=0.05)
    np.testing.assert_allclose(
        np.asarray(lg_dec[:, 0], np.float32), np.asarray(full[:, npfx + S], np.float32),
        atol=0.35, rtol=0.05)


@pytest.mark.parametrize("arch", ["gemma3-12b", "mixtral-8x7b", "recurrentgemma-9b"])
def test_local_ring_cache_long_decode(arch):
    """Decode past the local window: ring cache must match full forward."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B = 1
    W = cfg.local_window
    S = W + 24   # prompt exceeds the window -> ring wraps
    toks = jax.random.randint(jax.random.key(1), (B, S + 4), 0, cfg.vocab)
    full = model.forward(params, {"tokens": toks})
    cache = model.init_cache(B, S + 16)
    lg, cache = model.prefill(params, {"tokens": toks[:, :S]}, cache)
    for i in range(3):
        lg, cache = model.decode_step(params, toks[:, S + i:S + i + 1], cache)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32), np.asarray(full[:, S + i], np.float32),
            atol=0.35, rtol=0.05)


def test_all_archs_registered_with_exact_assigned_sizes():
    spec = {
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }
    assert set(spec) == set(ALL_ARCHS)
    for a, (L, d, H, K, ff, V) in spec.items():
        cfg = get_arch(a)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, H, K, ff, V), a
