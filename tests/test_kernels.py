"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py
oracles, executed with interpret=True (kernel body runs on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru.ops import rglru
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.ssd.ops import ssd
from repro.models.ssm import ssd_sequential


def _fa_ref(q, k, v, **kw):
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, k.shape[1], D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, k.shape[1], D)
    return attention_ref(qf, kf, vf, **kw).reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("B,S,H,K,D", [
    (1, 128, 2, 2, 32), (2, 256, 4, 2, 64), (1, 192, 8, 1, 128), (2, 64, 4, 4, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(B, S, H, K, D, dtype):
    q = jax.random.normal(jax.random.key(1), (B, S, H, D), dtype)
    k = jax.random.normal(jax.random.key(2), (B, S, K, D), dtype)
    v = jax.random.normal(jax.random.key(3), (B, S, K, D), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = _fa_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("mask,window", [("causal", 0), ("local", 32), ("local", 100), ("full", 0)])
def test_flash_attention_masks(mask, window):
    B, S, H, D = 1, 160, 2, 64   # S not a multiple of block: tests tail masking
    q = jax.random.normal(jax.random.key(1), (B, S, H, D))
    k = jax.random.normal(jax.random.key(2), (B, S, H, D))
    v = jax.random.normal(jax.random.key(3), (B, S, H, D))
    out = flash_attention(q, k, v, mask_type=mask, window=window, block_q=64, block_k=64)
    ref = _fa_ref(q, k, v, mask_type=mask, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-4)


def test_flash_attention_softcap_and_offset():
    B, S, H, D = 1, 128, 2, 32
    q = jax.random.normal(jax.random.key(1), (B, 32, H, D))
    k = jax.random.normal(jax.random.key(2), (B, S, H, D))
    v = jax.random.normal(jax.random.key(3), (B, S, H, D))
    out = flash_attention(q, k, v, q_offset=96, softcap=30.0, block_q=32, block_k=64)
    ref = _fa_ref(q, k, v, q_offset=96, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 32, 16), (2, 96, 3, 16, 32, 32), (1, 128, 1, 32, 16, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_vs_sequential(B, S, H, P, N, chunk, dtype):
    x = jax.random.normal(jax.random.key(4), (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(5), (B, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(jax.random.key(6), (H,)) * 0.3)
    Bm = (jax.random.normal(jax.random.key(7), (B, S, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(jax.random.key(8), (B, S, N)) * 0.3).astype(dtype)
    yk = ssd(x, dt, A, Bm, Cm, chunk=chunk)
    yr, _ = ssd_sequential(x.astype(jnp.float32), dt, A,
                           Bm.astype(jnp.float32), Cm.astype(jnp.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-5
    np.testing.assert_allclose(np.asarray(yk, np.float32), np.asarray(yr, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,D,block_t", [(1, 64, 64, 16), (2, 48, 96, 16), (1, 128, 128, 32)])
def test_rglru_kernel_vs_ref(B, S, D, block_t):
    x = jax.random.normal(jax.random.key(9), (B, S, D))
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(10), (B, S, D)) * 2)
    hk = rglru(x, a, block_t=block_t)
    b = jnp.sqrt(1 - a ** 2) * x
    hr = rglru_ref(a, b)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), atol=2e-5, rtol=2e-5)


def test_rglru_extreme_decay_stability():
    """a -> 0 and a -> 1 extremes must stay finite (log-space blocking)."""
    B, S, D = 1, 32, 128
    x = jax.random.normal(jax.random.key(0), (B, S, D))
    a = jnp.concatenate([jnp.full((B, S, D // 2), 1e-6), jnp.full((B, S, D // 2), 1 - 1e-6)], -1)
    h = rglru(x, a, block_t=16)
    assert bool(jnp.all(jnp.isfinite(h)))
