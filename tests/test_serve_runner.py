"""Serving as a first-class scenario axis: trace determinism, the shared
percentile helper, serve scenario/matrix semantics, latency-metric
recording, and the serial-vs-sharded token-equality invariant."""
import os
import subprocess
import sys

import pytest

from repro.runner import (BenchmarkRunner, ResultStore, Scenario,
                          ScenarioMatrix, TraceSpec, assign_shards,
                          generate_trace, percentile)
from repro.runner.latency import latency_summary
from repro.runner.traces import tokens_by_rid, tokens_digest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: one small serve cell reused across tests (cheap on the reduced config)
SERVE = dict(arch="gemma-2b", task="serve", batch=4, seq=8, slots=2)


# ---- percentile helper ----------------------------------------------------

def test_percentile_single_sample_is_every_percentile():
    assert percentile([7.0], 0) == percentile([7.0], 50) == \
        percentile([7.0], 99) == 7.0


def test_percentile_odd_and_even_counts():
    odd = [3.0, 1.0, 2.0]                    # sorted: 1 2 3
    assert percentile(odd, 50) == 2.0
    assert percentile(odd, 0) == 1.0 and percentile(odd, 100) == 3.0
    even = [4.0, 1.0, 3.0, 2.0]              # sorted: 1 2 3 4
    assert percentile(even, 50) == 2.5       # interpolated middle
    assert percentile(even, 25) == 1.75
    # linear interpolation between closest ranks (numpy semantics)
    assert percentile([0.0, 10.0], 95) == pytest.approx(9.5)


def test_percentile_rejects_bad_inputs():
    with pytest.raises(ValueError):
        percentile([], 50)
    with pytest.raises(ValueError):
        percentile([1.0], 101)


def test_latency_summary_keys_and_scaling():
    s = latency_summary([0.001, 0.002, 0.003], "ttft", scale=1e6)
    assert set(s) == {"ttft_p50", "ttft_p95", "ttft_p99"}
    assert s["ttft_p50"] == pytest.approx(2000.0)
    assert latency_summary([], "ttft") == {}


# ---- trace generation -----------------------------------------------------

def test_trace_same_seed_same_trace():
    spec = TraceSpec(profile="mixed", requests=8, prompt_len=6, max_new=4,
                     seed=3)
    a, b = generate_trace(spec, vocab=100), generate_trace(spec, vocab=100)
    assert [(r.rid, r.arrival_step, r.max_new, r.prompt.tolist())
            for r in a] == \
           [(r.rid, r.arrival_step, r.max_new, r.prompt.tolist())
            for r in b]
    # a different seed moves the prompts
    c = generate_trace(TraceSpec(profile="mixed", requests=8, prompt_len=6,
                                 max_new=4, seed=4), vocab=100)
    assert [r.prompt.tolist() for r in a] != [r.prompt.tolist() for r in c]


def test_trace_profiles_shape_the_load():
    uni = generate_trace(TraceSpec("uniform", 8, 6, 4), vocab=50)
    assert all(r.arrival_step == 0 and r.max_new == 4 for r in uni)
    bursty = generate_trace(TraceSpec("bursty", 8, 6, 4, seed=1), vocab=50)
    assert any(r.arrival_step > 0 for r in bursty)      # staggered arrivals
    assert all(r.max_new == 4 for r in bursty)
    mixed = generate_trace(TraceSpec("mixed", 16, 6, 4, seed=1), vocab=50)
    assert len({r.max_new for r in mixed}) > 1          # varied budgets
    assert all(1 <= r.max_new <= 8 for r in mixed)
    spec = TraceSpec("mixed", 16, 6, 4)
    assert spec.max_new_cap == 8
    with pytest.raises(ValueError):
        TraceSpec("flash-crowd", 8, 6, 4)


def test_tokens_digest_is_order_canonical():
    reqs = generate_trace(TraceSpec("bursty", 4, 6, 4, seed=2), vocab=50)
    for i, r in enumerate(reqs):
        r.out = [i, i + 1]
    forward = tokens_digest(tokens_by_rid(reqs))
    assert forward == tokens_digest(tokens_by_rid(list(reversed(reqs))))


# ---- scenario / matrix semantics ------------------------------------------

def test_serve_scenario_axes_and_validation():
    sc = Scenario(**SERVE, trace="bursty")
    assert sc.name == "gemma-2b/serve/b4/s8/fp32/jit_donated/x2/bursty"
    assert sc.build_key()[-2:] == ("serve", 2)
    # bare serve normalizes its axes
    bare = Scenario(arch="gemma-2b", task="serve")
    assert bare.slots == 4 and bare.trace == "uniform"
    # round-trips through dict (worker dispatch payload)
    assert Scenario.from_dict(sc.to_dict()) == sc
    with pytest.raises(ValueError):
        Scenario(arch="gemma-2b", task="serve", mode="eager")
    with pytest.raises(ValueError):
        Scenario(arch="gemma-2b", task="serve", trace="flash-crowd")
    with pytest.raises(ValueError):
        Scenario(arch="gemma-2b", task="train", slots=2)   # serve-only axis
    # serve cells of one (arch, slots) group share a shard; the step cells
    # of the same arch keep their own (serve extends the key)
    step = Scenario(arch="gemma-2b", task="train")
    assert step.build_key() != sc.build_key()
    assert sc.build_key() == Scenario(**SERVE, trace="uniform").build_key()


def test_matrix_expands_serve_axes_only_for_serve():
    m = ScenarioMatrix(archs=["a1"], tasks=("train", "serve"), batches=(4,),
                       seqs=(8,), modes=("eager", "jit_donated"),
                       slots=(2, 4), traces=("uniform", "bursty"))
    names = [s.name for s in m.expand()]
    # train: 2 modes x 1 cell; serve: jit_donated only, 2 slots x 2 traces
    assert len([n for n in names if "/train/" in n]) == 2
    serve = [s for s in m if s.task == "serve"]
    assert len(serve) == 4
    assert all(s.mode == "jit_donated" for s in serve)
    assert {(s.slots, s.trace) for s in serve} == \
        {(2, "uniform"), (2, "bursty"), (4, "uniform"), (4, "bursty")}
    # serve cells shard by (arch, slots): 2 groups here
    shards = assign_shards(serve, 2)
    assert sorted(map(len, shards)) == [2, 2]


# ---- execution: metrics + determinism -------------------------------------

def test_serve_run_records_latency_metrics(tmp_path):
    store = ResultStore(str(tmp_path / "s"))
    r = BenchmarkRunner(store=store)
    rr = r.run(Scenario(**SERVE, trace="bursty"))
    assert rr.status == "ok", rr.error
    ex = rr.extra
    for key in ("ttft_p50", "ttft_p95", "ttft_p99", "tok_lat_p50",
                "tok_lat_p95", "tok_lat_p99", "tok_per_s", "tokens_digest"):
        assert key in ex, key
    assert ex["trace"] == "bursty" and ex["slots"] == 2
    assert ex["tok_per_s"] > 0 and ex["ttft_p50"] > 0
    assert ex["ttft_p50"] <= ex["ttft_p95"] <= ex["ttft_p99"]
    assert len(ex["tokens"]) == 4 and rr.runs == 4
    assert ex["tokens_digest"] == tokens_digest(ex["tokens"])
    # per-token latency view occupies the core timing fields
    assert rr.median_us == pytest.approx(ex["tok_lat_p50"])
    # a fresh engine's jit is paid by the untimed warm replay and recorded
    # as compile_us, keeping the latency samples steady-state
    assert rr.compile_us > 0
    # persisted through the store like any other cell
    assert store.latest_result(rr.name).extra["tokens"] == ex["tokens"]
    # the engine (compiled decode) is cached: a re-run reuses it and
    # regenerates the identical trace -> identical tokens
    rr2 = r.run(Scenario(**SERVE, trace="bursty"))
    assert rr2.cache == {"model_reused": True, "executable_reused": True}
    assert rr2.extra["tokens"] == ex["tokens"]
    assert rr2.compile_us == 0.0   # nothing compiled on an engine cache hit
    assert r.stats.executable_builds == 1
    # the same engine serves the other trace profile of this shape (the
    # trace changes load timing, not what gets compiled)
    rr3 = r.run(Scenario(**SERVE, trace="uniform"))
    assert rr3.status == "ok" and r.stats.executable_builds == 1
    assert rr3.extra["trace"] == "uniform" and rr3.extra["queue_depth_max"] >= 0


def test_serve_many_refill_waves_fit_the_cache():
    """requests >> slots: per-slot positions rewind on refill, so the KV
    cache needs exactly the largest single-request footprint (prompt +
    budget) — no lockstep slack, however many refill waves the replay
    has.  One token less and the engine must refuse to decode past its
    cache instead of corrupting attention."""
    from repro.core.suite import build_arch
    from repro.launch.serve import ServeEngine
    from repro.runner.traces import cache_len_bound
    spec = TraceSpec("uniform", 6, 8, 4)
    reqs = generate_trace(spec, vocab=1000)
    built = build_arch("gemma-2b")
    bound = cache_len_bound(reqs)
    assert bound == 8 + 4        # tight: max(prompt + max_new), no +8 slack
    out = ServeEngine(built, slots=2, max_len=bound).run(reqs)
    assert out["tokens"] == 6 * 4 and out["decode_steps"] <= 18
    # the last KV write of a request lands at prompt + max_new - 2 (the
    # final emitted token is never written back), so two positions short
    # must raise rather than silently clamp writes
    small = ServeEngine(built, slots=2, max_len=bound - 2)
    with pytest.raises(RuntimeError, match="KV cache exhausted"):
        small.run(generate_trace(spec, vocab=1000))


def test_serve_mode_axis_gets_its_own_engine():
    """jit vs jit_donated share a build_key (neither overrides the config)
    but compile different decode donation — the engine cache must not
    alias them."""
    r = BenchmarkRunner()
    a = r.run(Scenario(**SERVE, mode="jit_donated"), record=False)
    b = r.run(Scenario(**SERVE, mode="jit"), record=False)
    assert a.status == "ok" and b.status == "ok"
    assert r.stats.executable_builds == 2
    assert a.extra["tokens"] == b.extra["tokens"]   # donation is not semantics


def test_serve_hook_slowdown_lands_in_latency_metrics():
    """An injected per-step slowdown must move the recorded per-token
    latencies (what regression.detect compares), like harness.measure."""
    from repro.core.harness import RegressionHook
    r = BenchmarkRunner()
    sc = Scenario(**SERVE, trace="uniform")
    clean = r.run(sc, record=False)
    slow = r.run(sc, hook=RegressionHook(slowdown_s=0.05), record=False)
    assert clean.status == "ok" and slow.status == "ok"
    assert slow.median_us > clean.median_us + 40_000   # >= ~50ms/step visible
    assert slow.extra["tok_lat_p50"] > clean.extra["tok_lat_p50"] + 40_000


def test_serve_sharded_matches_serial(tmp_path):
    """The acceptance invariant: a serve sweep sharded across jobs=2
    persistent workers generates byte-identical tokens to the serial
    in-process run, while recording the full latency metrics."""
    m = ScenarioMatrix(archs=["gemma-2b"], tasks=("serve",), batches=(4,),
                       seqs=(8,), slots=(2, 3), traces=("bursty",))
    serial = BenchmarkRunner()
    serial_rrs = serial.run_matrix(m)
    store = ResultStore(str(tmp_path / "s"))
    sharded = BenchmarkRunner(store=store, jobs=2)
    try:
        shard_rrs = sharded.run_matrix(m)
    finally:
        sharded.close()
    assert [r.name for r in shard_rrs] == [r.name for r in serial_rrs]
    assert len(shard_rrs) == 2
    for ser, shd in zip(serial_rrs, shard_rrs):
        assert ser.status == "ok", ser.error
        assert shd.status == "ok", shd.error
        assert shd.extra["tokens"] == ser.extra["tokens"], ser.name
        assert shd.extra["tokens_digest"] == ser.extra["tokens_digest"]
        assert shd.extra["ttft_p99"] > 0 and shd.extra["tok_per_s"] > 0
    # two slot-widths = two build_key groups = both workers used
    assert {r.extra["shard"] for r in shard_rrs} == {0, 1}
    # every cell landed in the store with its metrics
    assert {r["name"] for r in store.history()} == {r.name for r in shard_rrs}


def test_run_py_list_flag_prints_without_executing(tmp_path):
    """`benchmarks.run --list` prints selected scenario names (post
    filter/exclude) and runs nothing — no store writes, no measurements."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--list", "--fast",
         "--only", "serve_latency", "--exclude", "bursty"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    lines = [ln for ln in r.stdout.splitlines()
             if ln.startswith("serve_latency ")]
    assert lines, r.stdout
    assert all("/serve/" in ln and "uniform" in ln for ln in lines)
    assert not any("bursty" in ln for ln in lines)   # --exclude applied
