"""Optimizer math, gradient compression, data determinism, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property-based tests need the hypothesis package")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.checkpoint import CheckpointManager, latest_step, restore_pytree, save_pytree
from repro.data.pipeline import DataConfig, SyntheticTokenDataset
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.compression import compress_grads, decompress_grads


def test_adamw_first_step_is_signed_lr():
    """After one step with wd=0, |update| == lr (bias-corrected Adam)."""
    params = {"w": jnp.ones((4,)) * 2.0}
    grads = {"w": jnp.array([1.0, -1.0, 2.0, -2.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=1e9)
    st_ = adamw_init(params)
    p2, st2, m = adamw_update(params, grads, st_, cfg)
    upd = np.asarray(params["w"] - p2["w"])
    np.testing.assert_allclose(np.abs(upd), 0.1, rtol=1e-5)
    np.testing.assert_allclose(np.sign(upd), np.sign(np.asarray(grads["w"])))


def test_adamw_grad_clipping():
    params = {"w": jnp.zeros((3,))}
    grads = {"w": jnp.array([30.0, 40.0, 0.0])}   # norm 50
    cfg = AdamWConfig(grad_clip=1.0)
    _, _, m = adamw_update(params, grads, adamw_init(params), cfg)
    assert abs(float(m["grad_norm"]) - 50.0) < 1e-3


@given(scale=st.floats(1e-4, 1e3), seed=st.integers(0, 10_000))
@settings(deadline=None, max_examples=25)
def test_int8_ef_compression_error_is_bounded(scale, seed):
    g = {"w": jax.random.normal(jax.random.key(seed), (300,)) * scale}
    wire, err = compress_grads(g, "int8_ef")
    deq = decompress_grads(wire, "int8_ef", like=g)
    # per-block absmax int8: |error| <= scale_block/2 ~ max/254
    bound = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(deq["w"] - g["w"]))) <= bound + 1e-6
    # error feedback: the residual carried equals the quantization error
    np.testing.assert_allclose(np.asarray(err["w"]), np.asarray(g["w"] - deq["w"]), atol=1e-6)


def test_error_feedback_unbiased_over_steps():
    """Accumulated EF-compressed grads converge to accumulated true grads."""
    key = jax.random.key(0)
    g_true = jax.random.normal(key, (64,)) * 0.01
    err = None
    total = jnp.zeros(64)
    for _ in range(50):
        wire, err = compress_grads({"w": g_true}, "int8_ef", err)
        total = total + decompress_grads(wire, "int8_ef", like={"w": g_true})["w"]
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g_true), atol=1e-4)


def test_data_determinism_and_host_sharding():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8)
    ds1 = SyntheticTokenDataset(cfg)
    ds2 = SyntheticTokenDataset(cfg)
    np.testing.assert_array_equal(ds1.batch_at(7)["tokens"], ds2.batch_at(7)["tokens"])
    # two hosts produce different shards, same shapes
    a = SyntheticTokenDataset(DataConfig(1000, 32, 8, n_hosts=2, host_id=0)).batch_at(3)
    b = SyntheticTokenDataset(DataConfig(1000, 32, 8, n_hosts=2, host_id=1)).batch_at(3)
    assert a["tokens"].shape == (4, 33)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_zipf_distribution_is_skewed():
    ds = SyntheticTokenDataset(DataConfig(vocab=5000, seq_len=256, global_batch=8))
    toks = ds.batch_at(0)["tokens"]
    assert (toks < 50).mean() > 0.2    # head-heavy
    assert toks.max() < 5000 and toks.min() >= 0


def test_checkpoint_roundtrip_and_retention(tmp_path):
    tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3)}, "step": jnp.int32(3),
            "list": [jnp.ones(2), jnp.zeros(3)]}
    d = str(tmp_path)
    save_pytree(tree, d, 10)
    back = restore_pytree(tree, d, 10)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert latest_step(d) == 10

    mgr = CheckpointManager(d, keep=2, async_write=True)
    for s in (20, 30, 40):
        mgr.save(tree, s)
    mgr.wait()
    steps = sorted(int(p.split("_")[1]) for p in os.listdir(d) if p.startswith("step_"))
    assert steps == [30, 40]
    restored, step = mgr.restore_latest(tree)
    assert step == 40 and restored is not None


def test_checkpoint_crash_safety(tmp_path):
    """A leftover .tmp dir must never be picked up as a checkpoint."""
    d = str(tmp_path)
    os.makedirs(os.path.join(d, "step_5.tmp"))
    save_pytree({"w": jnp.ones(3)}, d, 4)
    assert latest_step(d) == 4
