"""Property-based tests (hypothesis) for the chunked-attention invariants and
the sharding resolver — the system's core numeric/distribution invariants."""
import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based tests need the hypothesis package")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.kernels.flash_attention.ref import attention_ref
from repro.models.layers import attention

hypothesis.settings.register_profile("ci", deadline=None, max_examples=20)
hypothesis.settings.load_profile("ci")


@given(
    sq=st.integers(1, 48),
    sk_extra=st.integers(0, 64),
    h=st.sampled_from([1, 2, 4]),
    kv=st.sampled_from([1, 2]),
    d=st.sampled_from([8, 16]),
    chunk=st.sampled_from([7, 16, 33]),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunked_attention_matches_naive(sq, sk_extra, h, kv, d, chunk, seed):
    if h % kv:
        kv = 1
    sk = sq + sk_extra
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (1, sq, h, d))
    k = jax.random.normal(k2, (1, sk, kv, d))
    v = jax.random.normal(k3, (1, sk, kv, d))
    out = attention(q, k, v, mask_type="causal", q_offset=sk - sq, chunk=chunk)
    g = h // kv
    qf = q.transpose(0, 2, 1, 3).reshape(h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(h, sk, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(h, sk, d)
    ref = attention_ref(qf, kf, vf, mask_type="causal", q_offset=sk - sq)
    ref = ref.reshape(1, h, sq, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


@given(
    sq=st.integers(2, 32),
    h=st.sampled_from([1, 2]),
    d=st.sampled_from([8, 16]),
    scale_pow=st.integers(-3, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_softmax_invariants(sq, h, d, scale_pow, seed):
    """Output is a convex combination of V rows: bounded by min/max of v."""
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (1, sq, h, d)) * (2.0 ** scale_pow)
    k = jax.random.normal(k2, (1, sq, h, d)) * (2.0 ** scale_pow)
    v = jax.random.normal(k3, (1, sq, h, d))
    out = attention(q, k, v, mask_type="causal", chunk=8)
    vmin, vmax = float(v.min()), float(v.max())
    assert float(out.min()) >= vmin - 1e-4
    assert float(out.max()) <= vmax + 1e-4


@given(
    shape=st.lists(st.sampled_from([1, 2, 3, 4, 6, 8, 12, 16, 32, 48, 256]),
                   min_size=1, max_size=4),
    seed=st.integers(0, 999),
)
def test_resolve_spec_always_valid(shape, seed):
    """resolve_spec output must always evenly partition the array."""
    import random
    from jax.sharding import Mesh
    from repro.distributed.sharding import LOGICAL_RULES_BASE, resolve_spec
    rnd = random.Random(seed)
    names = list(LOGICAL_RULES_BASE)
    axes = tuple(rnd.choice(names + [None]) for _ in shape)
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    mesh = Mesh(devs, ("data", "model"))
    spec = resolve_spec(axes, shape, mesh, LOGICAL_RULES_BASE)
    for dim, entry in zip(shape, tuple(spec)):
        if entry is None:
            continue
        axes_t = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes_t:
            total *= mesh.shape[a]
        assert dim % total == 0, (shape, axes, spec)
    # no mesh axis used twice
    used = []
    for entry in tuple(spec):
        if entry is None:
            continue
        used += list(entry) if isinstance(entry, tuple) else [entry]
    assert len(used) == len(set(used))
