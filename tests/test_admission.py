"""Batched prefill admission (PR 8): token equivalence against the
per-request baseline across every dispatch mode, the recompile bound
from prompt-length bucketing, the knee-driven ``slots="auto"`` resolver,
and the ``admission`` scenario axis."""
import json
import os

import pytest

from repro.runner import (BenchmarkRunner, Scenario, ScenarioMatrix,
                          TraceSpec, generate_trace)
from repro.runner.loadgen import (AUTO_SLOTS_MAX, CURVE_PATH_ENV,
                                  CURVE_SCHEMA, DEFAULT_SLOTS, auto_slots)

#: a queue-forming loadgen cell: bimodal (mixed-length) prompts, bursty
#: arrivals compressed 8x so several requests queue against several free
#: slots — the regime where batched admission actually batches
LOADGEN = dict(arch="gemma-2b", task="loadgen", batch=8, seq=8, slots=4,
               trace="bursty+bimodal", load=8.0)


# ---- token equivalence across admission policies and dispatch modes -------

def test_admission_policies_and_dispatch_modes_agree_on_tokens(tmp_path):
    """The tentpole invariant, 4 ways: admission="single" (per-request
    baseline), batched serial, batched under jobs=2 sharded dispatch, and
    batched under cluster="local:2" all generate byte-identical tokens on
    a mixed-length trace — batched admission is a pure scheduling change."""
    serial = BenchmarkRunner(runs=1, warmup=0)
    rb = serial.run(Scenario(**LOADGEN), record=False)
    rs = serial.run(Scenario(**LOADGEN, admission="single"), record=False)
    assert rb.status == "ok", rb.error
    assert rs.status == "ok", rs.error
    assert rb.extra["tokens"] == rs.extra["tokens"]
    assert rb.extra["tokens_digest"] == rs.extra["tokens_digest"]
    # the compressed load really formed waves: batched admission made
    # fewer, larger prefill calls than the one-per-request baseline
    assert rb.extra["admit_batch_max"] >= 2
    assert rb.extra["admit_calls"] < rs.extra["admit_calls"]
    assert rs.extra["admit_batch_max"] == 1
    assert rs.extra["admit_calls"] == LOADGEN["batch"]
    # both policies share the arch build (admission is engine protocol,
    # not model config) but get distinct cached engines
    assert Scenario(**LOADGEN).build_key() == \
        Scenario(**LOADGEN, admission="single").build_key()
    assert serial.stats.executable_builds == 2

    matrix = ScenarioMatrix(
        archs=[LOADGEN["arch"]], tasks=("loadgen",),
        batches=(LOADGEN["batch"],), seqs=(LOADGEN["seq"],),
        slots=(LOADGEN["slots"],), traces=(LOADGEN["trace"],),
        loads=(LOADGEN["load"],), admissions=("batched", "single"))
    assert len(matrix) == 2
    by_name = {rb.name: rb.extra["tokens"], rs.name: rs.extra["tokens"]}

    sharded = BenchmarkRunner(runs=1, warmup=0, jobs=2)
    try:
        shard_rrs = sharded.run_matrix(matrix)
    finally:
        sharded.close()
    clustered = BenchmarkRunner(runs=1, warmup=0)
    try:
        cluster_rrs = clustered.run_matrix(matrix, cluster="local:2")
    finally:
        clustered.close()
    for rr in list(shard_rrs) + list(cluster_rrs):
        assert rr.status == "ok", f"{rr.name}: {rr.error}"
        assert rr.extra["tokens"] == by_name[rr.name], rr.name


# ---- recompile bound: buckets, not distinct lengths -----------------------

def test_batched_admission_compiles_per_bucket_not_per_length():
    """Prompt lengths are padded into power-of-two buckets before the
    jitted admission call, so a longtail trace with many distinct lengths
    compiles a handful of (rows, padded_len) shapes — the per-request
    baseline would compile one prefill per distinct exact length."""
    from repro.core.suite import build_arch
    from repro.launch.serve import ADMIT_MIN_BUCKET, ServeEngine
    from repro.runner.traces import cache_len_bound
    spec = TraceSpec("uniform", 16, 24, 2, seed=5,
                     prompt_profile="longtail")
    reqs = generate_trace(spec, vocab=500)
    distinct = {len(r.prompt) for r in reqs}
    assert len(distinct) >= 5          # longtail: many exact lengths
    built = build_arch("gemma-2b")
    eng = ServeEngine(built, slots=4, max_len=cache_len_bound(reqs))
    out = eng.run(reqs)
    shapes = [tuple(s) for s in out["admit_shapes"]]
    assert out["admit_batch_max"] >= 2     # uniform arrivals: full waves
    assert len(shapes) < len(distinct)
    cap = eng.max_len                      # bucket grid is capped there
    for rows, lpad in shapes:
        assert rows & (rows - 1) == 0      # row counts rounded to pow2
        assert lpad == cap or (lpad & (lpad - 1) == 0
                               and lpad >= ADMIT_MIN_BUCKET)
    # single-admission on the same trace compiles one shape per length
    eng_s = ServeEngine(built, slots=4, max_len=cache_len_bound(reqs),
                        admission="single")
    out_s = eng_s.run(reqs)
    assert len(out_s["admit_shapes"]) == len(distinct)
    assert out_s["tokens_by_rid"] == out["tokens_by_rid"]


# ---- the knee-driven slots="auto" resolver --------------------------------

def _write_curve(path, **over):
    data = {"schema": CURVE_SCHEMA, "arch": "gemma-2b", "slots": 4,
            "curves": {"batched": {"knee": {"knee_load": 2.0,
                                            "knee_tok_s": 100.0}}}}
    data.update(over)
    with open(path, "w") as f:
        json.dump(data, f)
    return str(path)


def test_auto_slots_policy_scales_measured_width_to_knee(tmp_path):
    p = tmp_path / "curve.json"
    # knee at 2x offered load: the measured width is oversized — shrink
    # (ceil(4 * 1.25 / 2) = 3)
    assert auto_slots("gemma-2b", _write_curve(p)) == 3
    # knee at native load: keep the width plus headroom (ceil(5) = 5)
    _write_curve(p, curves={"batched": {"knee": {"knee_load": 1.0}}})
    assert auto_slots("gemma-2b", str(p)) == 5
    # knee below native load: the engine saturates early — scale up
    _write_curve(p, curves={"batched": {"knee": {"knee_load": 0.5}}})
    assert auto_slots("gemma-2b", str(p)) == 10
    # clamped to the autoscaler bounds
    _write_curve(p, curves={"batched": {"knee": {"knee_load": 0.01}}})
    assert auto_slots("gemma-2b", str(p)) == AUTO_SLOTS_MAX


def test_auto_slots_falls_back_on_missing_stale_or_foreign_curve(tmp_path):
    missing = str(tmp_path / "nope.json")
    assert auto_slots("gemma-2b", missing) == DEFAULT_SLOTS
    assert auto_slots("gemma-2b", missing, default=7) == 7
    # a pre-PR-8 schema is stale: never trust its layout
    stale = _write_curve(tmp_path / "stale.json", schema=CURVE_SCHEMA - 1)
    assert auto_slots("gemma-2b", stale) == DEFAULT_SLOTS
    # a curve measured for another arch must not shape this matrix
    other = _write_curve(tmp_path / "other.json", arch="mixtral-8x7b")
    assert auto_slots("gemma-2b", other) == DEFAULT_SLOTS
    # unreadable JSON degrades the same way
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert auto_slots("gemma-2b", str(bad)) == DEFAULT_SLOTS


def test_matrix_resolves_auto_slots_at_expansion(tmp_path, monkeypatch):
    curve = _write_curve(tmp_path / "curve.json")   # knee_load=2 -> 3 slots
    monkeypatch.setenv(CURVE_PATH_ENV, curve)
    m = ScenarioMatrix(archs=["gemma-2b"], tasks=("serve",), slots=("auto",))
    [sc] = m.expand()
    assert sc.slots == 3 and "/x3/" in sc.name
    # no usable curve for this arch -> the default width
    m2 = ScenarioMatrix(archs=["mixtral-8x7b"], tasks=("serve",),
                        slots=("auto",))
    assert m2.expand()[0].slots == DEFAULT_SLOTS
    # "auto" is a matrix-only value: a bare Scenario must reject it
    with pytest.raises(ValueError, match="auto"):
        Scenario(arch="gemma-2b", task="serve", slots="auto")


# ---- the admission scenario axis ------------------------------------------

def test_admission_axis_normalization_and_validation():
    sc = Scenario(arch="gemma-2b", task="serve")
    assert sc.admission == "batched"          # the default policy
    assert sc.name.endswith("/uniform")       # default stays out of names
    single = Scenario(arch="gemma-2b", task="serve", admission="single")
    assert single.name.endswith("/adm-single")
    assert Scenario.from_dict(single.to_dict()) == single
    with pytest.raises(ValueError, match="admission"):
        Scenario(arch="gemma-2b", task="serve", admission="wavefront")
    with pytest.raises(ValueError, match="serve/loadgen-only"):
        Scenario(arch="gemma-2b", task="train", admission="single")


def test_matrix_admissions_axis_multiplies_serve_cells_only():
    m = ScenarioMatrix(archs=["gemma-2b"], tasks=("train", "serve"),
                       admissions=("batched", "single"))
    scs = m.expand()
    assert len([s for s in scs if s.task == "train"]) == 1
    serve = [s for s in scs if s.task == "serve"]
    assert {s.admission for s in serve} == {"batched", "single"}
    assert len(serve) == 2
