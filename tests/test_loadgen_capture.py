"""PR 7's production-shaped serving stack: mixed prompt lengths through
per-slot KV positions, live trace capture -> byte-identical replay, and
``task="loadgen"`` offered-load sweeps — serial, sharded, and clustered
runs must all agree token-for-token."""
import dataclasses
import json

import pytest

from repro.runner import (BenchmarkRunner, ResultStore, Scenario,
                          ScenarioMatrix, TraceSpec, generate_trace,
                          save_spec)
from repro.runner.loadgen import (find_knee, parse_split, scale_arrivals,
                                  shard_requests)
from repro.runner.traces import capture_spec, load_spec, split_trace

#: the mixed-prompt-length serve cell reused across tests: 4 requests
#: spanning 2 distinct prompt lengths in one continuous-batching replay
MIXED = dict(arch="gemma-2b", task="serve", batch=4, seq=8, slots=2,
             trace="bursty+bimodal")


# ---- trace layer: prompt-length profiles + capture ------------------------

def test_prompt_profiles_mix_lengths_deterministically():
    spec = TraceSpec("bursty", 8, 8, 4, seed=0, prompt_profile="bimodal")
    a, b = generate_trace(spec, vocab=100), generate_trace(spec, vocab=100)
    assert [r.prompt.tolist() for r in a] == [r.prompt.tolist() for r in b]
    lens = {len(r.prompt) for r in a}
    assert lens == {4, 16}                       # P//2 and 2P, both drawn
    lt = generate_trace(TraceSpec("uniform", 16, 8, 4, seed=1,
                                  prompt_profile="longtail"), vocab=100)
    assert all(4 <= len(r.prompt) <= 32 for r in lt)   # clipped to [P//2, 4P]
    assert len({len(r.prompt) for r in lt}) > 1
    # the length profile never shifts prompt content for a given length
    # layout: fixed spec and an explicit pin of the same lengths agree
    pinned = TraceSpec("bursty", 8, 8, 4, seed=0,
                       prompt_lens=tuple(len(r.prompt)
                                         for r in sorted(a, key=lambda r: r.rid)))
    c = generate_trace(pinned, vocab=100)
    assert [r.prompt.tolist() for r in sorted(c, key=lambda r: r.rid)] == \
        [r.prompt.tolist() for r in sorted(a, key=lambda r: r.rid)]


def test_split_trace_axis_syntax():
    assert split_trace("bursty") == ("bursty", "fixed")
    assert split_trace("bursty+bimodal") == ("bursty", "bimodal")
    with pytest.raises(ValueError):
        Scenario(arch="a", task="serve", trace="bursty+flashcrowd")
    with pytest.raises(ValueError):
        Scenario(arch="a", task="serve", trace="flashcrowd+bimodal")


def test_capture_spec_roundtrips_through_save_load(tmp_path):
    spec = TraceSpec("mixed", 6, 8, 4, seed=3, prompt_profile="uniform")
    reqs = generate_trace(spec, vocab=100)
    cap = capture_spec(reqs, seed=3, source="test")
    assert cap.prompt_lens and cap.arrivals and cap.budgets
    # the captured spec regenerates the exact prompts without storing them
    replay = generate_trace(cap, vocab=100)
    assert [(r.rid, r.arrival_step, r.max_new, r.prompt.tolist())
            for r in replay] == \
        [(r.rid, r.arrival_step, r.max_new, r.prompt.tolist()) for r in reqs]
    path = str(tmp_path / "cap.json")
    save_spec(cap, path)
    assert load_spec(path) == cap
    # pre-capture files (no optional fields) still load
    with open(path) as f:
        d = json.load(f)
    for k in ("prompt_profile", "prompt_lens", "arrivals", "budgets",
              "source"):
        d.pop(k, None)
    bare = str(tmp_path / "bare.json")
    with open(bare, "w") as f:
        json.dump(d, f)
    assert load_spec(bare).profile == cap.profile


# ---- loadgen helpers ------------------------------------------------------

def test_parse_split_and_shard_partition():
    assert parse_split("0/2") == (0, 2)
    for bad in ("2/2", "1", "a/b", "-1/2"):
        with pytest.raises(ValueError):
            parse_split(bad)
    reqs = generate_trace(TraceSpec("bursty", 9, 8, 4, seed=1), vocab=50)
    shards = [shard_requests(list(reqs), f"{i}/3") for i in range(3)]
    rids = sorted(r.rid for s in shards for r in s)
    assert rids == sorted(r.rid for r in reqs)            # exact partition
    assert shard_requests(reqs, "") is reqs               # no-op


def test_scale_arrivals_compresses_the_clock():
    reqs = generate_trace(TraceSpec("bursty", 8, 8, 4, seed=1), vocab=50)
    orig = [r.arrival_step for r in reqs]
    assert any(a > 0 for a in orig)
    scaled = scale_arrivals(reqs, 2.0)
    assert [r.arrival_step for r in scaled] == [a // 2 for a in orig]
    with pytest.raises(ValueError):
        scale_arrivals(reqs, 0.0)


def test_find_knee_marks_saturation():
    pts = [{"load": 0.5, "tok_per_s": 100.0},
           {"load": 1.0, "tok_per_s": 200.0},
           {"load": 2.0, "tok_per_s": 390.0},
           {"load": 4.0, "tok_per_s": 400.0},   # +2.6%: saturated
           {"load": 8.0, "tok_per_s": 395.0}]
    knee = find_knee(pts)
    assert knee == {"knee_load": 2.0, "knee_tok_s": 390.0}
    assert find_knee([])["knee_load"] == 0.0
    assert find_knee(pts[:1])["knee_load"] == 0.5


# ---- scenario layer -------------------------------------------------------

def test_loadgen_scenario_axes_and_validation():
    sc = Scenario(arch="gemma-2b", task="loadgen", batch=4, seq=8, slots=2,
                  trace="bursty+bimodal", load=2.0, split="1/2")
    assert sc.name == ("gemma-2b/loadgen/b4/s8/fp32/jit_donated"
                       "/x2/bursty+bimodal/L2/1of2")
    # loadgen shares the serve engine group
    assert sc.build_key() == Scenario(**MIXED).build_key()
    assert Scenario.from_dict(sc.to_dict()) == sc
    bare = Scenario(arch="gemma-2b", task="loadgen")
    assert bare.load == 1.0 and bare.slots == 4
    with pytest.raises(ValueError):
        Scenario(arch="a", task="loadgen", load=-1.0)
    with pytest.raises(ValueError):
        Scenario(arch="a", task="loadgen", split="2of4")
    with pytest.raises(ValueError):
        Scenario(arch="a", task="serve", load=2.0)      # loadgen-only axis
    with pytest.raises(ValueError):
        Scenario(arch="a", task="train", split="0/2")


def test_matrix_expands_load_and_split_axes_for_loadgen_only():
    m = ScenarioMatrix(archs=["a1"], tasks=("serve", "loadgen"),
                       batches=(4,), seqs=(8,), slots=(2,),
                       traces=("bursty+bimodal",), loads=(1.0, 2.0),
                       splits=("0/2", "1/2"))
    serve = [s for s in m if s.task == "serve"]
    loadgen = [s for s in m if s.task == "loadgen"]
    assert len(serve) == 1                    # loads/splits stay inert
    assert len(loadgen) == 4
    assert {(s.load, s.split) for s in loadgen} == \
        {(1.0, "0/2"), (1.0, "1/2"), (2.0, "0/2"), (2.0, "1/2")}


# ---- execution ------------------------------------------------------------

def test_mixed_prompt_serve_records_capture_and_length_percentiles():
    r = BenchmarkRunner()
    rr = r.run(Scenario(**MIXED), record=False)
    assert rr.status == "ok", rr.error
    cap = rr.extra["capture"]
    assert len(set(cap["prompt_lens"])) >= 2   # the mixed-length invariant
    assert cap["source"].startswith("capture:gemma-2b/serve/")
    assert rr.extra["prompt_len_p50"] > 0
    assert rr.extra["prompt_len_p95"] >= rr.extra["prompt_len_p50"]


def test_loadgen_cell_tokens_invariant_under_offered_load():
    """Per-slot positions make each request's tokens a function of its own
    prompt alone — so scaling the arrival clock (which reshuffles slot
    assignment and co-residency) must not move a single token."""
    r = BenchmarkRunner()
    base = r.run(Scenario(**MIXED), record=False)
    for load in (0.5, 4.0):
        rr = r.run(Scenario(**{**MIXED, "task": "loadgen"}, load=load),
                   record=False)
        assert rr.status == "ok", rr.error
        assert rr.extra["offered_load"] == load
        assert rr.extra["tokens"] == base.extra["tokens"], load


def test_loadgen_shards_union_to_the_whole_trace():
    r = BenchmarkRunner()
    whole = r.run(Scenario(**MIXED), record=False)
    toks = []
    for i in range(2):
        rr = r.run(Scenario(**{**MIXED, "task": "loadgen"}, split=f"{i}/2"),
                   record=False)
        assert rr.status == "ok", rr.error
        assert rr.extra["split"] == f"{i}/2" and rr.runs == 2
        toks.extend(rr.extra["tokens"])
    # shard 0 takes rids {0, 2}, shard 1 {1, 3} -> interleave back
    merged = [toks[0], toks[2], toks[1], toks[3]]
    assert merged == whole.extra["tokens"]


def test_capture_replay_matches_serial_sharded_and_clustered(tmp_path):
    """The acceptance invariant, end-to-end: a live mixed-prompt run's
    captured TraceSpec, replayed via trace="file:..." through run_matrix,
    reproduces the original tokens byte-for-byte — serially, across
    --jobs 2 pool workers, and across cluster="local:2" socket workers."""
    r = BenchmarkRunner()
    live = r.run(Scenario(**MIXED), record=False)
    assert live.status == "ok", live.error
    path = str(tmp_path / "cap.json")
    save_spec(TraceSpec(**live.extra["capture"]), path)
    matrix = ScenarioMatrix(archs=["gemma-2b"], tasks=("serve",),
                            batches=(4,), seqs=(8,), slots=(2, 3),
                            traces=(f"file:{path}",))
    digests = {}
    serial_rrs = r.run_matrix(matrix, runs=1)
    for mode, kw in (("jobs2", dict(jobs=2)), ("cluster", dict())):
        runner = BenchmarkRunner(store=ResultStore(str(tmp_path / mode)), **kw)
        try:
            rrs = (runner.run_matrix(matrix, cluster="local:2")
                   if mode == "cluster" else runner.run_matrix(matrix))
        finally:
            runner.close()
        digests[mode] = [rr.extra["tokens_digest"] for rr in rrs]
        for rr in rrs:
            assert rr.status == "ok", (mode, rr.error)
    serial = [rr.extra["tokens_digest"] for rr in serial_rrs]
    assert digests["jobs2"] == serial
    assert digests["cluster"] == serial
    # and the replay IS the live run, token for token (both slot widths:
    # co-residency does not leak into outputs)
    for d in serial:
        assert d == live.extra["tokens_digest"]


def test_co_resident_requests_do_not_perturb_each_other(tmp_path):
    """Same slot count, different co-residency: staggering arrivals so
    each request decodes alone must not move a single token relative to
    the all-at-once run where mixed-length requests share decode batches
    (the old lockstep engine failed exactly this — refilled rows attended
    zeroed keys and wrong RoPE offsets)."""
    together = TraceSpec("uniform", 4, 8, 4, seed=0,
                         prompt_profile="bimodal")
    # budgets are 4 and the longest prompt is 16: 30-step gaps guarantee
    # each request finishes before the next arrives
    alone = dataclasses.replace(together, arrivals=(0, 30, 60, 90))
    r = BenchmarkRunner()
    rrs = {}
    for name, spec in (("together", together), ("alone", alone)):
        path = str(tmp_path / f"{name}.json")
        save_spec(spec, path)
        rr = r.run(Scenario(**{**MIXED, "trace": f"file:{path}"}),
                   record=False)
        assert rr.status == "ok", (name, rr.error)
        rrs[name] = rr
    # same seed + same length layout -> same prompts; only co-residency
    # differs, so per-request tokens must agree exactly
    assert rrs["alone"].extra["tokens"] == rrs["together"].extra["tokens"]
    assert rrs["alone"].extra["tokens_digest"] == \
        rrs["together"].extra["tokens_digest"]


# ---- tuning backend provenance --------------------------------------------

def test_tuning_db_ignores_mismatched_backend(tmp_path, monkeypatch):
    from repro.tuning import db as tdb
    monkeypatch.setenv("REPRO_TUNING_DB", str(tmp_path / "db.json"))
    tdb.invalidate_cache()
    here = tdb._current_backend()
    assert here                                  # jax is importable in tests
    db = tdb.TuningDB.load()
    db.record("flash_attention", "Sq8,Sk8,D4", "fp32",
              params={"block_q": 8}, median_us=1.0, backend=here)
    db.record("rglru", "S8,D4", "fp32",
              params={"block_s": 8}, median_us=1.0,
              backend="tpu" if here != "tpu" else "cpu")
    db.record("ssd", "S8,P4,N4", "fp32",
              params={"block_s": 8}, median_us=1.0)   # no provenance
    db.save()
    # matching backend serves; mismatched is ignored; unstamped serves
    assert tdb.tuned_params("flash_attention", "Sq8,Sk8,D4", "fp32") == \
        {"block_q": 8}
    assert tdb.tuned_params("rglru", "S8,D4", "fp32") is None
    assert tdb.tuned_params("ssd", "S8,P4,N4", "fp32") == {"block_s": 8}
    tdb.invalidate_cache()
