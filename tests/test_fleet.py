"""The fleet perf-CI service: metrics registry, tick scheduler, drift
triage with re-measure + bisect, and supervised crash recovery.

The module-wide registry (``repro.fleet.metrics.registry()``) is
process-global and fed by every runner execution in this test session,
so instrumentation assertions here always compare before/after deltas
with ``>=`` — never absolute counts.
"""
import json
import os
import re

import pytest

from repro.core.harness import RegressionHook
from repro.core.regression import Commit, MetricStore
from repro.fleet.metrics import (METRICS_SCHEMA_KEY, METRICS_SCHEMA_VERSION,
                                 MetricsRegistry, registry, set_enabled)
from repro.fleet.scheduler import FleetConfig, FleetScheduler, VirtualClock
from repro.fleet.service import FLEET_STATUS_SCHEMA_KEY, FleetService
from repro.fleet.triage import triage
from repro.runner import BenchmarkRunner, Scenario
from repro.runner.protocol import stats_delta

ARCH, SEQ = "gemma-2b", 8


@pytest.fixture(scope="module")
def runner():
    r = BenchmarkRunner(runs=1, warmup=0)
    yield r
    r.close()


@pytest.fixture(scope="module")
def cell():
    return Scenario(arch=ARCH, task="train", batch=1, seq=SEQ)


def _counters():
    return registry().snapshot()["counters"]


# ---- registry unit behavior (fresh instances, no jax) ----------------------

def test_snapshot_schema_and_instruments():
    reg = MetricsRegistry()
    reg.inc("fleet_cells_total")
    reg.inc("fleet_cells_total", 2)
    reg.inc("fleet_cells_total", -5)          # negative deltas ignored
    reg.set_gauge("pool_queue_depth", 3)
    reg.observe("fleet_measure_seconds", 0.5)
    snap = reg.snapshot()
    assert snap[METRICS_SCHEMA_KEY] == METRICS_SCHEMA_VERSION
    assert snap["ts"] > 0
    assert snap["counters"]["fleet_cells_total"] == 3
    assert snap["gauges"]["pool_queue_depth"] == 3.0
    hist = snap["histograms"]["fleet_measure_seconds"]
    assert hist["count"] == 1 and hist["sum"] == 0.5 and hist["max"] == 0.5


def test_histogram_quantiles():
    reg = MetricsRegistry()
    for v in range(1, 101):
        reg.observe("h", float(v))
    h = reg.snapshot()["histograms"]["h"]
    assert h["count"] == 100 and h["sum"] == 5050.0
    assert h["p50"] == 50.0 and h["p95"] == 95.0 and h["max"] == 100.0


def test_disabled_registry_is_a_noop():
    reg = MetricsRegistry(enabled=False)
    reg.inc("c")
    reg.set_gauge("g", 1)
    reg.observe("h", 1.0)

    class FakeRR:
        status, cache, compile_us, runs, median_us = "ok", {}, 0.0, 1, 5.0
    reg.record_result(FakeRR())
    snap = reg.snapshot()
    assert not snap["counters"] and not snap["gauges"] and not snap["histograms"]


def test_wire_round_trip_delta_merge():
    """Worker-side cumulative snapshots delta-merge into a parent registry
    with the stats_delta arithmetic: counters add exactly, histograms ship
    count/sum, and a second snapshot only ships the increment."""
    worker, parent, seen = MetricsRegistry(), MetricsRegistry(), {}
    worker.inc("fleet_cells_total", 2)
    worker.observe("fleet_measure_seconds", 1.0)
    worker.set_gauge("pool_queue_depth", 7)   # gauges never cross the wire
    parent.merge_cumulative(stats_delta(worker.counters_cumulative(), seen))
    worker.inc("fleet_cells_total")
    worker.observe("fleet_measure_seconds", 3.0)
    parent.merge_cumulative(stats_delta(worker.counters_cumulative(), seen))
    snap = parent.snapshot()
    assert snap["counters"]["fleet_cells_total"] == 3
    h = snap["histograms"]["fleet_measure_seconds"]
    assert h["count"] == 2 and h["sum"] == 4.0
    assert "pool_queue_depth" not in snap["gauges"]
    # a worker respawn resets seen: the fresh process's counters must not
    # be double-subtracted (delta of a fresh cumulative vs empty seen)
    respawned, seen2 = MetricsRegistry(), {}
    respawned.inc("fleet_cells_total")
    parent.merge_cumulative(stats_delta(respawned.counters_cumulative(), seen2))
    assert parent.snapshot()["counters"]["fleet_cells_total"] == 4


def test_prometheus_exposition_parses():
    reg = MetricsRegistry()
    reg.inc("fleet_cells_total", 4)
    reg.set_gauge("serve_kv_occupancy", 0.25)
    reg.observe("fleet_compile_seconds", 1.5)
    reg.set_gauge("cluster_inflight_local0:weird name", 1)  # needs sanitizing
    text = reg.to_prometheus()
    sample = re.compile(r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? '
                        r'[-+0-9.eE]+$')
    lines = [ln for ln in text.splitlines() if ln and not ln.startswith("#")]
    assert lines
    for ln in lines:
        assert sample.match(ln), ln
    assert "fleet_cells_total 4" in text
    assert 'fleet_compile_seconds{quantile="0.5"} 1.5' in text
    assert "fleet_compile_seconds_count 1" in text


# ---- runner instrumentation ------------------------------------------------

def test_runner_records_executions(runner, cell):
    before = _counters()
    rr = runner.run(cell, record=False)
    assert rr.status == "ok", rr.error
    after = _counters()
    assert after.get("fleet_cells_total", 0) >= before.get(
        "fleet_cells_total", 0) + 1
    cache_events = (after.get("fleet_exec_cache_hits_total", 0)
                    + after.get("fleet_exec_cache_misses_total", 0))
    cache_before = (before.get("fleet_exec_cache_hits_total", 0)
                    + before.get("fleet_exec_cache_misses_total", 0))
    assert cache_events >= cache_before + 1


def test_coverage_extras(cell):
    r = BenchmarkRunner(runs=1, warmup=0, coverage=True)
    rr = r.run(cell, record=False)
    assert rr.status == "ok", rr.error
    assert rr.extra["cov_primitives"] > 0
    # a fresh runner's first cell IS the union frontier
    assert rr.extra["cov_new_primitives"] == rr.extra["cov_primitives"]
    gauge = registry().snapshot()["gauges"].get("fleet_cov_union_primitives", 0)
    assert gauge >= rr.extra["cov_primitives"]
    # the same scenario again adds nothing new (cached trace, same union)
    rr2 = r.run(cell, record=False)
    assert rr2.extra["cov_new_primitives"] == 0
    assert rr2.extra["cov_primitives"] == rr.extra["cov_primitives"]


# ---- scheduler + triage ----------------------------------------------------

def _fleet_cfg(tmp_path, **over):
    kw = dict(archs=(ARCH,), tasks=("train",), batches=(1,), seqs=(SEQ,),
              runs=1, drain_stride=0,
              queue_path=str(tmp_path / "queue.json"))
    kw.update(over)
    return FleetConfig(**kw)


def test_scheduler_ticks_and_drift(tmp_path, runner):
    store = MetricStore(str(tmp_path / "store.json"))
    hooks_for_tick = (lambda tick:
                      {f"{ARCH}/train": RegressionHook(slowdown_s=0.05)}
                      if tick >= 1 else None)
    sched = FleetScheduler(_fleet_cfg(tmp_path), store, runner,
                           clock=VirtualClock(),
                           hooks_for_tick=hooks_for_tick)
    before = _counters()
    t0 = sched.tick(0)
    assert len(t0.results) == 1 and t0.results[0].status == "ok"
    assert not [f for f in t0.drift["findings"]
                if f["rule"] == "perf_drift"]
    t1 = sched.tick(1)
    drifted = [f for f in t1.drift["findings"] if f["rule"] == "perf_drift"]
    assert drifted, t1.drift["findings"]
    assert drifted[0]["cell"] == t1.results[0].name
    assert float(drifted[0]["evidence"]["baseline"]) > 0
    after = _counters()
    assert after.get("fleet_ticks_total", 0) >= before.get(
        "fleet_ticks_total", 0) + 2
    # each tick logged exactly one provenance point, stamped with its tick
    points = [rec for rec in store._store.history()
              if rec.get("name") == t1.results[0].name]
    assert [p["extra"]["fleet_tick"] for p in points] == [0, 1]


def test_triage_confirm_refute_unverified_bisect(tmp_path, runner, cell):
    scenarios = {cell.name: cell}

    def commits_for(fd, sc):
        def mk(bad):
            return lambda name: {"median_us": 1e6 if bad else 1.0}
        return [Commit(f"c{i}", i, mk(i >= 5)) for i in range(8)]

    drift = {"findings": [
        {"rule": "perf_drift", "cell": cell.name, "severity": "crit",
         "score": 5.0, "evidence": {"metric": "median_us", "baseline": 1.0}},
        {"rule": "perf_drift", "cell": cell.name, "severity": "warn",
         "score": 1.0, "evidence": {"metric": "median_us", "baseline": 1e12}},
        {"rule": "perf_drift", "cell": "no/such/cell", "severity": "warn",
         "score": 1.0, "evidence": {"metric": "median_us", "baseline": 10.0}},
        {"rule": "low_util", "cell": cell.name},   # not a drift rule: skipped
    ]}
    report = triage(drift, runner=runner, scenarios=scenarios,
                    commits_for=commits_for, meta={"tick": 7})
    rules = [f["rule"] for f in report["findings"]]
    assert rules.count("regression_confirmed") == 1
    assert rules.count("regression_bisected") == 1
    assert rules.count("drift_refuted") == 1
    assert rules.count("drift_unverified") == 1
    bisected = next(f for f in report["findings"]
                    if f["rule"] == "regression_bisected")
    assert bisected["evidence"]["culprit"] == "c5"
    assert bisected["evidence"]["measurements"] < len(commits_for(None, None))
    # ranked crit-first; meta folds the caller's context in
    assert report["findings"][0]["severity"] == "crit"
    assert report["meta"]["kind"] == "fleet_triage"
    assert report["meta"]["tick"] == 7
    assert report["meta"]["confirmed"] == 1 and report["meta"]["refuted"] == 1
    assert registry().snapshot()["gauges"]["fleet_open_findings"] == 2


def test_scheduler_drains_tuning_queue(tmp_path, runner, monkeypatch):
    monkeypatch.setenv("REPRO_TUNING_DB", str(tmp_path / "db.json"))
    from repro.tuning import enqueue_jobs, make_case
    case = make_case("flash_attention", B=1, S=32, H=2, K=2, D=32)
    queue_path = tmp_path / "queue.json"
    enqueue_jobs([{"kernel": case.kernel, "case": case.case_id,
                   "signature": case.signature, "dtype": case.dtype}],
                 queue_path)
    store = MetricStore(str(tmp_path / "store.json"))
    sched = FleetScheduler(
        _fleet_cfg(tmp_path, drain_stride=1, drain_max_candidates=1),
        store, runner, clock=VirtualClock())
    before = _counters()
    tres = sched.tick(0)
    assert tres.drained_cases == 1
    after = _counters()
    assert after.get("fleet_drained_jobs_total", 0) >= before.get(
        "fleet_drained_jobs_total", 0) + 1
    queue = json.loads(queue_path.read_text())
    assert queue["jobs"] == []


# ---- supervisor backoff + supervised crash recovery ------------------------

def test_supervisor_backoff_schedule(tmp_path):
    from repro.fleet.service import _TickCheckpoint
    from repro.runtime.supervisor import Supervisor
    delays = []
    boom = {"left": 3}

    def step(state, i):
        if i == 1 and boom["left"]:
            boom["left"] -= 1
            raise RuntimeError("flaky step")
        return {"n": state["n"] + 1}

    sup = Supervisor(_TickCheckpoint(str(tmp_path / "ck.json")), save_every=1,
                     max_restarts=5, backoff_s=0.5, sleep=delays.append)
    state, steps = sup.run({"n": 0}, step, 3)
    assert steps == 3 and state["n"] == 3 and sup.restarts == 3
    assert delays == [0.5, 1.0, 2.0]          # exponential, base 0.5
    assert any(e.startswith("backoff@1:") for e in sup.events)

    # backoff_s=0 (the default everywhere else) never sleeps
    delays2 = []
    boom["left"] = 1
    sup2 = Supervisor(_TickCheckpoint(str(tmp_path / "ck2.json")),
                      save_every=1, max_restarts=5, sleep=delays2.append)
    sup2.run({"n": 0}, step, 3)
    assert delays2 == []


def test_service_crash_recovery_no_lost_history(tmp_path):
    """A tick that raises mid-run restarts under the supervisor with
    backoff; completed ticks' history points survive, the replayed tick
    logs its own, and the pool workers all die with close()."""
    fault = {"armed": True}

    def hooks_for_tick(tick):
        # fail the first consult of tick 1 (the sweep's), once — the
        # supervisor must replay the tick and the retry consults again
        if tick == 1 and fault["armed"]:
            fault["armed"] = False
            raise RuntimeError("injected tick fault")
        return None

    store = MetricStore(str(tmp_path / "store.json"))
    runner = BenchmarkRunner(runs=1, warmup=0, jobs=2)
    delays = []
    service = FleetService(
        _fleet_cfg(tmp_path), store=store, runner=runner,
        results_dir=str(tmp_path), clock=VirtualClock(),
        hooks_for_tick=hooks_for_tick, backoff_s=0.25, sleep=delays.append)
    try:
        summary = service.run(2)
        pids = runner.worker_pids()
    finally:
        runner.close()

    assert summary["ticks"] == 2 and summary["restarts"] == 1
    assert delays == [0.25]
    assert any(e.startswith("backoff@1:") for e in summary["events"])
    # tick 0's point survived the tick-1 crash; the replay logged tick 1
    cell_name = next(iter(service.scheduler.scenarios))
    ticks_logged = [rec["extra"]["fleet_tick"]
                    for rec in store._store.history()
                    if rec.get("name") == cell_name]
    assert ticks_logged == [0, 1]
    # heartbeat is fresh and consistent with the supervised outcome
    with open(summary["status_path"]) as f:
        status = json.load(f)
    assert status[FLEET_STATUS_SCHEMA_KEY] == 1
    assert status["ticks_done"] == 2 and status["restarts"] == 1
    assert len(status["ticks"]) == 2
    # no orphan shard workers after close()
    assert pids
    for pid in pids:
        with pytest.raises(ProcessLookupError):
            os.kill(pid, 0)


def test_service_metrics_disabled_toggle_restores():
    prev = set_enabled(False)
    try:
        assert set_enabled(True) is False
    finally:
        set_enabled(True)
        assert registry().enabled
