"""Integration: the multi-pod dry-run path end-to-end, in a subprocess (so
this test process keeps its single CPU device).  One representative cell per
mesh — the full 40-cell sweep is scripts/sweep_dryrun.py."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=900):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run([sys.executable, "-m", "repro.launch.dryrun", *args],
                          env=env, cwd=REPO, capture_output=True, text=True,
                          timeout=timeout)


@pytest.mark.parametrize("extra", [[], ["--multi-pod"]], ids=["16x16", "2x16x16"])
def test_dryrun_cell_compiles(tmp_path, extra):
    out = str(tmp_path / "cell.json")
    r = _run(["--arch", "gemma-2b", "--shape", "decode_32k", "--json", out] + extra)
    assert r.returncode == 0, r.stderr[-2000:]
    cell = json.load(open(out))[0]
    rl = cell["roofline"]
    assert rl["chips"] == (512 if extra else 256)
    assert rl["flops_global"] > 0 and rl["collective_bytes_global"] > 0
    assert cell["memory"]["temp_bytes"] > 0
    assert cell["cost_source"] == "post_spmd_partitioning"
    # decode at 32k with a 128-seq batch must be memory-bound
    assert rl["dominant"] in ("memory", "collective")
