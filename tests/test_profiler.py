"""The measured profiling subsystem: phase timelines, op-class
attribution (fractions sum to 1), detector rules on synthetic profiles,
report ranking, and runner integration (serial, sharded --jobs 2, serve,
overhead bound)."""
import json

import pytest

from repro.core.hloanalysis import HloCost, analyze_hlo, op_class
from repro.profiler import (Thresholds, Timeline, attribute, build_report,
                            detect, format_table)
from repro.profiler.timeline import PhaseSample
from repro.runner import BenchmarkRunner, Scenario, ScenarioMatrix

PROF_FRACS = ("prof_frac_compute", "prof_frac_memory",
              "prof_frac_collective", "prof_frac_dispatch", "prof_frac_idle")


def _frac_sum(rr):
    return sum(rr.extra[k] for k in PROF_FRACS)


# ---- op classes -----------------------------------------------------------

def test_op_class_mapping():
    assert op_class("dot") == "matmul"
    assert op_class("convolution") == "matmul"
    assert op_class("all-reduce") == "collective"
    assert op_class("all-gather-start") == "collective"
    assert op_class("add") == "elementwise"
    assert op_class("reduce") == "other"
    assert op_class("custom-call", 'custom_call_target="flash_attention"') == "attention"
    assert op_class("custom-call", 'custom_call_target="topk"') == "other"


def test_hlo_class_tallies_sum_to_totals():
    hlo = """
HloModule m

ENTRY %main (x: f32[64,64], y: f32[64,64]) -> f32[64,64] {
  %x = f32[64,64] parameter(0)
  %y = f32[64,64] parameter(1)
  %d = f32[64,64] dot(%x, %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %a = f32[64,64] add(%d, %x)
}
"""
    c = analyze_hlo(hlo)
    assert c.flops > 0 and c.bytes_accessed > 0
    assert abs(sum(c.flops_by_class.values()) - c.flops) < 1e-6
    assert abs(sum(c.bytes_by_class.values()) - c.bytes_accessed) < 1e-6
    assert c.flops_by_class["matmul"] == 2.0 * 64 * 64 * 64


# ---- attribution ----------------------------------------------------------

def _timeline(dispatch=100.0, device=900.0, n=3, idle=0.0):
    return Timeline(kind="step",
                    samples=[PhaseSample(dispatch, device)] * n,
                    idle_us=idle)


def test_attribute_fractions_sum_and_split():
    cost = HloCost()
    cost.tally_flops("matmul", 1e12)       # strongly compute-bound class
    cost.tally_bytes("matmul", 1e6)
    cost.tally_flops("elementwise", 1e3)   # strongly memory-bound class
    cost.tally_bytes("elementwise", 1e9)
    att = attribute(_timeline(), cost)
    assert abs(sum(att.fractions().values()) - 1.0) < 1e-9
    assert abs(att.frac_dispatch - 0.1) < 1e-9
    assert abs(sum(att.class_frac.values()) - 1.0) < 1e-9
    # both classes carry device time, and the split respects boundedness:
    # matmul's share is mostly compute, elementwise's mostly memory
    assert att.class_us["matmul"] > 0 and att.class_us["elementwise"] > 0
    assert att.frac_compute > 0 and att.frac_memory > 0
    assert att.frac_idle == 0.0


def test_attribute_empty_cost_lands_in_idle():
    att = attribute(_timeline(), HloCost())
    assert abs(sum(att.fractions().values()) - 1.0) < 1e-9
    assert abs(att.frac_idle - 0.9) < 1e-9      # all device time unexplained
    assert att.frac_compute == att.frac_memory == 0.0
    assert att.util == 0.0


def test_attribute_serve_idle_share():
    # serve: 10 decode steps of 1ms + 10ms outside them (prefill/queue)
    tl = Timeline.from_phase_log([(1e-4, 9e-4)] * 10, kind="decode_step",
                                 wall_s=0.02)
    assert abs(tl.idle_us - 1e4) < 1e-6
    cost = HloCost()
    cost.tally_flops("matmul", 1e9)
    cost.tally_bytes("matmul", 1e6)
    att = attribute(tl, cost)
    assert abs(sum(att.fractions().values()) - 1.0) < 1e-9
    assert abs(att.frac_idle - 0.5) < 1e-9
    assert abs(att.frac_dispatch - 0.05) < 1e-9


# ---- detectors on synthetic profiles --------------------------------------

def _rec(name, task="train", status="ok", compile_us=0.0, wall_s=1.0, **extra):
    return {"name": name, "task": task, "status": status,
            "compile_us": compile_us, "wall_s": wall_s, "extra": extra}


def _prof(mem=0.2, comp=0.6, disp=0.1, util=1e-3, **kw):
    return dict(prof_frac_memory=mem, prof_frac_compute=comp,
                prof_frac_collective=0.0, prof_frac_dispatch=disp,
                prof_frac_idle=max(0.0, 1.0 - mem - comp - disp),
                prof_util=util, **kw)


def test_detector_data_movement_fires_and_stays_silent():
    hot = _rec("a/train/x", **_prof(mem=0.8, comp=0.1))
    cold = _rec("b/train/x", **_prof(mem=0.3, comp=0.6))
    rules = [f.rule for f in detect([hot, cold])]
    hits = [f for f in detect([hot, cold]) if f.rule == "data_movement_bound"]
    assert [f.cell for f in hits] == ["a/train/x"]
    assert hits[0].severity == "crit"        # > 0.75
    assert "data_movement_bound" in rules


def test_detector_dispatch_bound():
    hot = _rec("a/x", **_prof(mem=0.2, comp=0.2, disp=0.5))
    cold = _rec("b/x", **_prof(disp=0.1))
    hits = [f for f in detect([hot, cold]) if f.rule == "dispatch_bound"]
    assert [f.cell for f in hits] == ["a/x"]


def test_detector_low_util_is_relative_to_sweep():
    recs = [_rec(f"c{i}/x", **_prof(util=1e-3)) for i in range(4)]
    slow = _rec("slow/x", **_prof(util=1e-5))
    hits = [f for f in detect(recs + [slow]) if f.rule == "low_util"]
    assert [f.cell for f in hits] == ["slow/x"]
    # too few cells for a meaningful median: silent
    assert not [f for f in detect([slow, recs[0]]) if f.rule == "low_util"]


def test_detector_compile_outlier():
    recs = [_rec(f"c{i}/x", compile_us=2e5) for i in range(3)]
    big = _rec("big/x", compile_us=5e6)
    hits = [f for f in detect(recs + [big]) if f.rule == "compile_outlier"]
    assert [f.cell for f in hits] == ["big/x"]
    # large multiple but tiny absolute compile time: silent
    small = [_rec("s0/x", compile_us=10.0), _rec("s1/x", compile_us=10.0),
             _rec("sbig/x", compile_us=400.0)]
    assert not [f for f in detect(small) if f.rule == "compile_outlier"]


def test_detector_queue_saturation():
    sat = _rec("s/serve/x", task="serve", slots=2, queue_depth_mean=5.0,
               queue_depth_max=9, trace="bursty")
    okq = _rec("ok/serve/x", task="serve", slots=4, queue_depth_mean=1.0,
               queue_depth_max=3, trace="uniform")
    hits = [f for f in detect([sat, okq]) if f.rule == "queue_saturation"]
    assert [f.cell for f in hits] == ["s/serve/x"]
    assert hits[0].severity == "crit"        # 5.0 > 2 * slots


def test_detector_shard_imbalance():
    recs = [_rec("a/x", wall_s=10.0, shard=0), _rec("b/x", wall_s=1.0, shard=1)]
    hits = [f for f in detect(recs) if f.rule == "shard_imbalance"]
    assert len(hits) == 1 and hits[0].cell == "<sweep>"
    balanced = [_rec("a/x", wall_s=5.0, shard=0),
                _rec("b/x", wall_s=4.5, shard=1)]
    assert not [f for f in detect(balanced) if f.rule == "shard_imbalance"]


def test_report_ranks_by_severity_then_score_and_formats():
    recs = [
        _rec("crit/x", **_prof(mem=0.9, comp=0.05)),            # crit
        _rec("warn/x", **_prof(mem=0.6, comp=0.2)),             # warn
        _rec("c0/x", compile_us=1e5), _rec("c1/x", compile_us=1e5),
        _rec("big/x", compile_us=9e6),                          # info
    ]
    findings = detect(recs)
    sev = [f.severity for f in findings]
    assert sev == sorted(sev, key=["crit", "warn", "info"].index)
    report = build_report(recs, findings, meta={"fast": True})
    assert report["cells"] == 5 and report["cells_profiled"] == 2
    assert report["by_severity"]["crit"] == 1
    assert json.loads(json.dumps(report)) == report
    table = format_table(report)
    assert "crit" in table and "data_movement_bound" in table


# ---- runner integration (real cells) --------------------------------------

@pytest.fixture(scope="module")
def prof_runner():
    r = BenchmarkRunner(runs=2, warmup=0)
    yield r
    r.close()


def test_profiled_real_cell_fractions_sum_to_one(prof_runner):
    sc = Scenario(arch="gemma-2b", task="train", batch=1, seq=8)
    rr = prof_runner.run(sc, profile=True, record=False)
    assert rr.status == "ok", rr.error
    assert abs(_frac_sum(rr) - 1.0) < 0.05
    assert rr.extra["prof_kind"] == "step"
    assert rr.extra["prof_steps"] == 2
    assert len(rr.extra["prof_timeline"]) == 2
    assert rr.extra["prof_flops"] > 0
    # a transformer train step is matmul-heavy in its op-class split
    assert rr.extra["prof_class_frac"]["matmul"] > 0.01
    assert abs(sum(rr.extra["prof_class_frac"].values()) - 1.0) < 1e-6
    # the record stays JSON-serializable (store round-trip)
    assert json.loads(json.dumps(rr.to_dict()))["extra"]["prof_steps"] == 2


def test_unprofiled_run_records_no_prof_keys(prof_runner):
    sc = Scenario(arch="gemma-2b", task="train", batch=1, seq=8)
    rr = prof_runner.run(sc, record=False)
    assert rr.status == "ok"
    assert not any(k.startswith("prof_") for k in rr.extra)


def test_profiled_serve_cell_records_decode_timeline(prof_runner):
    sc = Scenario(arch="gemma-2b", task="serve", batch=4, seq=8,
                  slots=2, trace="bursty")
    rr = prof_runner.run(sc, profile=True, record=False)
    assert rr.status == "ok", rr.error
    assert rr.extra["prof_kind"] == "decode_step"
    assert rr.extra["prof_steps"] == rr.extra["decode_steps"]
    assert abs(_frac_sum(rr) - 1.0) < 0.05
    # admission + per-request prefill happen outside decode steps
    assert rr.extra["prof_idle_us"] > 0


def test_profile_overhead_within_tolerance(prof_runner):
    """Profiled and unprofiled median step times must agree: the phase
    split is two extra perf_counter reads per step and attribution runs
    outside the timed loop.  (Generous bound — shared CI hosts are noisy;
    runner_bench reports the honest ratio.)"""
    sc = Scenario(arch="gemma-2b", task="train", batch=2, seq=32)
    prof_runner.run(sc, record=False, runs=2)            # compile + settle
    base = prof_runner.run(sc, record=False, runs=3)
    prof = prof_runner.run(sc, record=False, runs=3, profile=True)
    assert base.status == prof.status == "ok"
    assert prof.median_us < base.median_us * 1.5


def test_profiled_sharded_matches_serial(tmp_path):
    """A profiled --jobs 2 run must record the same prof_* payload shape
    (and identical HLO-cost numbers — same program) as the serial path."""
    matrix = ScenarioMatrix(archs=["gemma-2b"], tasks=("train",),
                            batches=(1,), seqs=(8,),
                            dtypes=("fp32", "bf16"))
    serial = BenchmarkRunner(runs=1, warmup=0)
    shard = BenchmarkRunner(runs=1, warmup=0, jobs=2)
    try:
        rs = serial.run_matrix(matrix, profile=True)
        rp = shard.run_matrix(matrix, profile=True)
    finally:
        serial.close()
        shard.close()
    assert [r.name for r in rs] == [r.name for r in rp]
    for a, b in zip(rs, rp):
        assert a.status == b.status == "ok", (a.error, b.error)
        ka = {k for k in a.extra if k.startswith("prof_")}
        kb = {k for k in b.extra if k.startswith("prof_")}
        assert ka == kb and "prof_frac_compute" in ka
        assert abs(_frac_sum(a) - 1.0) < 0.05
        assert abs(_frac_sum(b) - 1.0) < 0.05
        # the attribution inputs are properties of the compiled program,
        # not of the host that measured it
        assert a.extra["prof_flops"] == b.extra["prof_flops"]
        assert a.extra["prof_bytes"] == b.extra["prof_bytes"]
    assert {r.extra["shard"] for r in rp} == {0, 1}
