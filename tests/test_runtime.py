"""Fault tolerance: supervisor restart/replay, stragglers, elastic rescale."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property-based tests need the hypothesis package")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.checkpoint import CheckpointManager
from repro.runtime import HeartbeatMonitor, Supervisor, elastic_rescale_plan


def test_supervisor_restores_and_replays(tmp_path):
    """A mid-run fault must roll back to the last checkpoint and produce the
    exact same final state as a fault-free run (deterministic step fn)."""
    def run(inject):
        ckpt = CheckpointManager(str(tmp_path / ("a" if inject else "b")), keep=3,
                                 async_write=False)
        sup = Supervisor(ckpt, save_every=5, max_restarts=3)
        fired = {"x": False}

        def step(state, i):
            if inject and i == 13 and not fired["x"]:
                fired["x"] = True
                raise RuntimeError("simulated host loss")
            return {"v": state["v"] + (i + 1), "step": jnp.int32(i + 1)}

        state, end = sup.run({"v": jnp.float32(0), "step": jnp.int32(0)}, step, 20)
        return state, sup

    s_fault, sup = run(True)
    s_clean, _ = run(False)
    assert sup.restarts == 1
    assert any(e.startswith("restore@") for e in sup.events)
    assert float(s_fault["v"]) == float(s_clean["v"]) == sum(range(1, 21))


def test_supervisor_bounded_restarts(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    sup = Supervisor(ckpt, save_every=100, max_restarts=2)

    def always_fail(state, i):
        raise ValueError("broken step")

    with pytest.raises(RuntimeError, match="exceeded"):
        sup.run({"v": jnp.float32(0)}, always_fail, 5)


def test_straggler_detection():
    mon = HeartbeatMonitor(n_hosts=4, straggler_factor=1.5)
    for step in range(8):
        for h in range(4):
            mon.report(h, 1.0 if h != 2 else 2.5)
    assert mon.stragglers() == [2]
    mon.evict(2)
    assert 2 not in mon.healthy
    assert mon.stragglers() == []


@given(chips=st.integers(16, 512), batch=st.sampled_from([64, 128, 256, 512]))
@settings(deadline=None, max_examples=40)
def test_elastic_plan_properties(chips, batch):
    plan = elastic_rescale_plan(chips, model_parallel=16, global_batch=batch)
    used = int(np.prod(plan.mesh_shape))
    assert used <= chips
    assert plan.mesh_shape[-1] == 16                 # model axis preserved
    data = used // 16
    assert batch % data == 0                          # batch stays exact
    assert plan.dropped_chips == chips - used
    assert plan.per_replica_batch_multiplier == batch // data


def test_elastic_plan_multipod_axis():
    plan = elastic_rescale_plan(512, model_parallel=16, global_batch=256, multi_pod=True)
    assert plan.axis_names[0] == "pod"
    assert int(np.prod(plan.mesh_shape)) == 512
