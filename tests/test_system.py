"""End-to-end behaviour: training converges, fault-injected training is
bit-identical to fault-free, serving generates, CI nightly detects injected
regressions across the measured suite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ci import run_nightly
from repro.core.harness import RegressionHook
from repro.core.regression import MetricStore
from repro.launch.train import train


def test_training_loss_decreases():
    out = train("gemma-2b", steps=30, batch=4, seq=64, reduced=True)
    first = out["history"][0]["loss"]
    last = out["history"][-1]["loss"]
    assert last < first - 0.05, (first, last)


def test_fault_tolerant_training_is_exact(tmp_path):
    """Injected fault + restore from checkpoint == fault-free run, exactly
    (deterministic data pipeline + checkpoint replay)."""
    clean = train("mamba2-2.7b", steps=24, batch=2, seq=32,
                  ckpt_dir=str(tmp_path / "clean"), save_every=8)
    faulty = train("mamba2-2.7b", steps=24, batch=2, seq=32,
                   ckpt_dir=str(tmp_path / "faulty"), save_every=8,
                   inject_fault_at=13)
    assert any(e.startswith("fault@13") for e in faulty["events"])
    assert any(e.startswith("restore@8") for e in faulty["events"])
    assert clean["final_loss"] == pytest.approx(faulty["final_loss"], rel=1e-6)


def test_serving_generates_tokens():
    from repro.launch.serve import Request, Server
    from repro.configs import get_arch
    cfg = get_arch("gemma-2b").reduced()
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, 8).astype(np.int32), 4) for i in range(4)]
    srv = Server(cfg, slots=2, max_len=24)
    out = srv.run(reqs)
    assert out["tokens"] >= 4 * 3   # every request generated
    assert all(r.done for r in reqs)
    assert out["decode_steps"] >= 4


def test_nightly_ci_detects_injected_regression(tmp_path):
    from repro.runner import BenchmarkRunner
    store = MetricStore(str(tmp_path / "metrics.json"))
    archs = ["gemma-2b"]
    # one runner for all three nights: nights 1-2 re-measure night 0's
    # cached executable instead of rebuilding + recompiling
    runner = BenchmarkRunner(runs=3)
    # night 0: record baseline
    rep0 = run_nightly(store, archs=archs, tasks=("train",), runs=3,
                       update_baseline=True, runner=runner)
    assert rep0.ran == 1 and not rep0.issues
    # night 1: healthy — at most scheduler-noise-level drift (the CI boxes
    # this runs on are shared; the detector's 7% threshold absorbs normal
    # noise but a loaded host can exceed it, so bound it rather than pin 0)
    rep1 = run_nightly(store, archs=archs, tasks=("train",), runs=3, runner=runner)
    noise = max((i.increase for i in rep1.issues if i.metric == "median_us"), default=0.0)
    assert runner.stats.executable_cache_hits >= 1
    # night 2: a commit lands that slows the step by ~50 ms — detection must
    # fire and dominate whatever noise night 1 showed
    hooks = {"gemma-2b/train": RegressionHook(slowdown_s=0.05)}
    rep2 = run_nightly(store, archs=archs, tasks=("train",), runs=3, hooks=hooks,
                       runner=runner)
    hits = [i for i in rep2.issues if i.metric == "median_us" and i.benchmark == "gemma-2b/train"]
    assert hits and hits[0].increase > max(0.07, 2 * noise)
