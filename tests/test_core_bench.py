"""The paper's contribution, tested end-to-end: harness protocol, coverage,
regression detection + bisection, compiler comparison, breakdown, hardware
projection, HLO analyzer."""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.breakdown import breakdown_rows, domain_table
from repro.core.coverage import coverage_report, jaxpr_primitives, stablehlo_ops
from repro.core.harness import RegressionHook, measure
from repro.core.hloanalysis import analyze_hlo
from repro.core.hwcompare import hardware_ratio_table, project_step_time
from repro.core.regression import Commit, MetricStore, bisect_commits, detect
from repro.core.roofline import roofline_from_cost
from repro.core.suite import build_suite


def test_hlo_analyzer_trip_count_correction():
    def scanned(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((9, 64, 64), jnp.float32)
    comp = jax.jit(scanned).lower(x, ws).compile()
    c = analyze_hlo(comp.as_text())
    expect = 9 * 2 * 64 ** 3
    assert 0.9 < c.flops / expect < 1.2
    # XLA's own number misses the trip count (documented limitation);
    # cost_analysis() returns [dict] on some jax versions, dict on others
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < 0.5 * expect


def test_roofline_terms_and_dominance():
    from repro.core.hloanalysis import HloCost
    cost = HloCost(flops=1e12, bytes_accessed=1e9, collective_bytes=1e8)
    rl = roofline_from_cost(cost, arch="x", shape="train_4k", mesh="16x16",
                            chips=256, model_flops=200e12)
    assert rl.dominant == "compute"
    assert rl.compute_s == pytest.approx(1e12 / 197e12)
    assert 0 < rl.useful_ratio < 1.0
    t = project_step_time(rl.to_dict(), __import__("repro.core.hardware", fromlist=["HW_PROFILES"]).HW_PROFILES["a100_like"])
    assert t > 0


def test_measure_median_protocol():
    calls = {"n": 0}

    def step(x):
        calls["n"] += 1
        return x * 2

    m = measure("t", step, (jnp.ones(16),), runs=5)
    assert m.runs == 5 and m.median_us > 0
    assert m.p10_us <= m.median_us <= m.p90_us


def test_regression_detect_and_bisect():
    store = MetricStore("/tmp/repro_test_store.json")
    store.update("bench/a", {"median_us": 100.0, "host_peak_bytes": 1000})
    # below threshold: clean
    assert detect(store, "bench/a", {"median_us": 106.0}) == []
    # above: issue
    issues = detect(store, "bench/a", {"median_us": 120.0})
    assert len(issues) == 1 and issues[0].increase > 0.07

    # bisect a synthetic day of commits; commit #7 introduces a regression
    def runner(factor):
        return lambda bench: {"median_us": 100.0 * factor}

    commits = [Commit(sha=f"c{i}", timestamp=i, run=runner(1.3 if i >= 7 else 1.0))
               for i in range(12)]
    trace = []
    culprit = bisect_commits(commits, "bench/a", "median_us", 100.0, trace=trace)
    assert culprit is not None and culprit.sha == "c7"
    assert len(trace) <= 6   # O(log n) measurements, not 12


def test_regression_hook_detected_end_to_end():
    """Inject a real slowdown via the harness hook; the detector must fire."""
    step = lambda x: jnp.sum(x * x)
    args = (jnp.ones(64),)
    base = measure("b", step, args, runs=4)
    slow = measure("b", step, args, runs=4, hook=RegressionHook(slowdown_s=0.002))
    store = MetricStore("/tmp/repro_test_store2.json")
    store.update("b", {"median_us": base.median_us})
    issues = detect(store, "b", {"median_us": slow.median_us})
    assert issues and issues[0].metric == "median_us"


def test_coverage_suite_exceeds_single_model():
    benches = build_suite(tasks=("train",),
                          archs=["gemma-2b", "mamba2-2.7b", "mixtral-8x7b",
                                 "whisper-large-v3"])
    rep = coverage_report(benches, batch=1, seq=16)
    assert rep["coverage_x_primitives"] > 1.1
    assert rep["suite_stablehlo_ops"] >= rep["baseline_stablehlo_ops"]
    assert "scan" in rep["union_primitives"] or "while" in rep["union_primitives"]


def test_breakdown_and_hardware_tables():
    fake = [{"arch": "gemma-2b", "shape": "train_4k", "mesh": "16x16",
             "roofline": {"compute_s": 0.6, "memory_s": 0.3, "collective_s": 0.1,
                          "chips": 256, "flops_global": 1e15, "bytes_global": 1e12,
                          "collective_bytes_global": 1e11, "dominant": "compute"}}]
    rows = breakdown_rows(fake)
    assert rows and abs(sum([rows[0]["compute_frac"], rows[0]["memory_frac"],
                             rows[0]["collective_frac"]]) - 1.0) < 1e-9
    dom = domain_table(rows)
    assert dom[0]["domain"] == "NLP"
    hw = hardware_ratio_table(fake)
    assert hw and hw[0]["winner"] in ("a100_like", "mi210_like")


def test_stablehlo_op_extraction():
    def f(x):
        return jnp.tanh(x @ x.T).sum()

    low = jax.jit(f).lower(jax.ShapeDtypeStruct((8, 8), jnp.float32))
    ops = stablehlo_ops(low.as_text())
    assert "dot_general" in ops and "tanh" in ops
    prims = jaxpr_primitives(f, jnp.ones((8, 8)))
    assert "dot_general" in prims
