"""The telemetry subsystem: span tracing stitched across dispatch
transports, Chrome trace export, provenance stamping, the slots="auto"
staleness warning, and the provenance-keyed result history."""
import json
import subprocess
import sys

import pytest

from repro.runner import (BenchmarkRunner, ResultStore, RunResult, Scenario,
                          ScenarioMatrix)
from repro.runner.loadgen import DEFAULT_SLOTS, auto_slots_info
from repro.telemetry.export import chrome_trace, flame_summary, save_trace
from repro.telemetry.history import drift, rolling_baseline, series, trajectory
from repro.telemetry.provenance import (PROV_KEYS, collect, provenance_key,
                                        stamp)
from repro.telemetry.spans import (NULL_TRACER, Tracer, recent_warnings,
                                   group_label, warn)


# ---- spans + export (no jax execution) ------------------------------------

def _synthetic_tracer() -> Tracer:
    tr = Tracer()
    tr.begin_trace()
    with tr.span("matrix", kind="matrix") as root:
        with tr.span("group:g0", kind="group"):
            with tr.span("cell:a/train", kind="cell", cell="a/train") as c:
                tr.add("build", ts=c.ts, dur_s=0.25, parent=c)
                tr.add("measure", ts=c.ts + 0.25, dur_s=0.75, parent=c)
    del root
    return tr


def test_tracer_nesting_and_export():
    tr = _synthetic_tracer()
    spans = tr.export()
    assert len(spans) == 5
    by_name = {sp["name"]: sp for sp in spans}
    assert by_name["group:g0"]["parent_id"] == by_name["matrix"]["span_id"]
    assert by_name["cell:a/train"]["parent_id"] == by_name["group:g0"]["span_id"]
    assert by_name["build"]["parent_id"] == by_name["cell:a/train"]["span_id"]
    # export is start-ordered
    assert [sp["ts"] for sp in spans] == sorted(sp["ts"] for sp in spans)


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    with NULL_TRACER.span("x") as sp:
        pass
    NULL_TRACER.finish(sp)
    assert NULL_TRACER.context(sp) is None
    assert NULL_TRACER.export() == []


def test_chrome_trace_lanes_and_args():
    tr = _synthetic_tracer()
    tr.ingest([{"name": "cell:a/train", "span_id": "w-1.1",
                "parent_id": None, "kind": "cell", "ts": 1.0,
                "dur_s": 0.5, "tid": 7}], proc="shard0")
    doc = chrome_trace(tr.export())
    events = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    meta = {e["args"]["name"]: e["pid"]
            for e in doc["traceEvents"] if e["ph"] == "M"}
    assert meta["coordinator"] == 0 and "shard0" in meta
    assert len({e["pid"] for e in events}) == 2
    cell = next(e for e in events if e["args"]["span_id"] == "w-1.1")
    assert cell["pid"] == meta["shard0"]
    assert cell["dur"] == pytest.approx(0.5e6)
    # attrs ride in args so the tree reconstructs from the file alone
    coord_cell = next(e for e in events
                      if e["name"] == "cell:a/train" and e["pid"] == 0)
    assert coord_cell["args"]["cell"] == "a/train"
    json.dumps(doc)   # must be JSON-serializable as-is


def test_flame_summary_tree():
    text = flame_summary(_synthetic_tracer().export())
    lines = text.splitlines()
    assert lines[0].startswith("matrix")
    assert lines[1].startswith("  group:g0")
    assert "      build 250.0ms" in text and "measure 750.0ms" in text


def test_worker_tracer_stitches_under_wire_parent():
    """The full wire round-trip: a worker-side tracer built from the job's
    trace context roots its spans under the coordinator's dispatch span,
    and ingest relabels the lane to the worker's identity."""
    coord = Tracer()
    coord.begin_trace()
    ds = coord.start("dispatch:a/train", kind="dispatch")
    ctx = coord.context(ds)
    worker = Tracer(trace_id=ctx["trace_id"], proc="worker",
                    root_parent=ctx["parent"] or None)
    with worker.span("cell:a/train", kind="cell") as c:
        worker.add("build", ts=c.ts, dur_s=0.1, parent=c)
    assert worker.trace_id == coord.trace_id
    coord.ingest(worker.export(), proc="local0")
    coord.finish(ds)
    spans = coord.export()
    cell = next(sp for sp in spans if sp["kind"] == "cell")
    build = next(sp for sp in spans if sp["kind"] == "phase")
    assert cell["parent_id"] == ds.span_id
    assert build["parent_id"] == cell["span_id"]   # intra-worker untouched
    assert cell["proc"] == build["proc"] == "local0"


def test_group_label_is_stable():
    assert group_label(("gemma-2b", "fp32")) == group_label(("gemma-2b", "fp32"))
    assert group_label(("gemma-2b", "fp32")) != group_label(("gemma-2b", "bf16"))


# ---- provenance ------------------------------------------------------------

def test_provenance_stamp_and_key():
    extra = {}
    stamp(extra)
    assert set(PROV_KEYS) <= set(extra)
    assert extra["prov_python"].count(".") == 2
    key = provenance_key(extra)
    assert key.endswith(f"/{extra['prov_backend']}/{extra['prov_host']}")
    # setdefault semantics: a worker's stamp must not be overwritten
    pre = {"prov_host": "measured-there"}
    stamp(pre)
    assert pre["prov_host"] == "measured-there"
    assert provenance_key(pre).endswith("/measured-there")


def test_provenance_collect_is_cached():
    assert collect() is collect()


# ---- slots="auto" staleness (satellite 1) ---------------------------------

def _write_curve(path, **over):
    data = {"schema": 2, "arch": "gemma-2b", "slots": 4,
            "curves": {"batched": {"knee": {"knee_load": 2.0}}}}
    data.update(over)
    path.write_text(json.dumps(data))
    return str(path)


def test_auto_slots_info_fallback_reasons(tmp_path):
    p = tmp_path / "curve.json"
    assert auto_slots_info("gemma-2b", str(p)) == (DEFAULT_SLOTS, "missing")
    p.write_text("{not json")
    assert auto_slots_info("gemma-2b", str(p))[1] == "unreadable"
    _write_curve(p, schema=1)
    assert auto_slots_info("gemma-2b", str(p))[1] == "stale-schema"
    _write_curve(p, arch="mamba2-2.7b")
    assert auto_slots_info("gemma-2b", str(p))[1] == "foreign-arch"
    _write_curve(p, slots=0)
    assert auto_slots_info("gemma-2b", str(p))[1] == "degenerate-curve"
    _write_curve(p)   # healthy: 4 slots * 1.25 headroom / knee_load 2.0
    assert auto_slots_info("gemma-2b", str(p)) == (3, "")
    # every fallback emitted a structured warning into the ring
    reasons = [w["reason"] for w in recent_warnings("slots_fallback")]
    for r in ("missing", "unreadable", "stale-schema", "foreign-arch",
              "degenerate-curve"):
        assert r in reasons, reasons


def test_matrix_slots_fallback_marks_auto_cells(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_LOADGEN_CURVE", str(tmp_path / "nope.json"))
    m = ScenarioMatrix(archs=["gemma-2b"], tasks=("serve",), batches=(2,),
                       seqs=(8,), slots=("auto",), modes=("jit",))
    cells = m.expand()
    assert cells and all(s.slots == DEFAULT_SLOTS for s in cells)
    fb = m.slots_fallback()
    assert fb == {s.name: "missing" for s in cells}
    # fixed-width cells never carry a marker
    fixed = ScenarioMatrix(archs=["gemma-2b"], tasks=("serve",), batches=(2,),
                           seqs=(8,), slots=(2,), modes=("jit",))
    fixed.expand()
    assert fixed.slots_fallback() == {}


def test_warn_ring_filters_by_event(capsys):
    warn("test_event_a", x=1)
    warn("test_event_b", x=2)
    got = recent_warnings("test_event_a")
    assert got and all(w["event"] == "test_event_a" for w in got)
    err = capsys.readouterr().err
    assert "[telemetry]" in err and "test_event_b" in err


# ---- history over the run log ---------------------------------------------

def _hist_record(name, median, ts, commit="aaa", status="ok"):
    return {"name": name, "status": status, "median_us": median, "ts": ts,
            "extra": {"prov_commit": commit, "prov_dirty": False,
                      "prov_backend": "cpu", "prov_host": "h1"}}


def test_series_groups_by_name_and_provenance(tmp_path):
    store = ResultStore(str(tmp_path / "s"))
    for i in range(3):
        store.append(_hist_record("a/train/b1", 100.0 + i, ts=float(i)))
    store.append(_hist_record("a/train/b1", 500.0, ts=9.0, commit="bbb"))
    store.append({"name": "a/train/b1", "median_us": 1.0})  # no prov: skipped
    ser = series(store)
    assert len(ser) == 2
    (k1, pts1), (k2, pts2) = sorted(ser.items())
    assert k1[0] == k2[0] == "a/train/b1" and k1[1] != k2[1]
    assert [p["median_us"] for p in pts1] == [100.0, 101.0, 102.0]
    assert [p["ts"] for p in pts1] == sorted(p["ts"] for p in pts1)
    assert len(pts2) == 1


def test_drift_flags_newest_point_only():
    pts = [{"status": "ok", "ts": float(i), "median_us": 100.0}
           for i in range(5)]
    assert drift(pts, benchmark="b") == []
    pts.append({"status": "ok", "ts": 5.0, "median_us": 130.0})
    issues = drift(pts, benchmark="b")
    assert [i.metric for i in issues] == ["median_us"]
    assert issues[0].increase == pytest.approx(0.30)
    assert rolling_baseline(pts[:-1])["median_us"] == 100.0


def test_trajectory_report_shape(tmp_path):
    store = ResultStore(str(tmp_path / "s"))
    for i in range(4):
        store.append(_hist_record("a/train/b1", 100.0, ts=float(i)))
    store.append(_hist_record("a/train/b1", 150.0, ts=4.0))
    store.append(_hist_record("a/infer/b1", 50.0, ts=0.0))  # 1 point: omitted
    rep = trajectory(store, min_points=2)
    assert [s["name"] for s in rep["meta"]["series"]] == ["a/train/b1"]
    s = rep["meta"]["series"][0]
    assert s["points"] == 5 and s["trend"] == pytest.approx(0.5)
    assert [f["rule"] for f in rep["findings"]] == ["perf_drift"]
    assert rep["findings"][0]["evidence"]["metric"] == "median_us"


def test_metric_store_log_result_keeps_baseline_pointer(tmp_path):
    from repro.core.regression import MetricStore
    store = MetricStore(str(tmp_path / "m"))
    store.update("a/train/b1", {"median_us": 100.0})
    base = store.baseline("a/train/b1")
    sc = Scenario(arch="a", task="train", batch=1, seq=8)
    rr = RunResult.from_error(sc, "n/a")
    rr.name, rr.status, rr.median_us, rr.error = "a/train/b1", "ok", 400.0, None
    store.log_result(rr)
    # the history got the point, the baseline pointer did not move
    assert store.baseline("a/train/b1") == base
    hist = list(store._store.history("a/train/b1"))
    assert any(r.get("median_us") == 400.0 for r in hist)


def test_concurrent_provenance_appends_two_processes(tmp_path):
    """Two stamped appenders (distinct commits via REPRO_COMMIT) into one
    store: zero corrupt lines, and each provenance series replays complete
    and time-ordered."""
    path = str(tmp_path / "store")
    ResultStore(path)
    script = (
        "import sys, time\n"
        "from repro.runner import ResultStore\n"
        "from repro.telemetry.provenance import stamp\n"
        "store = ResultStore(sys.argv[1])\n"
        "for i in range(20):\n"
        "    extra = stamp({})\n"
        "    store.append({'name': 'a/train/b1', 'status': 'ok',\n"
        "                  'median_us': float(i), 'ts': time.time(),\n"
        "                  'extra': extra})\n"
    )
    from repro.runner.pool import _subprocess_env
    procs = []
    for commit in ("c1" * 20, "c2" * 20):
        env = _subprocess_env()
        env["REPRO_COMMIT"] = commit
        procs.append(subprocess.Popen([sys.executable, "-c", script, path],
                                      env=env))
    for p in procs:
        assert p.wait(timeout=60) == 0
    fresh = ResultStore(path)
    assert fresh.corrupt_lines == 0
    ser = series(fresh)
    assert len(ser) == 2
    for (name, prov), pts in ser.items():
        assert name == "a/train/b1" and len(pts) == 20
        assert [p["ts"] for p in pts] == sorted(p["ts"] for p in pts)
    assert {k[1][:12] for k in ser} == {"c1" * 6, "c2" * 6}


# ---- traced execution through the runner (jax) ----------------------------

def test_jobs2_trace_stitches_worker_spans(tmp_path):
    """A traced --jobs 2 matrix exports ONE Chrome trace where every
    worker-side cell span nests under its coordinator dispatch span."""
    matrix = ScenarioMatrix(archs=["gemma-2b"], tasks=("train",),
                            batches=(1,), seqs=(8,),
                            dtypes=("fp32", "bf16"))
    runner = BenchmarkRunner(store=ResultStore(str(tmp_path / "s")),
                             runs=1, warmup=0, jobs=2)
    runner.tracer = Tracer()
    try:
        results = runner.run_matrix(matrix)
    finally:
        runner.close()
    assert [rr.status for rr in results] == ["ok", "ok"]
    for rr in results:
        assert rr.extra["span_trace"] == runner.tracer.trace_id
        assert rr.extra["span_dispatch"]
        assert rr.extra["prov_commit"]
    path = save_trace(runner.tracer.export(), str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    events = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    by_id = {e["args"]["span_id"]: e for e in events}
    assert len({e["pid"] for e in events}) >= 3   # coordinator + 2 shards
    worker_cells = [e for e in events
                    if e["args"].get("kind") == "cell" and e["pid"] != 0]
    assert len(worker_cells) >= 2
    dispatched = set()
    for cell in worker_cells:
        parent = by_id[cell["args"]["parent"]]
        assert parent["args"]["kind"] == "dispatch"
        assert parent["pid"] == 0                  # coordinator lane
        assert parent["args"]["cell"] == cell["args"]["cell"]
        dispatched.add(parent["args"]["cell"])
    assert dispatched == {rr.name for rr in results}


def test_span_overhead_on_warm_executable():
    """Tracing a warm cell costs < 5% of its median (plus scheduler-noise
    slack): spans are perf_counter reads, not measurement work."""
    sc = Scenario(arch="gemma-2b", task="train", batch=1, seq=8)
    runner = BenchmarkRunner(runs=3, warmup=1)
    try:
        runner.run(sc, record=False)   # build + compile once
        plain = min(runner.run(sc, record=False).median_us
                    for _ in range(3))
        runner.tracer = Tracer()
        traced = min(runner.run(sc, record=False).median_us
                     for _ in range(3))
    finally:
        runner.close()
    assert traced <= plain * 1.05 + 200.0, (traced, plain)


def test_provenance_on_every_status(tmp_path):
    """Mixed ok/error matrix: every stored record carries the prov_*
    stamps, whichever path created it."""
    matrix = ScenarioMatrix(archs=["gemma-2b", "no-such-arch"],
                            tasks=("train",), batches=(1,), seqs=(8,))
    store = ResultStore(str(tmp_path / "s"))
    runner = BenchmarkRunner(store=store, runs=1, warmup=0)
    try:
        results = runner.run_matrix(matrix)
    finally:
        runner.close()
    assert {rr.status for rr in results} == {"ok", "error"}
    recs = list(store.history())
    assert len(recs) == 2
    for rec in recs:
        for k in PROV_KEYS:
            assert k in rec["extra"], (rec["name"], k)
        assert provenance_key(rec["extra"]) == provenance_key(collect())
