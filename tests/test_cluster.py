"""Cluster dispatch subsystem: the shared JSONL protocol (pipe + socket
transports), build-key group scheduling (static LPT vs dynamic stealing),
the TCP coordinator (registration, work stealing, heartbeat/disconnect
failure handling, group reassignment), ``run_matrix(..., cluster=...)``
end-to-end on ``local:N`` workers, and recorded trace-spec files as a
serve scenario axis."""
import json
import socket
import threading
import time

import pytest

from repro.runner import (BenchmarkRunner, Coordinator, RunResult, Scenario,
                          ScenarioMatrix, TraceSpec, assign_shards,
                          generate_trace, load_spec, parse_cluster_spec,
                          rank_groups, save_spec)
from repro.runner.pool import steal_plan
from repro.runner.protocol import (Channel, LineBuffer, encode, job_message,
                                   stats_delta)
from repro.runner.traces import spec_for_scenario


# ---- protocol -------------------------------------------------------------

def test_line_buffer_reassembles_partial_lines():
    buf = LineBuffer()
    payload = encode({"op": "a"}) + encode({"op": "b"})
    assert buf.feed(payload[:5]) == []
    assert buf.feed(payload[5:]) == [{"op": "a"}, {"op": "b"}]
    assert buf.feed(b"") == []
    with pytest.raises(ValueError):
        buf.feed(b"[1, 2]\n")          # a line that is not an object


def test_channel_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    ca, cb = Channel.over_socket(a), Channel.over_socket(b)
    ca.send({"op": "run", "cell": 3})
    assert cb.recv(5.0) == {"op": "run", "cell": 3}
    assert cb.recv(0.05) is None and not cb.eof     # timeout, still open
    a.close()
    assert cb.recv(5.0) is None and cb.eof          # peer closed
    cb.close()


def test_job_message_carries_hook_params_and_cell_id():
    sc = Scenario(arch="gemma-2b", task="train", batch=1, seq=8)

    class Hook:
        slowdown_s, leak_bytes = 0.5, 128

    msg = job_message(sc, runs=2, warmup=0, profile=True, hook=Hook(),
                      cell=7)
    assert msg["op"] == "run" and msg["cell"] == 7 and msg["profile"]
    assert msg["hook"] == {"slowdown_s": 0.5, "leak_bytes": 128}
    assert Scenario.from_dict(msg["scenario"]) == sc
    assert "hook" not in job_message(sc, runs=None, warmup=None,
                                     profile=False)


def test_stats_delta_is_monotonic_difference():
    seen = {}
    assert stats_delta({"model_builds": 2}, seen) == {"model_builds": 2}
    assert stats_delta({"model_builds": 3, "errors": 1}, seen) == \
        {"model_builds": 1, "errors": 1}
    # a respawned worker's counters restart below the snapshot: clamped
    assert stats_delta({"model_builds": 1}, seen) == {"model_builds": 0}
    assert stats_delta(None, seen) == {}


# ---- scheduling: groups, static LPT, steal plan ---------------------------

def test_rank_groups_and_steal_plan():
    scs = [Scenario(arch=a, task=t, batch=1, seq=8, dtype=d)
           for a in ("a1", "a2") for d in ("fp32", "bf16")
           for t in ("train", "infer_decode")]
    ranked = rank_groups(scs)
    # 4 build-key groups, together in input order, equal weights keep
    # first-appearance order
    assert [idxs for idxs, _ in ranked] == [[0, 1], [2, 3], [4, 5], [6, 7]]
    assert all(w == 5 for _, w in ranked)    # train(4) + infer_decode(1)
    # static LPT places ranked groups onto the lightest shard — the
    # assign_shards contract every prior-PR test relies on
    assert assign_shards(scs, 2) == [[0, 1, 4, 5], [2, 3, 6, 7]]
    # steal plan: first `jobs` groups seed one worker each (deterministic
    # start), the tail is the shared deque idle workers pull from
    seeds, queue = steal_plan(ranked, 2)
    assert seeds == [[0, 1], [2, 3]] and list(queue) == [[4, 5], [6, 7]]
    # fewer groups than workers: surplus seeds empty, nothing queued
    seeds, queue = steal_plan(ranked[:1], 3)
    assert seeds == [[0, 1], [], []] and not queue


def test_parse_cluster_spec():
    assert parse_cluster_spec("local:2") == ("local", "2")
    assert parse_cluster_spec("0.0.0.0:5055") == ("bind", "0.0.0.0:5055")
    for bad in ("", "local:0", "local:x", "justahost", "host:"):
        with pytest.raises(ValueError):
            parse_cluster_spec(bad)


# ---- coordinator against scripted workers (no jax, fast) ------------------

def _fake_result(job: dict) -> RunResult:
    sc = Scenario.from_dict(job["scenario"])
    return RunResult(name=sc.name, bench=sc.bench, arch=sc.arch, task=sc.task,
                     batch=sc.batch, seq=sc.seq, dtype=sc.dtype, mode=sc.mode,
                     status="ok", median_us=1.0, runs=1)


def _connect_worker(address: str, host: str) -> Channel:
    h, _, p = address.rpartition(":")
    chan = Channel.over_socket(socket.create_connection((h, int(p)),
                                                        timeout=5))
    chan.send({"op": "register", "host": host, "capacity": 1})
    return chan


def test_coordinator_requeues_dead_workers_group():
    """The cluster failure contract: a worker dying mid-cell costs exactly
    that cell (error record naming the host), and the unsent remainder of
    its group is re-stolen by a surviving worker — the run completes."""
    scs = [Scenario(arch="a1", task="train", batch=1, seq=s, dtype=d)
           for d in ("fp32", "bf16") for s in (8, 16)]   # 2 groups of 2
    coord = Coordinator(bind="127.0.0.1:0", heartbeat_timeout=30.0,
                        timeout=60.0, connect_timeout=60.0)
    out = {}
    runner = threading.Thread(
        target=lambda: out.update(zip(("results", "stats"),
                                      coord.run(scs, runs=1))))
    runner.start()
    try:
        # worker A steals the fp32 group, gets cell 0, dies mid-cell
        chan_a = _connect_worker(coord.address, "fakeA")
        job = chan_a.recv(10.0)
        assert job and job["op"] == "run" and job["cell"] == 0
        chan_a.close()
        # worker B survives: drains the fp32 remainder + the bf16 group
        chan_b = _connect_worker(coord.address, "fakeB")
        served = 0
        for _ in range(3):
            job = chan_b.recv(20.0)
            assert job and job["op"] == "run"
            served += 1
            chan_b.send({"op": "result", "cell": job["cell"],
                         "result": _fake_result(job).to_dict(),
                         "stats": {"scenarios_run": served,
                                   "model_builds": 1}})
        runner.join(30.0)
        assert not runner.is_alive()
        chan_b.close()
    finally:
        coord.close()
        runner.join(5.0)
    results, stats = out["results"], out["stats"]
    assert [r.name for r in results] == [s.name for s in scs]
    dead, ok = results[0], results[1:]
    assert dead.status == "error" and "fakeA" in dead.error
    assert "disconnect" in dead.error and dead.extra["host"] == "fakeA"
    assert all(r.status == "ok" and r.extra["host"] == "fakeB" for r in ok)
    assert all(r.extra["isolated"] for r in results)
    # worker stats delta-merged (3 cumulative snapshots -> 3 runs, ONE
    # build), plus the coordinator's own error accounting
    assert stats.scenarios_run == 4 and stats.errors == 1
    assert stats.model_builds == 1


def test_coordinator_survives_stray_client_garbage():
    """Non-protocol bytes (port scan, HTTP probe, buggy worker) cost that
    connection, never the sweep — run() must not raise for cluster
    faults."""
    scs = [Scenario(arch="a1", task="train", batch=1, seq=8)]
    coord = Coordinator(bind="127.0.0.1:0", heartbeat_timeout=30.0,
                        timeout=60.0, connect_timeout=60.0)
    out = {}
    runner = threading.Thread(
        target=lambda: out.update(zip(("results", "stats"),
                                      coord.run(scs, runs=1))))
    runner.start()
    try:
        h, _, p = coord.address.rpartition(":")
        stray = socket.create_connection((h, int(p)), timeout=5)
        stray.sendall(b"GET / HTTP/1.1\r\nHost: x\r\n\r\n")
        chan = _connect_worker(coord.address, "real")
        job = chan.recv(20.0)
        assert job and job["op"] == "run"
        chan.send({"op": "result", "cell": job["cell"],
                   "result": _fake_result(job).to_dict(),
                   "stats": {"scenarios_run": 1}})
        runner.join(30.0)
        assert not runner.is_alive()
        stray.close()
        chan.close()
    finally:
        coord.close()
        runner.join(5.0)
    (rr,), stats = out["results"], out["stats"]
    assert rr.status == "ok" and rr.extra["host"] == "real"
    assert stats.scenarios_run == 1 and stats.errors == 0


def test_coordinator_reaps_unregistered_pinger():
    """A client that sends valid JSON but never registers is reaped on a
    registration deadline from ACCEPT time — last_seen-based reaping
    would let it refresh itself forever and leak its fd into every
    select() of the persistent coordinator."""
    scs = [Scenario(arch="a1", task="train", batch=1, seq=8)]
    coord = Coordinator(bind="127.0.0.1:0", heartbeat_timeout=1.0,
                        timeout=60.0, connect_timeout=60.0)
    out = {}
    runner = threading.Thread(
        target=lambda: out.update(zip(("results", "stats"),
                                      coord.run(scs, runs=1))))
    runner.start()
    try:
        h, _, p = coord.address.rpartition(":")
        stray = Channel.over_socket(
            socket.create_connection((h, int(p)), timeout=5))
        stray.send({"op": "ping"})      # valid JSON, but no register
        time.sleep(1.6)                 # past the registration deadline
        chan = _connect_worker(coord.address, "real")
        job = chan.recv(20.0)
        assert job and job["op"] == "run"
        # the stray connection was closed by the coordinator mid-run
        assert stray.recv(2.0) is None and stray.eof
        chan.send({"op": "result", "cell": job["cell"],
                   "result": _fake_result(job).to_dict(),
                   "stats": {"scenarios_run": 1}})
        runner.join(30.0)
        assert not runner.is_alive()
        stray.close()
        chan.close()
    finally:
        coord.close()
        runner.join(5.0)
    (rr,) = out["results"]
    assert rr.status == "ok" and rr.extra["host"] == "real"


def test_coordinator_reaps_idle_dead_worker_before_feeding():
    """A worker that dies while idle BETWEEN runs must be reaped before
    the next run's first feed — not handed a cell that instantly becomes
    a spurious error record while a healthy worker sits ready."""
    scs = [Scenario(arch="a1", task="train", batch=1, seq=8)]
    coord = Coordinator(bind="127.0.0.1:0", heartbeat_timeout=30.0,
                        timeout=60.0, connect_timeout=60.0)
    try:
        out1, out2 = {}, {}
        t1 = threading.Thread(
            target=lambda: out1.update(zip(("results", "stats"),
                                           coord.run(scs, runs=1))))
        t1.start()
        chan_a = _connect_worker(coord.address, "fakeA")
        job = chan_a.recv(10.0)
        assert job and job["op"] == "run"
        chan_a.send({"op": "result", "cell": job["cell"],
                     "result": _fake_result(job).to_dict(),
                     "stats": {"scenarios_run": 1}})
        t1.join(30.0)
        assert not t1.is_alive()
        chan_a.close()                      # dies idle between runs
        chan_b = _connect_worker(coord.address, "fakeB")
        time.sleep(0.2)                     # EOF + register reach the kernel
        t2 = threading.Thread(
            target=lambda: out2.update(zip(("results", "stats"),
                                           coord.run(scs, runs=1))))
        t2.start()
        job = chan_b.recv(20.0)
        assert job and job["op"] == "run"
        chan_b.send({"op": "result", "cell": job["cell"],
                     "result": _fake_result(job).to_dict(),
                     "stats": {"scenarios_run": 2}})
        t2.join(30.0)
        assert not t2.is_alive()
        chan_b.close()
    finally:
        coord.close()
    rr1, rr2 = out1["results"][0], out2["results"][0]
    assert rr1.status == "ok" and rr1.extra["host"] == "fakeA"
    assert rr2.status == "ok" and rr2.extra["host"] == "fakeB"


def test_coordinator_retires_worker_on_unmatched_result():
    """A result the coordinator can't match to an in-flight cell (e.g. a
    version-skewed worker omitting the echoed cell id) retires that
    connection immediately — not after the 1200s per-cell timeout."""
    scs = [Scenario(arch="a1", task="train", batch=1, seq=s)
           for s in (8, 16)]               # one group of 2 cells
    coord = Coordinator(bind="127.0.0.1:0", heartbeat_timeout=30.0,
                        timeout=60.0, connect_timeout=1.0)
    out = {}
    runner = threading.Thread(
        target=lambda: out.update(zip(("results", "stats"),
                                      coord.run(scs, runs=1))))
    runner.start()
    try:
        chan = _connect_worker(coord.address, "skewed")
        job = chan.recv(10.0)
        assert job and job["op"] == "run"
        chan.send({"op": "result",        # no "cell" echo: off-protocol
                   "result": _fake_result(job).to_dict(), "stats": {}})
        runner.join(30.0)
        assert not runner.is_alive()
        chan.close()
    finally:
        coord.close()
        runner.join(5.0)
    first, second = out["results"]
    assert first.status == "error" and "unmatched result" in first.error
    assert first.extra["host"] == "skewed"
    # the group remainder was requeued; with no workers left it drained
    # to error records after connect_timeout instead of hanging
    assert second.status == "error" and "no cluster workers" in second.error


def test_coordinator_errors_out_when_no_workers_connect():
    """No registered worker within connect_timeout: remaining cells become
    error records instead of hanging the sweep (run_matrix never raises
    for cluster faults)."""
    scs = [Scenario(arch="a1", task="train", batch=1, seq=8)]
    coord = Coordinator(bind="127.0.0.1:0", connect_timeout=0.5)
    try:
        t0 = time.monotonic()
        results, stats = coord.run(scs, runs=1)
    finally:
        coord.close()
    assert time.monotonic() - t0 < 10.0
    assert len(results) == 1 and results[0].status == "error"
    assert "no cluster workers" in results[0].error
    assert stats.errors == 1


# ---- cluster local:N end-to-end (real workers, real cells) ----------------

def test_cluster_local2_matches_serial_on_serve_matrix(tmp_path):
    """The acceptance invariant: cluster="local:2" on a 4-cell serve
    matrix returns the same result set as serial execution — names in
    matrix order, every cell ok, generated tokens byte-identical (the
    PR 2/3 determinism witness) — with extra["host"] stamped and worker
    builds visible in the parent stats."""
    from repro.runner import ResultStore
    matrix = ScenarioMatrix(archs=["gemma-2b"], tasks=("serve",),
                            batches=(3,), seqs=(8,), slots=(2, 3),
                            traces=("uniform", "bursty"))
    assert len(matrix) == 4
    serial = BenchmarkRunner(runs=1, warmup=0)
    serial_rrs = serial.run_matrix(matrix)
    assert all(r.status == "ok" for r in serial_rrs)

    store = ResultStore(str(tmp_path / "s"))
    clustered = BenchmarkRunner(store=store, runs=1, warmup=0)
    try:
        cluster_rrs = clustered.run_matrix(matrix, cluster="local:2")
    finally:
        clustered.close()

    assert [r.name for r in cluster_rrs] == [r.name for r in serial_rrs]
    assert all(r.status == "ok" for r in cluster_rrs)
    for srr, crr in zip(serial_rrs, cluster_rrs):
        assert crr.extra["tokens"] == srr.extra["tokens"], crr.name
        assert crr.extra["tokens_digest"] == srr.extra["tokens_digest"]
        assert crr.extra["host"].startswith("local")
        assert crr.extra["isolated"]
    # 2 build-key groups (slots 2 vs 3): worker builds/compiles merged
    assert clustered.stats.scenarios_run == 4
    assert clustered.stats.model_builds >= 1
    assert clustered.stats.executable_builds >= 2
    # every cell recorded from the coordinator's on_result callback
    assert len(list(store.history())) == 4


# ---- recorded trace specs (trace="file:...") ------------------------------

def test_trace_spec_save_load_roundtrip(tmp_path):
    spec = TraceSpec(profile="mixed", requests=5, prompt_len=8, max_new=4,
                     seed=11)
    path = save_spec(spec, str(tmp_path / "prod_trace.json"))
    assert load_spec(path) == spec
    a, b = generate_trace(spec, vocab=64), generate_trace(load_spec(path),
                                                          vocab=64)
    assert [(r.rid, r.arrival_step, r.max_new, r.prompt.tolist())
            for r in a] == \
        [(r.rid, r.arrival_step, r.max_new, r.prompt.tolist()) for r in b]
    # the file carries a schema tag; junk JSON is rejected loudly
    with open(path) as f:
        assert json.load(f)["trace_spec"] == 1
    bad = tmp_path / "bad.json"
    bad.write_text('{"profile": "uniform"}')
    with pytest.raises(ValueError):
        load_spec(str(bad))
    # strict shape: a misspelled field must fail loudly, not silently
    # replay a default workload under the intended trace's name
    typo = tmp_path / "typo.json"
    typo.write_text(json.dumps({"trace_spec": 1, "profile": "bursty",
                                "requests": 5, "prompt_len": 8, "seed": 0,
                                "max_new_tokens": 256}))
    with pytest.raises(ValueError, match="max_new"):
        load_spec(str(typo))


def test_file_trace_scenario_axis(tmp_path):
    spec = TraceSpec(profile="bursty", requests=3, prompt_len=8, max_new=4,
                     seed=9)
    path = save_spec(spec, str(tmp_path / "t.json"))
    sc = Scenario(arch="gemma-2b", task="serve", batch=3, seq=8, slots=2,
                  trace=f"file:{path}")
    # the file defines the workload; the scenario axes stay labels
    assert spec_for_scenario(sc) == spec
    assert sc.name.endswith(f"/x2/file:{path}")
    # file traces are serve-only, like every trace; empty path rejected
    with pytest.raises(ValueError):
        Scenario(arch="gemma-2b", task="serve", trace="file:")
    with pytest.raises(ValueError):
        Scenario(arch="gemma-2b", task="train", trace=f"file:{path}")
    # matrices expand file traces like any other profile
    m = ScenarioMatrix(archs=["gemma-2b"], tasks=("serve",), batches=(3,),
                       seqs=(8,), slots=(2,),
                       traces=("uniform", f"file:{path}"))
    assert len(m) == 2


def test_file_trace_replays_identically_to_inline_profile(tmp_path):
    """A recorded spec file replays the exact same workload as the inline
    profile it was recorded from: same requests in, byte-identical tokens
    out (the missing-file case degrades to that cell's error record)."""
    inline = Scenario(arch="gemma-2b", task="serve", batch=3, seq=8,
                      slots=2, trace="bursty")
    path = save_spec(spec_for_scenario(inline), str(tmp_path / "rec.json"))
    recorded = Scenario(arch="gemma-2b", task="serve", batch=3, seq=8,
                        slots=2, trace=f"file:{path}")
    runner = BenchmarkRunner(runs=1, warmup=0)
    rr_inline = runner.run(inline, record=False)
    rr_file = runner.run(recorded, record=False)
    assert rr_inline.status == "ok" and rr_file.status == "ok"
    assert rr_file.extra["tokens"] == rr_inline.extra["tokens"]
    assert rr_file.extra["tokens_digest"] == rr_inline.extra["tokens_digest"]
    assert rr_file.extra["trace"] == f"file:{path}"
    # same (build_key, mode, max_len): the second replay reused the engine
    assert rr_file.cache["executable_reused"]
    missing = Scenario(arch="gemma-2b", task="serve", batch=3, seq=8,
                       slots=2, trace="file:/nonexistent/trace.json")
    rr = runner.run(missing, record=False)
    assert rr.status == "error" and "trace.json" in rr.error
