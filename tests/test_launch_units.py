"""Launch-layer units that don't need 512 devices: cell rules, input specs,
microbatch equivalence, roofline estimates, hardware projection."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, get_shape, shape_applicable
from repro.core.roofline import model_flops_estimate
from repro.launch import dryrun
from repro.launch.steps import TrainHyper, make_train_step
from repro.optim.adamw import adamw_init


def test_input_specs_cover_all_model_inputs():
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            specs = dryrun.input_specs(cfg, shape)
            assert "tokens" in specs
            if shape.kind != "decode":
                if cfg.family == "encdec":
                    assert "frames" in specs and specs["frames"].shape[1] == cfg.enc_seq
                if cfg.family == "vlm":
                    assert specs["patch_embeds"].shape[1] == cfg.n_prefix
            assert specs["tokens"].shape[0] == shape.global_batch


def test_cell_rules_decode_uses_sequence_sharding():
    cfg = get_arch("internlm2-20b")
    rules = dryrun.cell_rules(cfg, get_shape("decode_32k"))
    assert rules["cache_seq"] == ("model",)
    assert rules["cache_heads"] is None


def test_opt_rules_sp_for_low_head_archs():
    r = dryrun.cell_rules(get_arch("gemma-2b"), get_shape("prefill_32k"), opt=True)
    assert r.get("act_q_seq") == ("model",)
    r2 = dryrun.cell_rules(get_arch("internlm2-20b"), get_shape("prefill_32k"), opt=True)
    assert "act_q_seq" not in r2
    r3 = dryrun.cell_rules(get_arch("gemma-2b"), get_shape("train_4k"), opt=True)
    assert r3.get("act_batch") == ("pod", "data", "model")   # DP256


def test_every_cell_is_classified():
    """40 cells: each either applicable or a documented skip."""
    n_run, n_skip = 0, 0
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            if ok:
                n_run += 1
            else:
                n_skip += 1
                assert "DESIGN.md" in why
    assert n_run + n_skip == 40
    assert n_skip == 6


def test_model_flops_estimate_scales():
    cfg = get_arch("gemma-2b")
    tr = model_flops_estimate(cfg, get_shape("train_4k"))
    de = model_flops_estimate(cfg, get_shape("decode_32k"))
    # train: 6*N*(256*4096) tokens; decode: 2*N*128
    assert tr / de == pytest.approx(3 * 256 * 4096 / 128, rel=0.01)
    # MoE counts active params only: deepseek ~21B active vs 236B total
    moe = get_arch("deepseek-v2-236b")
    n_active = model_flops_estimate(moe, get_shape("decode_32k")) / (2 * 128)
    assert 15e9 < n_active < 40e9, n_active


def test_microbatching_matches_single_batch():
    cfg = get_arch("gemma-2b").reduced()
    step1, model = make_train_step(cfg, TrainHyper(microbatches=1))
    step4, _ = make_train_step(cfg, TrainHyper(microbatches=4))
    params = model.init(jax.random.key(0))
    state = (params, adamw_init(params))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab)}
    (_, m1) = jax.jit(step1)(state, batch)[1], None
    out1, met1 = jax.jit(step1)(state, batch)
    out4, met4 = jax.jit(step4)(state, batch)
    assert float(met1["loss"]) == pytest.approx(float(met4["loss"]), rel=1e-3)
    # grad norms differ by clipping granularity but parameters move similarly
    d1 = jax.tree.leaves(out1[0])[0]
    d4 = jax.tree.leaves(out4[0])[0]
    np.testing.assert_allclose(np.asarray(d1, np.float32), np.asarray(d4, np.float32),
                               atol=5e-3)


def test_hw_projection_winner_flips_with_profile():
    from repro.core.hwcompare import project_step_time
    from repro.core.hardware import HW_PROFILES
    compute_bound = {"chips": 256, "flops_global": 5e16, "bytes_global": 1e12,
                     "collective_bytes_global": 1e11}
    mem_bound = {"chips": 256, "flops_global": 1e14, "bytes_global": 5e14,
                 "collective_bytes_global": 1e11}
    a, b = HW_PROFILES["a100_like"], HW_PROFILES["mi210_like"]
    # a100-like wins compute-bound (higher bf16 peak); mi210-like wins
    # memory-bound (higher HBM bw)
    assert project_step_time(compute_bound, a) < project_step_time(compute_bound, b)
    assert project_step_time(mem_bound, a) > project_step_time(mem_bound, b)
