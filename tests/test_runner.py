"""The unified BenchmarkRunner subsystem: scenario-matrix expansion
(filter/exclude/skip), ResultStore round-trips (incl. concurrent appenders
and torn-line recovery), build/executable reuse accounting, donation
threading, sharded process-pool dispatch, and regression detection driven
through the store-backed MetricStore."""
import json
import subprocess
import sys

import jax.numpy as jnp
import pytest

from repro.core.harness import RegressionHook, measure
from repro.core.regression import MetricStore, detect
from repro.runner import (BenchmarkRunner, ResultStore, RunResult, RunnerStats,
                          Scenario, ScenarioMatrix, ShardScheduler,
                          assign_shards)


# ---- scenario matrix ------------------------------------------------------

def test_matrix_expansion_is_full_product():
    m = ScenarioMatrix(archs=["a1", "a2"], tasks=("train", "infer_decode"),
                       batches=(1, 4), seqs=(16,), modes=("jit", "eager"))
    names = [s.name for s in m.expand()]
    assert len(names) == len(set(names)) == 2 * 2 * 2 * 1 * 2
    assert "a1/train/b1/s16/fp32/jit" in names
    assert len(m) == 16


def test_matrix_filter_exclude_skip():
    m = ScenarioMatrix(archs=["gemma-2b", "mamba2-2.7b", "mixtral-8x7b"],
                       tasks=("train", "infer_decode"),
                       filter=[r"gemma|mamba"],          # keep two archs
                       exclude=[r"infer_"],              # drop inference
                       skip=["mamba2-2.7b/train"])       # exact bench skip
    names = [s.name for s in m.expand()]
    assert names == ["gemma-2b/train/b2/s64/fp32/jit_donated"]
    # bare-arch skip (the torchbench SKIP-set idiom)
    m2 = ScenarioMatrix(archs=["gemma-2b", "mamba2-2.7b"], tasks=("train",),
                        skip=["mamba2-2.7b"])
    assert [s.arch for s in m2.expand()] == ["gemma-2b"]


def test_scenario_validation_and_roundtrip():
    with pytest.raises(ValueError):
        Scenario(arch="gemma-2b", task="nope")
    with pytest.raises(ValueError):
        Scenario(arch="gemma-2b", mode="tpu_magic")
    sc = Scenario(arch="gemma-2b", task="train", batch=4, seq=128, mode="jit")
    assert Scenario.from_dict(json.loads(json.dumps(sc.to_dict()))) == sc


def test_runner_session_filter():
    r = BenchmarkRunner()
    r.default_exclude = (r"infer_",)
    m = ScenarioMatrix(archs=["gemma-2b"])
    assert [s.task for s in r.select(m)] == ["train"]


def test_matrix_expansion_is_memoized(monkeypatch):
    """__len__/__iter__/expand share one cached expansion until a field
    changes (the product + regex selection used to re-run every call)."""
    import repro.runner.scenario as scenario_mod
    calls = {"n": 0}
    real = scenario_mod.select_scenarios

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(scenario_mod, "select_scenarios", counting)
    m = ScenarioMatrix(archs=["a1", "a2"], tasks=("train",), filter=[r"a\d"])
    first = m.expand()
    assert len(m) == 2 and list(m) == first and m.expand() == first
    assert calls["n"] == 1
    # mutating a field invalidates the cache
    m.archs = ["a1"]
    assert len(m) == 1
    assert calls["n"] == 2
    # expand() hands out copies: callers can't poison the cache
    m.expand().clear()
    assert len(m) == 1


# ---- sharded dispatch -----------------------------------------------------

def test_assign_shards_deterministic_by_build_key():
    scs = [Scenario(arch=a, task=t, batch=1, seq=8, dtype=d)
           for a in ("a1", "a2", "a3")
           for d in ("fp32", "bf16")
           for t in ("train", "infer_decode")]
    shards = assign_shards(scs, 2)
    # deterministic: same input, same partition
    assert shards == assign_shards(list(scs), 2)
    # complete and disjoint
    assert sorted(i for s in shards for i in s) == list(range(len(scs)))
    # all scenarios of one build_key land on one shard
    for key in {sc.build_key() for sc in scs}:
        owners = {j for j, shard in enumerate(shards)
                  for i in shard if scs[i].build_key() == key}
        assert len(owners) == 1, (key, owners)
    # more jobs than groups leaves the surplus shards empty, loses nothing
    wide = assign_shards(scs[:2], 4)
    assert sorted(i for s in wide for i in s) == [0, 1]
    assert sum(bool(s) for s in wide) == 1   # one build_key -> one worker


def test_runner_stats_merge():
    a = RunnerStats(model_builds=1, scenarios_run=2, errors=1)
    a.merge({"model_builds": 2, "executable_builds": 3, "bogus_key": 9})
    a.merge(RunnerStats(scenarios_run=1))
    assert a.model_builds == 3 and a.executable_builds == 3
    assert a.scenarios_run == 3 and a.errors == 1


def test_shard_worker_crash_becomes_error_records():
    """A dying worker costs its in-flight cell (error record), not the
    sweep: the scheduler respawns it for the shard's remaining cells."""
    sched = ShardScheduler(2, runs=1, warmup=0)
    try:
        for w in sched._workers:   # doomed stand-in for a crashy worker
            w.argv = [sys.executable, "-c",
                      "import sys; sys.stdin.readline(); sys.exit(7)"]
        scs = [Scenario(arch="gemma-2b", task="train", batch=1, seq=8),
               Scenario(arch="gemma-2b", task="train", batch=1, seq=8,
                        dtype="bf16")]
        results, stats = sched.run(scs)
    finally:
        sched.close()
    assert [r.status for r in results] == ["error", "error"]
    assert all("exit 7" in r.error for r in results)
    assert {r.extra["shard"] for r in results} == {0, 1}
    assert stats.scenarios_run == 2 and stats.errors == 2


def test_sharded_matrix_matches_serial(tmp_path):
    """jobs=2 returns the same scenario set/statuses as the serial path,
    merges worker stats into the parent, and records shard metadata."""
    m = ScenarioMatrix(archs=["gemma-2b"], tasks=("train",),
                       batches=(1,), seqs=(8,), dtypes=("fp32", "bf16"))
    serial = BenchmarkRunner(runs=1, warmup=0)
    serial_rrs = serial.run_matrix(m)

    store = ResultStore(str(tmp_path / "s"))
    sharded = BenchmarkRunner(store=store, runs=1, warmup=0, jobs=2)
    try:
        shard_rrs = sharded.run_matrix(m)
        rerun = sharded.run_matrix(m)   # same persistent pool, warm caches
    finally:
        sharded.close()

    assert [(r.name, r.status) for r in shard_rrs] == \
        [(r.name, r.status) for r in serial_rrs]
    assert all(r.status == "ok" and r.median_us > 0 for r in shard_rrs)
    # one build_key per dtype -> one worker each, results in matrix order
    assert {r.extra["shard"] for r in shard_rrs} == {0, 1}
    assert all(r.extra["isolated"] for r in shard_rrs)
    # worker builds/compiles are visible in the parent's merged stats;
    # the second run_matrix hit the persistent workers' caches (no new
    # builds) and merged only the DELTA, not the cumulative worker
    # counters again
    assert all(r.status == "ok" for r in rerun)
    assert sharded.stats.model_builds == 2
    assert sharded.stats.executable_builds == 2
    assert sharded.stats.executable_cache_hits == 2
    assert sharded.stats.scenarios_run == 4 and sharded.stats.errors == 0
    # every cell landed in the store from the worker-reader threads
    assert len(list(store.history())) == 4


def test_isolated_run_propagates_worker_stats(tmp_path):
    """isolate=True merges the worker's RunnerStats and ships them in
    extra["worker_stats"] (out-of-process builds used to be invisible)."""
    r = BenchmarkRunner(store=ResultStore(str(tmp_path / "s")),
                        runs=1, warmup=0, isolate=True)
    rr = r.run(Scenario(arch="gemma-2b", task="train", batch=1, seq=8))
    assert rr.status == "ok" and rr.extra["isolated"]
    assert rr.extra["worker_stats"]["model_builds"] == 1
    assert r.stats.model_builds == 1 and r.stats.scenarios_run == 1
    assert r.stats.errors == 0


# ---- result store ---------------------------------------------------------

def test_result_store_roundtrip_and_latest_pointer(tmp_path):
    store = ResultStore(str(tmp_path / "store"))
    sc = Scenario(arch="gemma-2b", task="train", batch=1, seq=8)
    class _M:  # minimal Measurement stand-in
        median_us, mean_us, p10_us, p90_us = 10.0, 11.0, 9.0, 12.0
        compile_us, host_peak_bytes, device_bytes_delta, runs = 100.0, 7, 3, 2
    store.append(RunResult.from_measurement(sc, _M))
    store.append(RunResult.from_measurement(sc, type("M2", (_M,), {"median_us": 20.0})))
    # latest pointer holds the second record; the log holds both
    fresh = ResultStore(str(tmp_path / "store"))
    latest = fresh.latest_result(sc.name)
    assert latest is not None and latest.median_us == 20.0
    assert latest.schema == 1 and latest.status == "ok"
    assert [r["median_us"] for r in fresh.history(sc.name)] == [10.0, 20.0]
    assert [r.name for r in fresh.results()] == [sc.name]


def test_metric_store_on_result_store(tmp_path):
    """regression.detect driven through the ResultStore-backed MetricStore."""
    path = str(tmp_path / "metrics.json")
    store = MetricStore(path)
    store.update("bench/a", {"median_us": 100.0, "host_peak_bytes": 1000})
    store.update("bench/a", {"median_us": 110.0, "host_peak_bytes": 1000})
    # the latest pointer file keeps the historical single-JSON format
    with open(path) as f:
        assert json.load(f)["bench/a"]["median_us"] == 110.0
    # the JSONL log replays both baselines
    assert [r["median_us"] for r in store.history("bench/a")] == [100.0, 110.0]
    # reload + detect against the latest baseline
    store2 = MetricStore(path)
    assert detect(store2, "bench/a", {"median_us": 115.0}) == []
    issues = detect(store2, "bench/a", {"median_us": 130.0})
    assert len(issues) == 1 and issues[0].increase > 0.07
    assert store2.baseline("missing") is None


def test_result_store_skips_corrupt_jsonl_lines(tmp_path):
    """A torn/truncated log line (writer killed mid-append) must not abort
    the history replay — skip and count it."""
    store = ResultStore(str(tmp_path / "store"))
    store.append({"name": "a", "median_us": 1.0})
    with open(store.log_path, "a") as f:
        f.write('{"name": "torn", "median_us": 2.\n')   # killed mid-write
        f.write("[1, 2, 3]\n")                          # non-record JSON
    store.append({"name": "b", "median_us": 3.0})
    replay = list(store.history())
    assert [r["name"] for r in replay] == ["a", "b"]
    assert store.corrupt_lines == 2


def test_result_store_concurrent_append_two_processes(tmp_path):
    """Two processes appending to one store: every log line stays intact
    (single O_APPEND writes) and the latest pointer merges both writers."""
    path = str(tmp_path / "store")
    ResultStore(path)   # create the layout up front
    script = (
        "import sys\n"
        "from repro.runner import ResultStore\n"
        "store = ResultStore(sys.argv[1])\n"
        "tag = sys.argv[2]\n"
        "for i in range(20):\n"
        "    store.append({'name': f'{tag}/{i}', 'median_us': float(i)})\n"
    )
    from repro.runner.pool import _subprocess_env
    procs = [subprocess.Popen([sys.executable, "-c", script, path, tag],
                              env=_subprocess_env())
             for tag in ("w1", "w2")]
    for p in procs:
        assert p.wait(timeout=60) == 0
    fresh = ResultStore(path)
    replay = list(fresh.history())
    assert len(replay) == 40 and fresh.corrupt_lines == 0
    assert len(fresh.latest) == 40
    assert {r["name"] for r in replay} == set(fresh.latest)


# ---- execution + reuse ----------------------------------------------------

def test_runner_reuse_accounting(tmp_path):
    r = BenchmarkRunner(store=ResultStore(str(tmp_path / "s")), runs=2, warmup=0)
    sc = Scenario(arch="gemma-2b", task="train", batch=1, seq=8)
    r1 = r.run(sc)
    assert r1.status == "ok" and r1.median_us > 0
    assert r.stats.model_builds == 1 and r.stats.executable_cache_hits == 0
    assert r1.cache == {"model_reused": False, "executable_reused": False}
    # same scenario again: executable cache hit, no new build/compile
    r2 = r.run(sc)
    assert r2.status == "ok"
    assert r.stats.model_builds == 1 and r.stats.executable_cache_hits == 1
    assert r2.cache == {"model_reused": True, "executable_reused": True}
    assert r2.compile_us == 0.0   # nothing compiled on a cache hit
    # different task of the same arch: model build reused, new executable
    r3 = r.run(Scenario(arch="gemma-2b", task="infer_decode", batch=1, seq=8))
    assert r3.status == "ok"
    assert r.stats.model_builds == 1 and r.stats.model_cache_hits >= 1
    assert r3.cache["model_reused"] and not r3.cache["executable_reused"]
    # all three runs landed in the store
    assert len(list(r.store.history())) == 3


def test_runner_error_containment():
    r = BenchmarkRunner(runs=1, warmup=0)
    rr = r.run(Scenario(arch="no-such-arch"))
    assert rr.status == "error" and "no-such-arch" in rr.error
    assert r.stats.errors == 1


class _ExplodingHook(RegressionHook):
    def fire(self):
        raise RuntimeError("boom mid-measure")


def test_runner_evicts_poisoned_donated_executable():
    """A mid-measure failure may leave the cached executable's donated args
    consumed; the entry must be evicted so the next run rebuilds cleanly."""
    r = BenchmarkRunner(runs=2, warmup=0)
    sc = Scenario(arch="gemma-2b", task="train", batch=1, seq=8)
    assert r.run(sc).status == "ok"
    bad = r.run(sc, hook=_ExplodingHook())
    assert bad.status == "error" and "boom" in bad.error
    ok = r.run(sc)   # must not reuse the half-consumed cached args
    assert ok.status == "ok" and ok.median_us > 0


def test_measure_donation_consumes_and_threads():
    """The donate satellite: donate_argnums is actually passed, the donated
    input is consumed, and the threaded state keeps subsequent calls valid."""
    def step(state, x):
        return state + x, state.sum()

    args = (jnp.ones(8), jnp.ones(8))
    m = measure("donated", step, args, donate=(0,), runs=3)
    assert m.runs == 3 and m.median_us > 0
    assert args[0].is_deleted()        # state buffer was donated
    assert not args[1].is_deleted()    # batch arg was not


def test_runner_donated_scenario_repeats(tmp_path):
    """Cached executables stay callable across re-measures even though their
    state buffers are donated (the threaded args are kept in the cache)."""
    r = BenchmarkRunner(runs=2, warmup=0)
    sc = Scenario(arch="gemma-2b", task="train", batch=1, seq=8,
                  mode="jit_donated")
    for _ in range(3):
        assert r.run(sc).status == "ok"
    assert r.stats.executable_cache_hits == 2
