"""The unified BenchmarkRunner subsystem: scenario-matrix expansion
(filter/exclude/skip), ResultStore round-trips, build/executable reuse
accounting, donation threading, and regression detection driven through the
store-backed MetricStore."""
import json

import jax.numpy as jnp
import pytest

from repro.core.harness import RegressionHook, measure
from repro.core.regression import MetricStore, detect
from repro.runner import (BenchmarkRunner, ResultStore, RunResult, Scenario,
                          ScenarioMatrix)


# ---- scenario matrix ------------------------------------------------------

def test_matrix_expansion_is_full_product():
    m = ScenarioMatrix(archs=["a1", "a2"], tasks=("train", "infer_decode"),
                       batches=(1, 4), seqs=(16,), modes=("jit", "eager"))
    names = [s.name for s in m.expand()]
    assert len(names) == len(set(names)) == 2 * 2 * 2 * 1 * 2
    assert "a1/train/b1/s16/fp32/jit" in names
    assert len(m) == 16


def test_matrix_filter_exclude_skip():
    m = ScenarioMatrix(archs=["gemma-2b", "mamba2-2.7b", "mixtral-8x7b"],
                       tasks=("train", "infer_decode"),
                       filter=[r"gemma|mamba"],          # keep two archs
                       exclude=[r"infer_"],              # drop inference
                       skip=["mamba2-2.7b/train"])       # exact bench skip
    names = [s.name for s in m.expand()]
    assert names == ["gemma-2b/train/b2/s64/fp32/jit_donated"]
    # bare-arch skip (the torchbench SKIP-set idiom)
    m2 = ScenarioMatrix(archs=["gemma-2b", "mamba2-2.7b"], tasks=("train",),
                        skip=["mamba2-2.7b"])
    assert [s.arch for s in m2.expand()] == ["gemma-2b"]


def test_scenario_validation_and_roundtrip():
    with pytest.raises(ValueError):
        Scenario(arch="gemma-2b", task="nope")
    with pytest.raises(ValueError):
        Scenario(arch="gemma-2b", mode="tpu_magic")
    sc = Scenario(arch="gemma-2b", task="train", batch=4, seq=128, mode="jit")
    assert Scenario.from_dict(json.loads(json.dumps(sc.to_dict()))) == sc


def test_runner_session_filter():
    r = BenchmarkRunner()
    r.default_exclude = (r"infer_",)
    m = ScenarioMatrix(archs=["gemma-2b"])
    assert [s.task for s in r.select(m)] == ["train"]


# ---- result store ---------------------------------------------------------

def test_result_store_roundtrip_and_latest_pointer(tmp_path):
    store = ResultStore(str(tmp_path / "store"))
    sc = Scenario(arch="gemma-2b", task="train", batch=1, seq=8)
    class _M:  # minimal Measurement stand-in
        median_us, mean_us, p10_us, p90_us = 10.0, 11.0, 9.0, 12.0
        compile_us, host_peak_bytes, device_bytes_delta, runs = 100.0, 7, 3, 2
    store.append(RunResult.from_measurement(sc, _M))
    store.append(RunResult.from_measurement(sc, type("M2", (_M,), {"median_us": 20.0})))
    # latest pointer holds the second record; the log holds both
    fresh = ResultStore(str(tmp_path / "store"))
    latest = fresh.latest_result(sc.name)
    assert latest is not None and latest.median_us == 20.0
    assert latest.schema == 1 and latest.status == "ok"
    assert [r["median_us"] for r in fresh.history(sc.name)] == [10.0, 20.0]
    assert [r.name for r in fresh.results()] == [sc.name]


def test_metric_store_on_result_store(tmp_path):
    """regression.detect driven through the ResultStore-backed MetricStore."""
    path = str(tmp_path / "metrics.json")
    store = MetricStore(path)
    store.update("bench/a", {"median_us": 100.0, "host_peak_bytes": 1000})
    store.update("bench/a", {"median_us": 110.0, "host_peak_bytes": 1000})
    # the latest pointer file keeps the historical single-JSON format
    with open(path) as f:
        assert json.load(f)["bench/a"]["median_us"] == 110.0
    # the JSONL log replays both baselines
    assert [r["median_us"] for r in store.history("bench/a")] == [100.0, 110.0]
    # reload + detect against the latest baseline
    store2 = MetricStore(path)
    assert detect(store2, "bench/a", {"median_us": 115.0}) == []
    issues = detect(store2, "bench/a", {"median_us": 130.0})
    assert len(issues) == 1 and issues[0].increase > 0.07
    assert store2.baseline("missing") is None


# ---- execution + reuse ----------------------------------------------------

def test_runner_reuse_accounting(tmp_path):
    r = BenchmarkRunner(store=ResultStore(str(tmp_path / "s")), runs=2, warmup=0)
    sc = Scenario(arch="gemma-2b", task="train", batch=1, seq=8)
    r1 = r.run(sc)
    assert r1.status == "ok" and r1.median_us > 0
    assert r.stats.model_builds == 1 and r.stats.executable_cache_hits == 0
    assert r1.cache == {"model_reused": False, "executable_reused": False}
    # same scenario again: executable cache hit, no new build/compile
    r2 = r.run(sc)
    assert r2.status == "ok"
    assert r.stats.model_builds == 1 and r.stats.executable_cache_hits == 1
    assert r2.cache == {"model_reused": True, "executable_reused": True}
    assert r2.compile_us == 0.0   # nothing compiled on a cache hit
    # different task of the same arch: model build reused, new executable
    r3 = r.run(Scenario(arch="gemma-2b", task="infer_decode", batch=1, seq=8))
    assert r3.status == "ok"
    assert r.stats.model_builds == 1 and r.stats.model_cache_hits >= 1
    assert r3.cache["model_reused"] and not r3.cache["executable_reused"]
    # all three runs landed in the store
    assert len(list(r.store.history())) == 3


def test_runner_error_containment():
    r = BenchmarkRunner(runs=1, warmup=0)
    rr = r.run(Scenario(arch="no-such-arch"))
    assert rr.status == "error" and "no-such-arch" in rr.error
    assert r.stats.errors == 1


class _ExplodingHook(RegressionHook):
    def fire(self):
        raise RuntimeError("boom mid-measure")


def test_runner_evicts_poisoned_donated_executable():
    """A mid-measure failure may leave the cached executable's donated args
    consumed; the entry must be evicted so the next run rebuilds cleanly."""
    r = BenchmarkRunner(runs=2, warmup=0)
    sc = Scenario(arch="gemma-2b", task="train", batch=1, seq=8)
    assert r.run(sc).status == "ok"
    bad = r.run(sc, hook=_ExplodingHook())
    assert bad.status == "error" and "boom" in bad.error
    ok = r.run(sc)   # must not reuse the half-consumed cached args
    assert ok.status == "ok" and ok.median_us > 0


def test_measure_donation_consumes_and_threads():
    """The donate satellite: donate_argnums is actually passed, the donated
    input is consumed, and the threaded state keeps subsequent calls valid."""
    def step(state, x):
        return state + x, state.sum()

    args = (jnp.ones(8), jnp.ones(8))
    m = measure("donated", step, args, donate=(0,), runs=3)
    assert m.runs == 3 and m.median_us > 0
    assert args[0].is_deleted()        # state buffer was donated
    assert not args[1].is_deleted()    # batch arg was not


def test_runner_donated_scenario_repeats(tmp_path):
    """Cached executables stay callable across re-measures even though their
    state buffers are donated (the threaded args are kept in the cache)."""
    r = BenchmarkRunner(runs=2, warmup=0)
    sc = Scenario(arch="gemma-2b", task="train", batch=1, seq=8,
                  mode="jit_donated")
    for _ in range(3):
        assert r.run(sc).status == "ok"
    assert r.stats.executable_cache_hits == 2
