"""Provenance stamping: who/what/where produced a ``RunResult``.

Every result-creating path in the runner stamps these well-known
``extra`` keys (schema stays v1 — see ``runner/results.py``):

    prov_commit     git HEAD sha ("unknown" outside a repo)
    prov_dirty      True when the working tree had local modifications
    prov_backend    ``jax.default_backend()`` of the measuring process
    prov_host       hostname of the measuring process
    prov_jax        jax.__version__
    prov_python     platform.python_version()

Workers stamp in their own process so host/backend reflect where the
number was actually measured; dispatcher-side stamping uses setdefault
semantics and only fills records created locally (e.g. worker-death
error results).

Collection is cached per process — two subprocess calls (git) and one
jax attribute read, once.
"""
from __future__ import annotations

import os
import platform
import socket
import subprocess
from typing import Any, Dict, Optional

__all__ = ["collect", "stamp", "provenance_key", "PROV_KEYS"]

PROV_KEYS = ("prov_commit", "prov_dirty", "prov_backend", "prov_host",
             "prov_jax", "prov_python")

_CACHE: Optional[Dict[str, Any]] = None


def _git(*args: str) -> Optional[str]:
    try:
        out = subprocess.run(
            ("git",) + args, cwd=os.path.dirname(os.path.abspath(__file__)),
            stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
            timeout=10, check=False)
    except (OSError, subprocess.TimeoutExpired):
        return None
    if out.returncode != 0:
        return None
    return out.stdout.decode("utf-8", "replace").strip()


def collect(refresh: bool = False) -> Dict[str, Any]:
    """The provenance dict for this process (cached)."""
    global _CACHE
    if _CACHE is not None and not refresh:
        return _CACHE
    commit = os.environ.get("REPRO_COMMIT") or _git("rev-parse", "HEAD") \
        or "unknown"
    status = _git("status", "--porcelain")
    dirty = bool(status) if status is not None else False
    try:
        import jax
        backend = jax.default_backend()
        jax_ver = jax.__version__
    except Exception:   # pragma: no cover - jax is a hard dep in practice
        backend, jax_ver = "unknown", "unknown"
    _CACHE = {
        "prov_commit": commit,
        "prov_dirty": dirty,
        "prov_backend": backend,
        "prov_host": socket.gethostname(),
        "prov_jax": jax_ver,
        "prov_python": platform.python_version(),
    }
    return _CACHE


def stamp(result: Any, *, overwrite: bool = False) -> Any:
    """Fill ``result.extra`` with provenance keys (setdefault unless
    *overwrite*).  Accepts a ``RunResult`` or a plain extras dict."""
    extra = result if isinstance(result, dict) else result.extra
    for k, v in collect().items():
        if overwrite:
            extra[k] = v
        else:
            extra.setdefault(k, v)
    return result


def provenance_key(extra: Dict[str, Any]) -> str:
    """Compact grouping key: ``<commit12>[+dirty]/<backend>/<host>``.

    Works on any dict carrying ``prov_*`` keys (a ``RunResult.extra`` or
    a serialized history record's ``extra``).
    """
    commit = str(extra.get("prov_commit", "unknown"))[:12]
    if extra.get("prov_dirty"):
        commit += "+dirty"
    return "/".join((commit, str(extra.get("prov_backend", "?")),
                     str(extra.get("prov_host", "?"))))
