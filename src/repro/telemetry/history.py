"""Provenance-keyed time series over ``ResultStore.history()``.

The append-only run log already survives months of concurrent
appenders; this module turns it into a queryable trajectory:

- :func:`series` groups full history records by
  ``(scenario name, provenance key)`` — records without ``prov_*``
  extras (e.g. ``MetricStore`` baseline rows) are not trajectory points
  and are skipped.
- :func:`rolling_baseline` / :func:`drift` give each series a rolling
  median baseline and flag the newest point against it, reusing the
  paper's 7% ``core/regression.detect`` threshold and metric set.
- :func:`trajectory` ranks the drifts across every series into a
  ``profiler/report.py`` report (same JSON shape and text table as the
  inefficiency findings), so nightly trend review reads like the
  profiler's.

``core/ci.py run_nightly`` appends one provenance-stamped point per
cell each night; ``benchmarks/history_report.py`` renders the view.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.regression import METRICS, THRESHOLD, Issue
from repro.profiler.detectors import Finding
from repro.profiler.report import build_report
from repro.telemetry.provenance import provenance_key

__all__ = ["series", "rolling_baseline", "drift", "trajectory",
           "SERIES_METRICS"]

#: metric fields carried into each series point (superset of the
#: regression metric tuple, so serve/throughput trends are visible too)
SERIES_METRICS = ("median_us", "mean_us", "compile_us", "host_peak_bytes",
                  "device_bytes_delta")

SeriesKey = Tuple[str, str]            # (scenario name, provenance key)


def _result_store(store: Any):
    """Accept a ``ResultStore`` or anything wrapping one (``MetricStore``)."""
    return getattr(store, "_store", store)


def _point(rec: Dict[str, Any]) -> Dict[str, Any]:
    extra = rec.get("extra") or {}
    pt = {"ts": float(rec.get("ts", 0.0)),
          "status": rec.get("status", "ok")}
    for m in SERIES_METRICS:
        v = rec.get(m)
        if isinstance(v, (int, float)):
            pt[m] = float(v)
    for k in ("tok_per_s", "prov_commit", "prov_dirty"):
        if k in extra:
            pt[k] = extra[k]
    return pt


def series(store: Any, *, name: Optional[str] = None
           ) -> Dict[SeriesKey, List[Dict[str, Any]]]:
    """Group the run log into per-(scenario, provenance) series, each
    sorted by timestamp.  Only records carrying provenance extras
    qualify — the log may interleave baseline rows and foreign records."""
    out: Dict[SeriesKey, List[Dict[str, Any]]] = {}
    for rec in _result_store(store).history(name):
        extra = rec.get("extra")
        if not isinstance(extra, dict) or "prov_commit" not in extra:
            continue
        rec_name = rec.get("name")
        if not rec_name:
            continue
        key = (str(rec_name), provenance_key(extra))
        out.setdefault(key, []).append(_point(rec))
    for pts in out.values():
        pts.sort(key=lambda p: p["ts"])
    return out


def rolling_baseline(points: List[Dict[str, Any]], *, window: int = 5,
                     metrics: Iterable[str] = METRICS) -> Dict[str, float]:
    """Median of the last *window* ok points per metric (the rolling
    baseline the newest point is judged against)."""
    ok = [p for p in points if p.get("status") == "ok"]
    tail = ok[-window:]
    base: Dict[str, float] = {}
    for m in metrics:
        vals = sorted(p[m] for p in tail if isinstance(p.get(m), float))
        if vals:
            base[m] = vals[len(vals) // 2]
    return base


def drift(points: List[Dict[str, Any]], *, threshold: float = THRESHOLD,
          window: int = 5, metrics: Iterable[str] = METRICS,
          benchmark: str = "") -> List[Issue]:
    """Flag the newest ok point against the rolling baseline of the
    points before it.  Same semantics as ``regression.detect`` (relative
    increase past *threshold*), so CI and trajectory review agree."""
    ok = [p for p in points if p.get("status") == "ok"]
    if len(ok) < 2:
        return []
    base = rolling_baseline(ok[:-1], window=window, metrics=metrics)
    newest = ok[-1]
    issues: List[Issue] = []
    for m in metrics:
        b = base.get(m)
        o = newest.get(m)
        if not b or o is None or b <= 0:
            continue
        inc = (o - b) / b
        if inc > threshold:
            issues.append(Issue(benchmark=benchmark, metric=m, baseline=b,
                                observed=o, increase=inc))
    return issues


def _severity(increase: float, threshold: float) -> str:
    return "crit" if increase > 4 * threshold else "warn"


def trajectory(store: Any, *, window: int = 5, threshold: float = THRESHOLD,
               min_points: int = 2) -> Dict[str, Any]:
    """The ranked drift report over every provenance-keyed series.

    Returns a ``profiler/report.py``-shaped dict; render it with
    ``profiler.report.format_table``.  ``meta["series"]`` summarises
    each qualifying series (first/last value, trend) so the report is
    useful even when nothing drifted.
    """
    ser = series(store)
    findings: List[Finding] = []
    summaries: List[Dict[str, Any]] = []
    records: List[Dict[str, Any]] = []
    for (name, prov), points in sorted(ser.items()):
        if len(points) < min_points:
            continue
        ok = [p for p in points if p.get("status") == "ok"]
        med = [p.get("median_us") for p in ok
               if isinstance(p.get("median_us"), float)]
        summaries.append({
            "name": name,
            "provenance": prov,
            "points": len(points),
            "ok": len(ok),
            "first_median_us": med[0] if med else None,
            "last_median_us": med[-1] if med else None,
            "trend": ((med[-1] - med[0]) / med[0]
                      if len(med) >= 2 and med[0] > 0 else 0.0),
        })
        records.append({"name": name, "status": "ok" if ok else "error"})
        for issue in drift(points, threshold=threshold, window=window,
                           benchmark=name):
            findings.append(Finding(
                rule="perf_drift",
                severity=_severity(issue.increase, threshold),
                cell=name,
                summary=(f"{issue.metric} +{issue.increase:.0%} vs rolling "
                         f"baseline ({issue.baseline:.1f} -> "
                         f"{issue.observed:.1f})"),
                score=issue.increase,
                evidence={"provenance": prov, "metric": issue.metric,
                          "baseline": issue.baseline,
                          "observed": issue.observed,
                          "points": len(points), "window": window},
            ))
    sev_rank = {"crit": 0, "warn": 1, "info": 2}
    findings.sort(key=lambda f: (sev_rank.get(f.severity, 3), -f.score))
    return build_report(records, findings,
                        meta={"kind": "trajectory", "window": window,
                              "threshold": threshold,
                              "series": summaries})
