"""Trace export: Chrome trace-event JSON (Perfetto-loadable) + a text
flame summary for terminals.

The Chrome format is the ``traceEvents`` array flavour: one ``"X"``
(complete) event per span with microsecond ``ts``/``dur``, one process
lane (``pid``) per span ``proc`` (coordinator, shard0.., local0.. /
remote hosts), and ``"M"`` metadata events naming the lanes.  Span ids,
parents and attrs ride in ``args`` so the nesting test and the smoke
gate can reconstruct the tree from the file alone.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

__all__ = ["chrome_trace", "save_trace", "flame_summary"]


def _as_dicts(spans: Iterable) -> List[Dict[str, Any]]:
    """Accept ``Tracer.export()`` dicts or live ``Span`` objects."""
    return [sp if isinstance(sp, dict) else sp.to_dict() for sp in spans]


def chrome_trace(spans: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Convert ``Tracer.export()`` span dicts to a Chrome trace dict."""
    spans = _as_dicts(spans)
    # stable small pids: coordinator first, then lanes by first appearance
    pids: Dict[str, int] = {}
    for sp in spans:
        proc = str(sp.get("proc", "?"))
        if proc not in pids:
            pids[proc] = 1 + len(pids) if proc != "coordinator" else 0
    if "coordinator" in pids and pids["coordinator"] != 0:
        # renumber so the coordinator lane is pid 0 at the top
        order = ["coordinator"] + [p for p in pids if p != "coordinator"]
        pids = {p: i for i, p in enumerate(order)}
    # per-proc compact tids
    tids: Dict[str, Dict[int, int]] = {}
    events: List[Dict[str, Any]] = []
    for proc, pid in pids.items():
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": 0, "args": {"name": proc}})
    for sp in spans:
        proc = str(sp.get("proc", "?"))
        pid = pids[proc]
        raw_tid = int(sp.get("tid", 0))
        lane = tids.setdefault(proc, {})
        tid = lane.setdefault(raw_tid, len(lane))
        args: Dict[str, Any] = {
            "span_id": sp.get("span_id"),
            "parent": sp.get("parent_id"),
            "kind": sp.get("kind"),
        }
        attrs = sp.get("attrs")
        if attrs:
            args.update(attrs)
        events.append({
            "ph": "X",
            "name": str(sp.get("name", "?")),
            "cat": str(sp.get("kind", "span")),
            "pid": pid,
            "tid": tid,
            "ts": float(sp.get("ts", 0.0)) * 1e6,
            "dur": max(0.0, float(sp.get("dur_s", 0.0))) * 1e6,
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def save_trace(spans: Iterable[Dict[str, Any]], path: str) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(spans), f)
    return path


def _fmt_dur(dur_s: float) -> str:
    if dur_s >= 1.0:
        return f"{dur_s:.2f}s"
    if dur_s >= 1e-3:
        return f"{dur_s * 1e3:.1f}ms"
    return f"{dur_s * 1e6:.0f}us"


def flame_summary(spans: Iterable[Dict[str, Any]], *, max_depth: int = 8,
                  max_children: int = 24) -> str:
    """Indented span tree, durations inline — a flame graph for
    terminals.  Children are shown in start order; long sibling runs
    (e.g. hundreds of decode steps) are elided with a count."""
    spans = sorted(_as_dicts(spans), key=lambda s: float(s.get("ts", 0.0)))
    by_id = {sp.get("span_id"): sp for sp in spans}
    kids: Dict[Optional[str], List[Dict[str, Any]]] = {}
    for sp in spans:
        parent = sp.get("parent_id")
        if parent not in by_id:
            parent = None
        kids.setdefault(parent, []).append(sp)

    lines: List[str] = []

    def emit(sp: Dict[str, Any], depth: int) -> None:
        pad = "  " * depth
        name = str(sp.get("name", "?"))
        proc = str(sp.get("proc", ""))
        lane = f" [{proc}]" if proc and proc != "coordinator" else ""
        lines.append(f"{pad}{name} {_fmt_dur(float(sp.get('dur_s', 0.0)))}"
                     f"{lane}")
        if depth + 1 >= max_depth:
            return
        children = kids.get(sp.get("span_id"), [])
        for child in children[:max_children]:
            emit(child, depth + 1)
        if len(children) > max_children:
            rest = children[max_children:]
            total = sum(float(c.get("dur_s", 0.0)) for c in rest)
            lines.append(f"{'  ' * (depth + 1)}... {len(rest)} more "
                         f"({_fmt_dur(total)})")

    roots = kids.get(None, [])
    if not roots:
        return "(no spans)"
    for root in roots:
        emit(root, 0)
    return "\n".join(lines)
