"""Low-overhead distributed span tracing for the benchmark runner.

One trace covers one ``run_matrix`` call regardless of transport.  The
span hierarchy is::

    matrix                          (coordinator lane)
      group:<build-key>             (one per build-key group)
        cell:<scenario>             (serial) or
        dispatch:<scenario>         (pool / cluster dispatch slot)
          cell:<scenario>           (worker lane, stitched by trace ctx)
            build / compile / warm / measure / attribute   (phases)
              admit_wave / decode_step                     (serve only)

Design constraints:

- **Cheap when off.**  ``Tracer(enabled=False)`` (the module singleton
  ``NULL_TRACER``) makes ``span()`` yield a shared no-op object without
  allocating; instrumented code never branches on anything else.
- **Thread-safe.**  The shard pool drives one thread per worker; spans
  append under a lock and the implicit parent stack is thread-local.
- **Wire-friendly.**  A span context is two strings
  (``{"trace_id", "parent"}``) carried by the JSONL job protocol; a
  worker builds a private ``Tracer`` seeded with them, runs the cell,
  and ships ``export()`` back in the result message.  The dispatcher
  ``ingest()``s those dicts under the worker's lane so the stitched
  timeline nests worker cells beneath their coordinator dispatch span.

Timestamps are wall-clock (``time.time()``) so same-host processes
share a base; durations come from paired wall reads, which is plenty at
the >=microsecond scale of benchmark phases.
"""
from __future__ import annotations

import collections
import itertools
import json
import os
import sys
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

__all__ = [
    "Span",
    "Tracer",
    "NULL_TRACER",
    "group_label",
    "warn",
    "recent_warnings",
]


def _new_prefix() -> str:
    # unique across processes (pid) and across Tracer instances within a
    # process (urandom); span ids are then "<prefix>.<counter>"
    return f"{os.getpid():x}-{os.urandom(3).hex()}"


def group_label(build_key: Tuple) -> str:
    """Human-readable label for a ``Scenario.build_key()`` tuple."""
    return "/".join(str(p) for p in build_key if p not in (None, False, ""))


class Span:
    """One timed region.  Mutable until :meth:`Tracer.finish` seals it."""

    __slots__ = ("name", "span_id", "parent_id", "kind", "proc", "tid",
                 "ts", "dur_s", "attrs", "_t0")

    def __init__(self, name: str, span_id: str, parent_id: Optional[str],
                 kind: str, proc: str, tid: int, ts: float,
                 attrs: Optional[Dict[str, Any]] = None):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.kind = kind
        self.proc = proc
        self.tid = tid
        self.ts = ts              # wall-clock start (time.time())
        self.dur_s = 0.0
        self.attrs = attrs or {}
        self._t0 = 0.0            # perf_counter at start, 0 when retroactive

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "name": self.name,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "kind": self.kind,
            "proc": self.proc,
            "tid": self.tid,
            "ts": self.ts,
            "dur_s": self.dur_s,
        }
        if self.attrs:
            d["attrs"] = self.attrs
        return d


class _NoopSpan:
    """Shared do-nothing span handed out by disabled tracers."""

    __slots__ = ()
    name = ""
    span_id = ""
    parent_id = None
    kind = ""
    proc = ""
    tid = 0
    ts = 0.0
    dur_s = 0.0
    attrs: Dict[str, Any] = {}

    def set(self, **attrs: Any) -> "_NoopSpan":
        return self

    def to_dict(self) -> Dict[str, Any]:
        return {}


_NOOP = _NoopSpan()


class _SpanCtx:
    """Context manager returned by :meth:`Tracer.span` (one allocation,
    reused for the with-statement protocol only)."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Union[Span, _NoopSpan]):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Union[Span, _NoopSpan]:
        return self._span

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._span is not _NOOP:
            if exc is not None:
                self._span.set(error=f"{exc_type.__name__}: {exc}"[:200])
            self._tracer.finish(self._span)


class Tracer:
    """Collects spans for one process's view of a trace.

    ``enabled=False`` turns every entry point into a near-free no-op so
    the instrumented hot path costs one attribute load + branch.
    """

    def __init__(self, *, enabled: bool = True, trace_id: Optional[str] = None,
                 proc: str = "coordinator", root_parent: Optional[str] = None):
        self.enabled = enabled
        self.proc = proc
        self.trace_id = trace_id or _new_prefix()
        self.root_parent = root_parent   # default parent when stack empty
        self._prefix = _new_prefix()
        self._counter = itertools.count(1)
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._by_id: Dict[str, Span] = {}
        self._tls = threading.local()

    # -- trace lifecycle ------------------------------------------------

    def begin_trace(self) -> str:
        """Start a fresh trace id (one per ``run_matrix`` call).

        Spans already collected are kept — a multi-matrix session
        exports them all in one file, each tree under its own root.
        """
        self.trace_id = _new_prefix()
        return self.trace_id

    # -- span creation --------------------------------------------------

    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _next_id(self) -> str:
        return f"{self._prefix}.{next(self._counter)}"

    def start(self, name: str, *, kind: str = "span",
              parent: Union[Span, str, None] = None,
              **attrs: Any) -> Union[Span, _NoopSpan]:
        """Open a span without touching the implicit stack (for async
        open/close across callbacks, e.g. coordinator dispatch slots)."""
        if not self.enabled:
            return _NOOP
        pid = self._resolve_parent(parent)
        sp = Span(name, self._next_id(), pid, kind, self.proc,
                  threading.get_ident(), time.time(), attrs or None)
        sp._t0 = time.perf_counter()
        return sp

    def finish(self, span: Union[Span, _NoopSpan],
               end_ts: Optional[float] = None) -> None:
        if span is _NOOP or not isinstance(span, Span):
            return
        if end_ts is not None:
            span.dur_s = max(0.0, end_ts - span.ts)
        elif span._t0:
            span.dur_s = time.perf_counter() - span._t0
        else:
            span.dur_s = max(0.0, time.time() - span.ts)
        self._record(span)
        st = self._stack()
        if st and st[-1] is span:
            st.pop()

    def span(self, name: str, *, kind: str = "span",
             parent: Union[Span, str, None] = None, **attrs: Any) -> _SpanCtx:
        """Context manager: open on enter, seal on exit.  Nested calls on
        the same thread parent to the enclosing span automatically."""
        if not self.enabled:
            return _SpanCtx(self, _NOOP)
        sp = self.start(name, kind=kind, parent=parent, **attrs)
        self._stack().append(sp)          # type: ignore[arg-type]
        return _SpanCtx(self, sp)

    def add(self, name: str, *, ts: float, dur_s: float,
            parent: Union[Span, str, None] = None, kind: str = "phase",
            tid: Optional[int] = None, **attrs: Any) -> Union[Span, _NoopSpan]:
        """Record a span retroactively from captured wall timestamps
        (phase events logged by the harness / serve engine)."""
        if not self.enabled:
            return _NOOP
        pid = self._resolve_parent(parent)
        ptid = tid
        if ptid is None:
            psp = self._by_id.get(pid) if pid else None
            ptid = psp.tid if psp is not None else threading.get_ident()
        sp = Span(name, self._next_id(), pid, kind, self.proc, ptid, ts,
                  attrs or None)
        sp.dur_s = max(0.0, dur_s)
        self._record(sp)
        return sp

    def _resolve_parent(self, parent: Union[Span, str, None]) -> Optional[str]:
        if parent is not None:
            if isinstance(parent, str):
                return parent
            return getattr(parent, "span_id", None) or None
        st = self._stack()
        if st:
            return st[-1].span_id
        return self.root_parent

    def _record(self, span: Span) -> None:
        with self._lock:
            self._spans.append(span)
            self._by_id[span.span_id] = span

    # -- stitching ------------------------------------------------------

    def context(self, span: Union[Span, _NoopSpan, None] = None
                ) -> Optional[Dict[str, str]]:
        """Wire context for a job message: ``{"trace_id", "parent"}``."""
        if not self.enabled:
            return None
        parent = getattr(span, "span_id", "") if span is not None else ""
        return {"trace_id": self.trace_id, "parent": parent or ""}

    def ingest(self, span_dicts: Optional[Iterable[Dict[str, Any]]],
               proc: Optional[str] = None) -> int:
        """Adopt spans exported by a remote process, relabelling their
        lane to *proc* (the dispatcher knows the worker's identity)."""
        if not self.enabled or not span_dicts:
            return 0
        n = 0
        for d in span_dicts:
            if not isinstance(d, dict) or "span_id" not in d:
                continue
            sp = Span(str(d.get("name", "?")), str(d["span_id"]),
                      d.get("parent_id") or None, str(d.get("kind", "span")),
                      proc or str(d.get("proc", "remote")),
                      int(d.get("tid", 0)), float(d.get("ts", 0.0)),
                      dict(d.get("attrs") or {}))
            sp.dur_s = float(d.get("dur_s", 0.0))
            self._record(sp)
            n += 1
        return n

    def group(self, name: str, child_ids: Sequence[str], *,
              parent: Union[Span, str, None] = None,
              **attrs: Any) -> Union[Span, _NoopSpan]:
        """Synthesize a span covering *child_ids* and re-parent them to
        it (serial cells interleave across build keys, so group spans
        are stitched after the fact)."""
        if not self.enabled:
            return _NOOP
        with self._lock:
            kids = [self._by_id[c] for c in child_ids if c in self._by_id]
        if not kids:
            return _NOOP
        t0 = min(k.ts for k in kids)
        t1 = max(k.ts + k.dur_s for k in kids)
        sp = self.add(name, ts=t0, dur_s=t1 - t0, parent=parent,
                      kind="group", cells=len(kids), **attrs)
        for k in kids:
            k.parent_id = sp.span_id
        return sp

    # -- export ---------------------------------------------------------

    def export(self) -> List[Dict[str, Any]]:
        with self._lock:
            spans = sorted(self._spans, key=lambda s: s.ts)
        return [s.to_dict() for s in spans]

    def find(self, span_id: str) -> Optional[Span]:
        return self._by_id.get(span_id)

    @property
    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._by_id.clear()


NULL_TRACER = Tracer(enabled=False)


# -- structured warnings ------------------------------------------------

_RECENT_WARNINGS: "collections.deque[Dict[str, Any]]" = collections.deque(
    maxlen=64)


def warn(event: str, **fields: Any) -> Dict[str, Any]:
    """Emit a structured warning: one JSON line on stderr, retained in a
    small ring for tests/introspection.  Returns the payload."""
    payload = {"telemetry": "warn", "event": event, "ts": time.time(),
               **fields}
    _RECENT_WARNINGS.append(payload)
    try:
        print("[telemetry] " + json.dumps(payload, sort_keys=True,
                                          default=str), file=sys.stderr)
    except Exception:
        pass
    return payload


def recent_warnings(event: Optional[str] = None) -> List[Dict[str, Any]]:
    """Warnings emitted by this process, newest last."""
    return [w for w in _RECENT_WARNINGS
            if event is None or w.get("event") == event]
