"""Observability for the benchmark runner: distributed span tracing,
provenance stamping, and provenance-keyed result history.

- ``spans``       low-overhead thread-safe ``Tracer``; span ids ride the
                  JSONL job protocol so worker spans stitch under their
                  coordinator dispatch span (one trace per ``run_matrix``)
- ``export``      Chrome trace-event JSON (Perfetto) + terminal flame text
- ``provenance``  ``prov_*`` extras: commit sha/dirty, backend, host,
                  jax/python versions, stamped on every ``RunResult``
- ``history``     (scenario, provenance)-keyed time series over
                  ``ResultStore.history()`` with rolling-baseline drift
"""
from repro.telemetry.spans import (  # noqa: F401
    NULL_TRACER,
    Span,
    Tracer,
    group_label,
    recent_warnings,
    warn,
)
from repro.telemetry.provenance import (  # noqa: F401
    PROV_KEYS,
    collect as collect_provenance,
    provenance_key,
    stamp as stamp_provenance,
)
from repro.telemetry.export import (  # noqa: F401
    chrome_trace,
    flame_summary,
    save_trace,
)

__all__ = [
    "NULL_TRACER",
    "Span",
    "Tracer",
    "group_label",
    "warn",
    "recent_warnings",
    "PROV_KEYS",
    "collect_provenance",
    "provenance_key",
    "stamp_provenance",
    "chrome_trace",
    "flame_summary",
    "save_trace",
]
