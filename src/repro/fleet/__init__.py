"""The perf-CI fleet service: live metrics, scheduled sweeps, triage.

``repro.fleet`` turns the one-shot nightly pieces (``core/ci``,
``telemetry/history``, ``benchmarks/profile_report --drain-queue``) into
a long-running, supervised service:

* :mod:`repro.fleet.metrics` — the process-wide metrics registry
  (counters / gauges / histograms) instrumenting runner, pool, cluster
  coordinator, and serve engine; JSON + Prometheus export;
* :mod:`repro.fleet.scheduler` — the tick-driven sweep loop (virtual
  clock injectable) appending provenance-stamped history points and
  running the drift pass + tuning-queue drain on a stride;
* :mod:`repro.fleet.triage` — drift findings graduate to confirmed
  regressions via automatic re-measure, then commit bisection;
* :mod:`repro.fleet.service` — the ``runtime/supervisor``-wrapped loop
  behind ``scripts/fleet.py``, with the heartbeat status file.

Only the metrics module is imported eagerly — it is stdlib-only, so the
runner / pool / coordinator / serve layers can ``import repro.fleet
.metrics`` without dragging the scheduler's runner dependency into a
cycle; everything else resolves lazily through ``__getattr__``.
"""
from repro.fleet.metrics import (METRICS_SCHEMA_KEY, METRICS_SCHEMA_VERSION,
                                 MetricsRegistry, registry, set_enabled)

__all__ = [
    "METRICS_SCHEMA_KEY", "METRICS_SCHEMA_VERSION", "MetricsRegistry",
    "registry", "set_enabled",
    "FleetConfig", "FleetScheduler", "TickResult", "VirtualClock",
    "triage", "FleetService", "FLEET_STATUS_SCHEMA_KEY",
    "FLEET_STATUS_SCHEMA_VERSION",
]

_LAZY = {
    "FleetConfig": "repro.fleet.scheduler",
    "FleetScheduler": "repro.fleet.scheduler",
    "TickResult": "repro.fleet.scheduler",
    "VirtualClock": "repro.fleet.scheduler",
    "triage": "repro.fleet.triage",
    "FleetService": "repro.fleet.service",
    "FLEET_STATUS_SCHEMA_KEY": "repro.fleet.service",
    "FLEET_STATUS_SCHEMA_VERSION": "repro.fleet.service",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.fleet' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
