"""Drift triage: automatic re-measure, confirmation, and bisection.

A trajectory drift finding is a *suspicion* — one slow point against a
rolling baseline, which on a shared host is as likely to be noise as a
regression.  Triage graduates suspicions to confirmed regressions:

1. **re-measure** — the flagged cell is run again, fresh, through the
   same runner (same process => same cached provenance key, so the
   re-measure lands in the same series the drift was detected in); the
   delta must reproduce above the threshold;
2. **bisect** — when the caller can supply a commit range
   (``commits_for``), ``core/regression.bisect_commits`` binary-searches
   the culprit at half the confirmed increase (so suite noise can't
   flag a good commit);
3. **rank** — confirmed / refuted / bisected outcomes become
   ``profiler/report.py`` findings (``regression_confirmed`` crit/warn,
   ``regression_bisected`` crit, ``drift_refuted`` info), ranked
   severity-then-score into the ``results/fleet_report.json`` shape.

Re-measures run with ``record=False`` and are never logged to the
history store — each cell keeps exactly one history point per tick.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from repro.core.regression import THRESHOLD, bisect_commits
from repro.fleet.metrics import registry
from repro.profiler.detectors import SEVERITIES, Finding
from repro.profiler.report import build_report

#: rules this module emits, most severe first
TRIAGE_RULES = ("regression_bisected", "regression_confirmed",
                "drift_unverified", "drift_refuted")


def triage(drift_report: Dict[str, Any], *, runner,
           scenarios: Dict[str, Any],
           hooks: Optional[Dict[str, Any]] = None,
           threshold: float = THRESHOLD,
           remeasure_runs: Optional[int] = None,
           commits_for: Optional[Callable[[dict, Any], Optional[list]]] = None,
           meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Re-measure every ``perf_drift`` finding in a trajectory report and
    rank the outcomes into a ``build_report``-shaped triage report.

    ``scenarios`` maps scenario name -> ``Scenario`` (the scheduler's
    expanded matrix); ``hooks`` are the *currently active* run_matrix
    hooks, keyed by scenario name or bench, so the re-measure sees the
    same world the flagged tick did.  ``commits_for(finding, scenario)``
    returns the ``core.regression.Commit`` range to bisect (or None).
    """
    reg = registry()
    hooks = hooks or {}
    findings: List[Finding] = []
    records: List[Any] = []
    confirmed = refuted = bisected = 0
    for fd in drift_report.get("findings", []):
        if fd.get("rule") != "perf_drift":
            continue
        cell = fd.get("cell", "")
        evidence = dict(fd.get("evidence") or {})
        metric = evidence.get("metric", "median_us")
        baseline = float(evidence.get("baseline") or 0.0)
        sc = scenarios.get(cell)
        if sc is None or baseline <= 0.0:
            findings.append(Finding(
                rule="drift_unverified", severity="info", cell=cell,
                summary=f"cannot re-measure {metric} drift "
                        f"(unknown cell or empty baseline)",
                score=float(fd.get("score") or 0.0), evidence=evidence))
            continue
        hook = hooks.get(sc.name) or hooks.get(sc.bench)
        rr = runner.run(sc, runs=remeasure_runs, hook=hook, record=False)
        reg.inc("fleet_remeasures_total")
        records.append(rr)
        observed = rr.metrics().get(metric, 0.0) if rr.status == "ok" else 0.0
        increase = (observed - baseline) / baseline if observed else 0.0
        if rr.status == "ok" and increase > threshold:
            confirmed += 1
            reg.inc("fleet_confirmed_total")
            findings.append(Finding(
                rule="regression_confirmed",
                severity=fd.get("severity", "warn"), cell=cell,
                summary=f"{metric} +{increase:.0%} reproduced on re-measure "
                        f"(baseline {baseline:.0f}, observed {observed:.0f})",
                score=increase,
                evidence={**evidence, "remeasured": observed,
                          "increase": increase}))
            commits = commits_for(fd, sc) if commits_for else None
            if commits:
                trace: List[str] = []
                reg.inc("fleet_bisects_total")
                culprit = bisect_commits(
                    commits, sc.bench, metric, baseline,
                    threshold=max(threshold, increase / 2), trace=trace)
                if culprit is not None:
                    bisected += 1
                    findings.append(Finding(
                        rule="regression_bisected", severity="crit",
                        cell=cell,
                        summary=f"bisected {metric} regression to "
                                f"{culprit.sha} "
                                f"({len(trace)} measurements of "
                                f"{len(commits)} commits)",
                        score=increase,
                        evidence={"culprit": culprit.sha, "metric": metric,
                                  "baseline": baseline,
                                  "measurements": len(trace),
                                  "commits": len(commits),
                                  "bisect_trace": trace}))
        else:
            refuted += 1
            reg.inc("fleet_refuted_total")
            findings.append(Finding(
                rule="drift_refuted", severity="info", cell=cell,
                summary=f"{metric} drift did not reproduce "
                        f"(baseline {baseline:.0f}, re-measured "
                        f"{observed:.0f}, status {rr.status})",
                score=max(increase, 0.0),
                evidence={**evidence, "remeasured": observed,
                          "increase": increase, "status": rr.status}))
    rank = {s: i for i, s in enumerate(SEVERITIES)}
    findings.sort(key=lambda f: (rank.get(f.severity, len(SEVERITIES)),
                                 -f.score))
    reg.set_gauge("fleet_open_findings",
                  sum(1 for f in findings
                      if f.rule in ("regression_confirmed",
                                    "regression_bisected")))
    return build_report(records, findings, meta={
        "kind": "fleet_triage",
        "drift_findings": len(drift_report.get("findings", [])),
        "confirmed": confirmed, "refuted": refuted, "bisected": bisected,
        **(meta or {}),
    })
