"""Tick-driven sweep scheduler for the fleet service.

One tick = one nightly-shaped pass over the configured matrix through
the shared :class:`~repro.runner.BenchmarkRunner` (so serial,
``jobs=N``, and ``cluster=`` dispatch all work unchanged), with every
measured ``RunResult`` appended to the :class:`~repro.core.regression
.MetricStore` history log as a provenance-stamped time-series point
(``extra["fleet_tick"]`` records which tick measured it), followed by
the ``telemetry/history.trajectory`` drift pass.  On a configurable
tick stride the scheduler also drains ``results/tuning_queue.json``
through ``repro.tuning.bridge.drain_queue`` — the scheduled version of
``benchmarks/profile_report --drain-queue`` — recording drained-job
counts in the metrics registry.

Time is injectable: pass a :class:`VirtualClock` and ticks advance
instantly in tests and ``scripts/fleet.py --fast`` demo runs; the
default :class:`WallClock` sleeps for real.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.regression import THRESHOLD, MetricStore
from repro.fleet.metrics import registry
from repro.runner.results import RunResult
from repro.runner.scenario import Scenario, ScenarioMatrix
from repro.telemetry.history import trajectory


class WallClock:
    """Real time (the default outside tests)."""

    def time(self) -> float:
        return time.time()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class VirtualClock:
    """Injectable clock: ``sleep`` advances the virtual time instantly,
    so a 2-tick nightly cadence demo completes in wall-milliseconds."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def time(self) -> float:
        return self._t

    def sleep(self, seconds: float) -> None:
        self._t += max(0.0, float(seconds))


@dataclasses.dataclass
class FleetConfig:
    """What one fleet tick measures, and on what cadence."""

    archs: Sequence[str] = ("gemma-2b",)
    tasks: Sequence[str] = ("train",)
    batches: Sequence[int] = (1,)
    seqs: Sequence[int] = (16,)
    dtypes: Sequence[str] = ("fp32",)
    runs: int = 3
    interval_s: float = 0.0        # clock.sleep between ticks (virtual ok)
    window: int = 5                # drift pass: rolling-baseline window
    threshold: float = THRESHOLD   # drift + triage confirmation threshold
    min_points: int = 2            # drift pass: series length floor
    drain_stride: int = 2          # drain tuning queue every Nth tick (0=off)
    drain_max_candidates: Optional[int] = None   # bound sweep cost per drain
    queue_path: str = ""           # "" -> tuning.bridge.default_queue_path()

    def matrix(self) -> ScenarioMatrix:
        return ScenarioMatrix(archs=list(self.archs), tasks=tuple(self.tasks),
                              batches=tuple(self.batches),
                              seqs=tuple(self.seqs),
                              dtypes=tuple(self.dtypes))


@dataclasses.dataclass
class TickResult:
    tick: int
    results: List[RunResult]
    drift: Dict[str, Any]          # trajectory() report (build_report shape)
    drained_cases: int             # kernel cases swept by this tick's drain
    wall_s: float


class FleetScheduler:
    """Runs the matrix, logs history, detects drift, drains the queue.

    The runner should be constructed with ``store=None`` — history
    points land exclusively through ``MetricStore.log_result`` here, so
    each cell contributes exactly one point per tick.

    ``hooks_for_tick(tick)`` returns the ``run_matrix`` hooks dict for a
    given tick (or None) — the injection point for regression demos and
    crash-recovery tests.
    """

    def __init__(self, config: FleetConfig, store: MetricStore, runner,
                 *, clock=None,
                 hooks_for_tick: Optional[Callable[[int], Optional[dict]]] = None):
        self.cfg = config
        self.store = store
        self.runner = runner
        self.clock = clock if clock is not None else WallClock()
        self.hooks_for_tick = hooks_for_tick or (lambda tick: None)
        self.matrix = config.matrix()
        self.scenarios: Dict[str, Scenario] = {sc.name: sc
                                               for sc in self.matrix.expand()}

    def tick(self, tick: int) -> TickResult:
        """One scheduled pass: sweep, log, drift, (stride-gated) drain."""
        reg = registry()
        t0 = time.monotonic()
        hooks = self.hooks_for_tick(tick)
        results = self.runner.run_matrix(self.matrix, hooks=hooks,
                                         runs=self.cfg.runs)
        for rr in results:
            rr.extra["fleet_tick"] = tick
            self.store.log_result(rr)
        reg.inc("fleet_ticks_total")
        reg.inc("fleet_history_points_total", len(results))
        reg.set_gauge("fleet_last_tick", tick)
        drift = trajectory(self.store, window=self.cfg.window,
                           threshold=self.cfg.threshold,
                           min_points=self.cfg.min_points)
        drained = 0
        if self.cfg.drain_stride and (tick + 1) % self.cfg.drain_stride == 0:
            drained = self.drain()
        return TickResult(tick=tick, results=results, drift=drift,
                          drained_cases=drained,
                          wall_s=time.monotonic() - t0)

    def drain(self) -> int:
        """Drain the autotuner's pending-job queue through the shared
        runner (the ``profile_report --drain-queue`` path, on schedule)."""
        from repro.tuning.bridge import drain_queue
        out = drain_queue(self.runner,
                          queue_path=self.cfg.queue_path or None,
                          max_candidates=self.cfg.drain_max_candidates)
        reg = registry()
        if out["jobs"]:
            reg.inc("fleet_drained_jobs_total", out["jobs"])
        if out["cases"]:
            reg.inc("fleet_drained_cases_total", out["cases"])
        return out["cases"]
