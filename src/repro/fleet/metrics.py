"""Process-wide metrics registry for the perf-CI fleet service.

Counters, gauges, and bounded-reservoir histograms instrumenting the
hot *control* paths — ``runner/runner.py`` (cells run/errored, cache
hits/misses, compile vs measure seconds), ``runner/pool.py`` and
``runner/cluster/coordinator.py`` (steals, respawns, worker deaths,
heartbeat gaps, queue depth, per-worker in-flight), and
``launch/serve.py`` (admission waves, bucket compiles, KV occupancy).
Every mutation is a dict update under one lock and happens per cell /
per job / per admission wave — never per decode step or per measured
iteration — so the registry costs nothing measurable when nobody
exports it (``benchmarks/runner_bench.py`` measures the enabled-vs-
disabled ratio ~= 1.0x on a warm cell); ``enabled = False`` turns every
mutation into an early return for belt-and-braces benchmarking.

Export surfaces:

* :meth:`MetricsRegistry.snapshot` — schema-tagged JSON
  (``{"fleet_metrics": 1, "counters": ..., "gauges": ...,
  "histograms": ...}``; see ``runner/results.py`` for the documented
  shape);
* :meth:`MetricsRegistry.to_prometheus` — Prometheus text exposition
  format (counters, gauges, and histograms as summaries with quantile
  labels).

Cross-process merging: worker subprocesses carry their own registry;
the flat cumulative-counter snapshot (:meth:`counters_cumulative`)
rides the JSONL result channel next to ``RunnerStats`` (the
``"metrics"`` field of a ``result`` message, see
``runner/protocol.py``) and the dispatcher delta-merges it with the
same ``protocol.stats_delta`` arithmetic — per-worker-process ``seen``
snapshots, reset on respawn — so parent-side counters stay
monotonically non-decreasing across worker respawns.  Histograms ship
only their count/sum on the wire (percentile reservoirs don't merge);
gauges are process-local and never cross.

This module depends only on the stdlib, so any layer (runner, pool,
coordinator, serve engine, worker) can import it without cycles.
"""
from __future__ import annotations

import math
import re
import threading
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

METRICS_SCHEMA_KEY = "fleet_metrics"
METRICS_SCHEMA_VERSION = 1

#: bounded histogram reservoir — percentile estimates come from the most
#: recent RESERVOIR observations; count/sum stay exact forever
RESERVOIR = 256

#: separator for flat histogram encoding on the wire ("|" never appears
#: in metric names, see _NAME_OK)
_HIST_COUNT = "|hcount"
_HIST_SUM = "|hsum"

_NAME_OK = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
_PROM_BAD = re.compile(r"[^a-zA-Z0-9_:]")


class _Hist:
    __slots__ = ("count", "total", "vmax", "samples")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.vmax = 0.0
        self.samples: Deque[float] = deque(maxlen=RESERVOIR)

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v > self.vmax:
            self.vmax = v
        self.samples.append(v)

    def quantile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        vals = sorted(self.samples)
        idx = min(len(vals) - 1, int(math.ceil(q * len(vals))) - 1)
        return vals[max(0, idx)]

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.total,
                "p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "max": self.vmax}


class MetricsRegistry:
    """Thread-safe counters / gauges / bounded-reservoir histograms.

    One process-wide instance lives behind :func:`registry`; tests build
    their own for isolation.  All mutation methods are near-no-ops when
    ``enabled`` is False.
    """

    def __init__(self, *, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, _Hist] = {}

    # ---- mutation --------------------------------------------------------

    def inc(self, name: str, value: float = 1.0) -> None:
        """Add to a monotonic counter (negative deltas are ignored —
        counters must survive ``stats_delta`` merging)."""
        if not self.enabled or value <= 0:
            return
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + value

    def set_gauge(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        with self._lock:
            hist = self._hists.get(name)
            if hist is None:
                hist = self._hists[name] = _Hist()
            hist.observe(float(value))

    def reset(self) -> None:
        """Drop every instrument (tests / fresh service runs)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    # ---- the runner's per-result hook ------------------------------------

    def record_result(self, rr: Any) -> None:
        """Count one scenario *execution* (a ``RunResult``): cells run /
        errored, executable-cache hit vs miss, and the compile/measure
        second distributions.  Called from the runner's result epilogue
        on every transport — note the measurement fence's unfenced warm
        pass is an execution too, so fenced cells count twice (the
        ledger-corrected ``RunnerStats`` stay the one-per-cell view)."""
        if not self.enabled:
            return
        self.inc("fleet_cells_total")
        if getattr(rr, "status", "ok") != "ok":
            self.inc("fleet_cells_errored_total")
            return
        cache = getattr(rr, "cache", None) or {}
        if cache.get("executable_reused"):
            self.inc("fleet_exec_cache_hits_total")
        else:
            self.inc("fleet_exec_cache_misses_total")
        compile_us = getattr(rr, "compile_us", 0.0) or 0.0
        if compile_us > 0:
            self.observe("fleet_compile_seconds", compile_us / 1e6)
        runs = getattr(rr, "runs", 0) or 0
        median_us = getattr(rr, "median_us", 0.0) or 0.0
        if runs and median_us:
            self.observe("fleet_measure_seconds", median_us * runs / 1e6)

    # ---- export ----------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """Schema-tagged JSON snapshot (see ``runner/results.py``)."""
        with self._lock:
            return {
                METRICS_SCHEMA_KEY: METRICS_SCHEMA_VERSION,
                "ts": time.time(),
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {n: h.summary()
                               for n, h in self._hists.items()},
            }

    def to_prometheus(self) -> str:
        """The Prometheus text exposition format: counters and gauges as
        single samples, histograms as summaries (quantile labels +
        ``_sum``/``_count``).  Names are sanitized to the Prometheus
        charset."""
        lines: List[str] = []
        snap = self.snapshot()
        for name, v in sorted(snap["counters"].items()):
            n = _prom_name(name)
            lines.append(f"# TYPE {n} counter")
            lines.append(f"{n} {_prom_num(v)}")
        for name, v in sorted(snap["gauges"].items()):
            n = _prom_name(name)
            lines.append(f"# TYPE {n} gauge")
            lines.append(f"{n} {_prom_num(v)}")
        for name, h in sorted(snap["histograms"].items()):
            n = _prom_name(name)
            lines.append(f"# TYPE {n} summary")
            lines.append(f'{n}{{quantile="0.5"}} {_prom_num(h["p50"])}')
            lines.append(f'{n}{{quantile="0.95"}} {_prom_num(h["p95"])}')
            lines.append(f"{n}_sum {_prom_num(h['sum'])}")
            lines.append(f"{n}_count {_prom_num(h['count'])}")
        return "\n".join(lines) + "\n"

    # ---- the wire (worker -> dispatcher) ---------------------------------

    def counters_cumulative(self) -> Dict[str, float]:
        """Flat, monotonically non-decreasing snapshot for the JSONL
        result channel: counters verbatim plus each histogram's exact
        count/sum under ``<name>|hcount`` / ``<name>|hsum`` keys — the
        shape ``protocol.stats_delta`` can diff.  Gauges stay local."""
        with self._lock:
            out: Dict[str, float] = dict(self._counters)
            for name, h in self._hists.items():
                out[name + _HIST_COUNT] = float(h.count)
                out[name + _HIST_SUM] = h.total
            return out

    def merge_cumulative(self, delta: Optional[Dict[str, float]]) -> None:
        """Fold a worker's ``stats_delta``-diffed snapshot into this
        registry.  Histogram count/sum merge exactly; the percentile
        reservoir only sees locally-observed samples (cross-process
        percentiles don't compose), so merged histograms report exact
        count/sum with parent-local quantiles."""
        if not delta or not self.enabled:
            return
        with self._lock:
            for k, v in delta.items():
                if not isinstance(v, (int, float)) or v <= 0:
                    continue
                if k.endswith(_HIST_COUNT):
                    hist = self._hists.setdefault(k[: -len(_HIST_COUNT)],
                                                  _Hist())
                    hist.count += int(v)
                elif k.endswith(_HIST_SUM):
                    hist = self._hists.setdefault(k[: -len(_HIST_SUM)],
                                                  _Hist())
                    hist.total += float(v)
                else:
                    self._counters[k] = self._counters.get(k, 0.0) + v


def _prom_name(name: str) -> str:
    name = _PROM_BAD.sub("_", name)
    if not name or not (name[0].isalpha() or name[0] in "_:"):
        name = "_" + name
    return name


def _prom_num(v: float) -> str:
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide registry every instrumentation site writes to."""
    return _REGISTRY


def set_enabled(flag: bool) -> bool:
    """Toggle the process-wide registry; returns the previous state
    (``benchmarks/runner_bench.py`` measures the overhead both ways)."""
    prev = _REGISTRY.enabled
    _REGISTRY.enabled = bool(flag)
    return prev
