"""The supervised fleet loop: ticks under restart/backoff semantics.

``FleetService`` wraps the :class:`~repro.fleet.scheduler.FleetScheduler`
tick as a ``runtime/supervisor.Supervisor`` step (one tick = one step,
checkpointed every step into a small JSON state file), so a tick that
raises mid-matrix restarts with exponential backoff from the last
completed tick — already-logged history points survive, because they
live in the ``MetricStore``'s append-only JSONL, not in service state.

After every completed tick the service:

* runs :func:`repro.fleet.triage.triage` over the tick's drift report
  and writes the ranked outcome to ``results/fleet_report.json``;
* rewrites the heartbeat status file ``results/fleet_status.json``
  (schema-tagged: last tick, open findings, restart count, per-tick
  counter snapshots, and the full metrics snapshot) — the liveness
  probe, fresh after each tick by construction;
* exports the Prometheus text snapshot to ``results/fleet_metrics.prom``.

``scripts/fleet.py`` is the CLI (``--ticks N --fast`` for bounded
virtual-clock demo runs).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.regression import MetricStore
from repro.fleet.metrics import registry
from repro.fleet.scheduler import FleetConfig, FleetScheduler, WallClock
from repro.fleet.triage import triage
from repro.runtime.supervisor import Supervisor

FLEET_STATUS_SCHEMA_KEY = "fleet_status"
FLEET_STATUS_SCHEMA_VERSION = 1

#: the counters the status file tracks per tick (the smoke gate's
#: monotonicity probe); everything else is in the full snapshot
STATUS_COUNTER_PREFIXES = ("fleet_", "pool_", "cluster_", "serve_")


class _TickCheckpoint:
    """A ``CheckpointManager``-shaped adapter over one JSON file: the
    supervisor's tiny service state (ticks done, open findings) doesn't
    need the async array-tree machinery of ``runtime/checkpoint``."""

    def __init__(self, path: str):
        self.path = path

    def save(self, state: Any, step: int) -> None:
        tmp = f"{self.path}.{os.getpid()}.tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "state": state}, f)
        os.replace(tmp, self.path)

    def wait(self) -> None:
        pass

    def restore_latest(self, like: Any):
        try:
            with open(self.path) as f:
                payload = json.load(f)
            return payload["state"], int(payload["step"])
        except (OSError, ValueError, KeyError):
            return None, 0


class FleetService:
    """The long-running perf-CI service: supervised scheduler ticks with
    triage, status heartbeat, and metrics export after every tick."""

    def __init__(self, config: FleetConfig, *, store: MetricStore, runner,
                 results_dir: str = "results", clock=None,
                 hooks_for_tick: Optional[Callable[[int], Optional[dict]]] = None,
                 commits_for: Optional[Callable] = None,
                 max_restarts: int = 3, backoff_s: float = 0.0,
                 sleep: Optional[Callable[[float], None]] = None,
                 scheduler: Optional[FleetScheduler] = None):
        self.cfg = config
        self.store = store
        self.runner = runner
        self.clock = clock if clock is not None else WallClock()
        self.commits_for = commits_for
        self.max_restarts = max_restarts
        self.backoff_s = backoff_s
        self._sleep = sleep if sleep is not None else self.clock.sleep
        self.scheduler = scheduler or FleetScheduler(
            config, store, runner, clock=self.clock,
            hooks_for_tick=hooks_for_tick)
        os.makedirs(results_dir, exist_ok=True)
        self.status_path = os.path.join(results_dir, "fleet_status.json")
        self.report_path = os.path.join(results_dir, "fleet_report.json")
        self.prom_path = os.path.join(results_dir, "fleet_metrics.prom")
        self.ckpt_path = os.path.join(results_dir, "fleet_service_state.json")
        #: per-tick status-counter snapshots (rewritten into the status
        #: file every tick — the monotonicity record across the run)
        self.tick_log: List[Dict[str, Any]] = []
        self.last_report: Optional[Dict[str, Any]] = None
        self._sup: Optional[Supervisor] = None

    # ---- the supervised loop ---------------------------------------------

    def run(self, ticks: int) -> Dict[str, Any]:
        """Run ``ticks`` supervised scheduler ticks; returns a summary.

        A fresh service run starts from tick 0 (the checkpoint file is
        reset) — long-lived *history* lives in the MetricStore, not in
        service state.
        """
        ckpt = _TickCheckpoint(self.ckpt_path)
        try:
            os.remove(self.ckpt_path)
        except OSError:
            pass
        sup = Supervisor(ckpt, save_every=1, max_restarts=self.max_restarts,
                         backoff_s=self.backoff_s, sleep=self._sleep)
        self._sup = sup
        state = {"ticks_done": 0, "open_findings": 0}
        state, step = sup.run(state, self._step, ticks)
        return {"ticks": step, "restarts": sup.restarts,
                "events": list(sup.events),
                "open_findings": state.get("open_findings", 0),
                "status_path": self.status_path,
                "report_path": self.report_path,
                "prom_path": self.prom_path}

    def _step(self, state: Dict[str, Any], step: int) -> Dict[str, Any]:
        tres = self.scheduler.tick(step)
        report = triage(
            tres.drift, runner=self.runner,
            scenarios=self.scheduler.scenarios,
            hooks=self.scheduler.hooks_for_tick(step) or {},
            threshold=self.cfg.threshold,
            commits_for=self.commits_for,
            meta={"tick": step, "drained_cases": tres.drained_cases})
        self.last_report = report
        _write_json(self.report_path, report)
        state = dict(state)
        state["ticks_done"] = step + 1
        state["open_findings"] = sum(
            1 for f in report["findings"]
            if f["rule"] in ("regression_confirmed", "regression_bisected"))
        self._write_status(step, state, tres)
        with open(self.prom_path, "w") as f:
            f.write(registry().to_prometheus())
        if self.cfg.interval_s:
            self.clock.sleep(self.cfg.interval_s)
        return state

    # ---- heartbeat --------------------------------------------------------

    def _write_status(self, step: int, state: Dict[str, Any],
                      tres) -> None:
        snap = registry().snapshot()
        counters = {k: v for k, v in snap["counters"].items()
                    if k.startswith(STATUS_COUNTER_PREFIXES)}
        self.tick_log.append({"tick": step, "ts": time.time(),
                              "clock": self.clock.time(),
                              "wall_s": tres.wall_s,
                              "cells": len(tres.results),
                              "drift_findings": len(tres.drift["findings"]),
                              "drained_cases": tres.drained_cases,
                              "counters": counters})
        status = {
            FLEET_STATUS_SCHEMA_KEY: FLEET_STATUS_SCHEMA_VERSION,
            "ts": time.time(),
            "tick": step,
            "ticks_done": state["ticks_done"],
            "open_findings": state["open_findings"],
            "restarts": self._sup.restarts if self._sup else 0,
            "ticks": self.tick_log,
            "metrics": snap,
        }
        _write_json(self.status_path, status)


def _write_json(path: str, payload: Dict[str, Any]) -> None:
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)
