"""Fault-tolerant checkpointing: atomic commit, async write, retention.

Layout:  <dir>/step_<N>/  with one .npy per flattened leaf + manifest.json.
Writes go to ``step_<N>.tmp`` and are renamed only after fsync — a crashed
writer never corrupts the latest checkpoint (restart-safe).  ``save_async``
snapshots to host memory synchronously (jax.device_get) and writes on a
background thread, overlapping the disk I/O with the next training steps.

On a real multi-host cluster each host writes only the shards it owns
(``process_index`` prefix); this container is single-process so the path
degenerates gracefully.
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        out.append((name, leaf))
    return out


def save_pytree(tree, directory: str, step: int, *, process_index: int = 0) -> str:
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"p{process_index}_{name.replace('/', '__')}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({"name": name, "file": fname,
                                   "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, f"manifest_p{process_index}.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)   # atomic commit
    return final


def restore_pytree(like, directory: str, step: int, *, process_index: int = 0):
    """Restore into the structure (and shardings, if any) of ``like``."""
    final = os.path.join(directory, f"step_{step}")
    with open(os.path.join(final, f"manifest_p{process_index}.json")) as f:
        manifest = json.load(f)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        arr = np.load(os.path.join(final, by_name[name]["file"]))
        if hasattr(leaf, "sharding") and leaf.sharding is not None and hasattr(leaf.sharding, "mesh"):
            leaves.append(jax.device_put(arr, leaf.sharding))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, [l for l in leaves])


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


class CheckpointManager:
    """Async checkpointing with retention and exactly-once commit per step."""

    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[concurrent.futures.Future] = None
        self._lock = threading.Lock()

    def save(self, tree, step: int) -> None:
        # Snapshot to host memory NOW (values at this step), write later.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_write:
            self.wait()   # at most one write in flight
            self._pending = self._pool.submit(self._write, host_tree, step)
        else:
            self._write(host_tree, step)

    def _write(self, host_tree, step: int) -> None:
        save_pytree(host_tree, self.directory, step)
        self._gc()

    def _gc(self) -> None:
        with self._lock:
            steps = sorted(
                int(d.split("_")[1]) for d in os.listdir(self.directory)
                if d.startswith("step_") and not d.endswith(".tmp"))
            for s in steps[: -self.keep]:
                shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def restore_latest(self, like):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return restore_pytree(like, self.directory, step), step
