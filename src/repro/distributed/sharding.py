"""Logical-axis sharding rules and divisibility-safe spec resolution.

Every parameter and activation in the framework is annotated with *logical*
axis names ("w_mlp", "act_batch", ...).  A rules dict maps logical names to
mesh axis names (or None).  ``resolve_spec`` turns (logical axes, shape) into
a ``PartitionSpec``, silently dropping mesh axes that do not divide the
dimension — this is what lets one model definition run on a 1-device CPU
smoke test, a 256-chip pod and a 512-chip multi-pod without edits.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Mesh axis names used across the framework.  "pod" only exists on the
# multi-pod mesh; rules may reference it — resolution drops absent axes.
DATA_AXES = ("pod", "data")

# Baseline rules: DP over (pod, data); TP over model; FSDP = shard the
# weights' embed dim over data.  Per-arch / per-shape overrides are merged
# on top (see repro.configs and repro.launch.dryrun).
LOGICAL_RULES_BASE: dict[str, Any] = {
    # --- activations ---
    "act_batch": ("pod", "data"),
    "act_seq": None,            # set to ("data",) for sequence parallelism
    "act_q_seq": None,          # attention q-seq SP (set to ("model",))
    "act_embed": None,
    "act_heads": ("model",),
    "act_kv_heads": ("model",),
    "act_mlp": ("model",),
    "act_vocab": ("model",),
    "act_experts": ("model",),
    "act_group": ("pod", "data"),   # MoE dispatch groups follow batch
    "act_cap": None,
    "act_state": None,
    "act_frames": None,
    # --- weights ---
    "w_embed": ("data",),       # FSDP: shard weight d_model dim over data
    "w_embed_pod": None,        # optionally also over pod (overridden)
    "w_vocab": ("model",),
    "w_heads": ("model",),
    "w_kv_heads": ("model",),
    "w_qk": None,
    "w_mlp": ("model",),
    "w_experts": ("model",),
    "w_expert_mlp": ("model",), # expert FFN dim: TP fallback when E < axis
    "w_lora": None,
    "w_state": None,
    "w_conv": None,
    "w_frames": None,
    # --- never sharded ---
    "layers": None,
    "scalar": None,
    # --- kv cache ---
    "cache_batch": ("pod", "data"),
    "cache_seq": None,          # ("data",) under long-context SP decode
    "cache_heads": ("model",),
    "cache_state": None,
}


def merge_rules(*overrides: Optional[Mapping[str, Any]]) -> dict[str, Any]:
    rules = dict(LOGICAL_RULES_BASE)
    for ov in overrides:
        if ov:
            rules.update(ov)
    return rules


def _as_tuple(v: Any) -> Tuple[str, ...]:
    if v is None:
        return ()
    if isinstance(v, str):
        return (v,)
    return tuple(v)


def resolve_spec(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Mapping[str, Any],
) -> P:
    """Map logical axes -> PartitionSpec, dropping non-divisible mesh axes."""
    assert len(axes) == len(shape), (axes, shape)
    used: set[str] = set()
    entries = []
    for dim, name in zip(shape, axes):
        if name is None:
            entries.append(None)
            continue
        mesh_axes = _as_tuple(rules.get(name))
        kept = []
        divisor = 1
        for ax in mesh_axes:
            if ax not in mesh.shape or ax in used:
                continue
            size = mesh.shape[ax]
            if dim % (divisor * size) == 0:
                kept.append(ax)
                divisor *= size
        used.update(kept)
        if not kept:
            entries.append(None)
        elif len(kept) == 1:
            entries.append(kept[0])
        else:
            entries.append(tuple(kept))
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


@dataclasses.dataclass
class ShardingCtx:
    mesh: Optional[Mesh]
    rules: Mapping[str, Any]

    def spec(self, axes: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        assert self.mesh is not None
        return resolve_spec(axes, shape, self.mesh, self.rules)

    def sharding(self, axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))


_TLS = threading.local()


def set_ctx(ctx: Optional[ShardingCtx]) -> None:
    _TLS.ctx = ctx


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_TLS, "ctx", None)


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[Mapping[str, Any]] = None):
    prev = current_ctx()
    set_ctx(ShardingCtx(mesh, merge_rules(rules)) if mesh is not None else None)
    try:
        yield current_ctx()
    finally:
        set_ctx(prev)


def logical(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Constrain ``x`` to the sharding implied by logical ``axes``.

    A no-op outside a sharding context (single-device smoke tests).
    """
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return x
    spec = ctx.spec(axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def spec_tree(defs, mesh: Mesh, rules: Mapping[str, Any]):
    """Tree of ParamDef/CacheDef-likes (with .axes/.shape) -> tree of NamedSharding."""
    return jax.tree.map(
        lambda d: NamedSharding(mesh, resolve_spec(d.axes, d.shape, mesh, rules)),
        defs,
        is_leaf=lambda d: hasattr(d, "axes"),
    )
