from repro.distributed.sharding import (  # noqa: F401
    LOGICAL_RULES_BASE,
    ShardingCtx,
    current_ctx,
    logical,
    merge_rules,
    resolve_spec,
    set_ctx,
    sharding_ctx,
    spec_tree,
)
