"""Shared building blocks: param defs, norms, RoPE, activations, attention.

All modules are pure functions over explicit param pytrees.  Parameters are
*declared* via ``ParamDef`` trees (shape/dtype/logical axes/init), from which
we derive: materialized params (``init_tree``), ShapeDtypeStructs
(``abstract_tree``) and NamedShardings (``repro.distributed.spec_tree``).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import current_ctx, logical

# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"       # normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev override; default fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def abstract_tree(defs):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=_is_def
    )


def _init_one(d: ParamDef, key: jax.Array) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        std = d.scale if d.scale is not None else 0.02
        return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)
    # fan-in normal
    fan_in = d.shape[0] if len(d.shape) == 1 else int(np.prod(d.shape[:-1]))
    std = d.scale if d.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)


def init_tree(defs, key: jax.Array):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_one(d, k) for d, k in zip(leaves, keys)])


def axes_tree(defs):
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=_is_def)


# ---------------------------------------------------------------------------
# Elementary ops
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    # gemma convention: (1 + gamma); with gamma init zeros this is identity.
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma + beta).astype(dtype)


def squared_relu(x):
    return jnp.square(jax.nn.relu(x))


ACTIVATIONS: dict[str, Callable] = {
    "gelu": partial(jax.nn.gelu, approximate=True),
    "gelu_tanh": partial(jax.nn.gelu, approximate=True),
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "sq_relu": squared_relu,
}


def rope_freqs(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin tables (..., head_dim/2) in fp32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, D); cos/sin (..., S, D/2) broadcast over heads."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Attention (pure-XLA chunked online-softmax — also the Pallas kernel oracle)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _mask_bias(q_pos, k_pos, mask_type: str, window: int, prefix_len: int):
    """Additive bias in fp32 for the given mask type: (Q,K) for 1-d
    ``q_pos``, (B,Q,K) for per-row (batched) ``q_pos`` (B,Q) — the
    serve engine's per-slot decode positions."""
    qp = q_pos[..., :, None]
    kp = k_pos[None, :]
    if mask_type == "full":
        allowed = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    elif mask_type == "causal":
        allowed = kp <= qp
    elif mask_type == "local":
        allowed = (kp <= qp) & (kp > qp - window)
    elif mask_type == "prefix":
        allowed = (kp <= qp) | (kp < prefix_len)
    else:  # pragma: no cover
        raise ValueError(mask_type)
    return jnp.where(allowed, 0.0, NEG_INF)


def attention(
    q: jax.Array,               # (B, Sq, H, D)
    k: jax.Array,               # (B, Sk, K, D)
    v: jax.Array,               # (B, Sk, K, D)
    *,
    mask_type: str = "causal",
    window: int = 0,
    prefix_len: int = 0,
    q_offset: Any = 0,          # position of q[0]: scalar, or (B,) per-row
    kv_len: Optional[jax.Array] = None,  # valid kv length (decode w/ cache):
                                         # scalar, or (B,) per-row

    chunk: int = 512,
    softmax_scale: Optional[float] = None,
    logit_softcap: float = 0.0,
    bf16_probs: bool = False,
) -> jax.Array:
    """Memory-bounded attention: lax.scan over KV chunks with online softmax.

    Handles GQA (H a multiple of K), causal / local / prefix / full masks and
    decode-with-cache (Sq small, kv_len masks the unwritten cache tail).
    ``q_offset``/``kv_len`` may be per-row (B,) vectors — the serve engine's
    per-slot cache positions — in which case the mask bias is (B, Q, K).
    """
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    Dv = v.shape[-1]
    assert H % K == 0
    G = H // K
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)

    # GQA + tensor parallelism: the (K, G) head split below would break H-dim
    # sharding whenever K doesn't divide the model axis (e.g. 48 heads as
    # 8x6 on a 16-way axis -> replicated attention).  When H divides the
    # axis but K doesn't, materialize kv per q-head instead (cheap: kv is
    # the small side of GQA) and keep full head-TP.
    # (Sq == 1 decode excluded: repeating would amplify the KV-cache read,
    # and decode attention compute is negligible anyway.)
    ctx = current_ctx()
    if G > 1 and Sq > 1 and ctx is not None and ctx.mesh is not None:
        m = 1
        ax = ctx.rules.get("act_heads")
        for a in (ax if isinstance(ax, (tuple, list)) else [ax] if ax else []):
            m *= ctx.mesh.shape.get(a, 1)
        if m > 1 and K % m and H % m == 0:
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
            K, G = H, 1

    sdt = jnp.bfloat16 if bf16_probs else jnp.float32
    qf = (q.astype(jnp.float32) * scale).astype(sdt).reshape(B, Sq, K, G, D)
    # (B,K,G,Sq,D): the kv-chunk dot then writes scores directly in the
    # (b,k,g,q,s) carry layout — avoids a full-score-tensor transpose.
    qt = qf.transpose(0, 2, 3, 1, 4)
    qo = jnp.asarray(q_offset)
    # per-row offsets (B,) -> per-row positions (B, Sq); scalar -> (Sq,)
    q_pos = (qo[:, None] if qo.ndim else qo) + jnp.arange(Sq)
    kl = None if kv_len is None else jnp.asarray(kv_len)

    if Sk <= chunk or Sq == 1:
        # single-block path (decode or short sequences)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(sdt),
                       preferred_element_type=jnp.float32)
        if logit_softcap > 0:
            s = jnp.tanh(s / logit_softcap) * logit_softcap
        bias = _mask_bias(q_pos, jnp.arange(Sk), mask_type, window, prefix_len)
        if kl is not None:
            lim = kl[:, None, None] if kl.ndim else kl
            bias = bias + jnp.where(jnp.arange(Sk) < lim, 0.0, NEG_INF)
        # (B,Q,K) bias aligns at the batch axis of the (b,k,g,q,s) scores
        s = s + (bias[:, None, None] if bias.ndim == 3 else bias)
        p = jax.nn.softmax(s, axis=-1).astype(sdt)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(sdt),
                       preferred_element_type=jnp.float32)
        return o.reshape(B, Sq, H, Dv).astype(q.dtype)

    n_chunks = (Sk + chunk - 1) // chunk
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, K, D)
    vc = v.reshape(B, n_chunks, chunk, K, Dv)

    def step(carry, inputs):
        m, l, acc = carry
        kb, vb, idx = inputs
        k_pos = idx * chunk + jnp.arange(chunk)
        # bf16_probs: the (Sq x chunk) score tensor — the dominant HBM
        # traffic of the XLA attention path (EXPERIMENTS §Perf cell A) —
        # stays bf16 end-to-end; only the running max/denominator/output
        # accumulator carries are fp32.
        s = jnp.einsum("bkgqd,bskd->bkgqs", qt, kb.astype(sdt),
                       preferred_element_type=sdt)
        if logit_softcap > 0:
            s = jnp.tanh(s / logit_softcap) * logit_softcap
        bias = _mask_bias(q_pos, k_pos, mask_type, window, prefix_len)
        lim = Sk if kl is None else (kl[:, None, None] if kl.ndim else kl)
        bias = (bias + jnp.where(k_pos < lim, 0.0, NEG_INF)).astype(sdt)
        s = s + (bias[:, None, None] if bias.ndim == 3 else bias)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1).astype(jnp.float32))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None].astype(sdt))
        l_new = l * alpha + jnp.sum(p, axis=-1, dtype=jnp.float32)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(sdt), vb.astype(sdt),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step,
        (m0, l0, a0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n_chunks)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer with optional MLA and KV cache
# ---------------------------------------------------------------------------


def gqa_defs(cfg, layers_prefix: Tuple[int, ...] = ()) -> dict:
    """Param defs for a standard GQA attention layer (optionally stacked)."""
    D = cfg.head_dim
    lp = layers_prefix
    la = ("layers",) * len(lp)
    defs = {
        "wq": ParamDef(lp + (cfg.d_model, cfg.n_heads, D), la + ("w_embed", "w_heads", "w_qk"), cfg.param_dtype),
        "wk": ParamDef(lp + (cfg.d_model, cfg.n_kv_heads, D), la + ("w_embed", "w_kv_heads", "w_qk"), cfg.param_dtype),
        "wv": ParamDef(lp + (cfg.d_model, cfg.n_kv_heads, D), la + ("w_embed", "w_kv_heads", "w_qk"), cfg.param_dtype),
        "wo": ParamDef(lp + (cfg.n_heads, D, cfg.d_model), la + ("w_heads", "w_qk", "w_embed"), cfg.param_dtype),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef(lp + (D,), la + ("w_qk",), cfg.param_dtype, "zeros")
        defs["k_norm"] = ParamDef(lp + (D,), la + ("w_qk",), cfg.param_dtype, "zeros")
    return defs


def _row_update(cache_arr: jax.Array, fresh: jax.Array, idx: jax.Array):
    """Write ``fresh`` (B, S, ...) into ``cache_arr`` (B, max, ...), each
    row at its own offset ``idx`` (B,) — the per-slot KV-cache write.
    (dynamic_update_slice clamps an out-of-range start to the cache edge;
    only a retired serve slot ever overflows, and its row is fully
    overwritten at the next admission.)"""
    fresh = fresh.astype(cache_arr.dtype)

    def one(c, f, i):
        return jax.lax.dynamic_update_slice(c, f, (i,) + (0,) * (c.ndim - 1))

    return jax.vmap(one)(cache_arr, fresh, idx)


def gqa_attention(
    p: dict,
    x: jax.Array,                      # (B, S, E)
    cfg,
    *,
    mask_type: str,
    window: int = 0,
    prefix_len: int = 0,
    positions: Optional[jax.Array] = None,   # (S,) or per-row (B, S)
    cache: Optional[dict] = None,      # {"k","v": (B, max, K, D), "len": (B,)}
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    seq_lens: Optional[jax.Array] = None,    # (B,) valid prefix per row
                                             # (batched padded prefill)
) -> Tuple[jax.Array, Optional[dict]]:
    B, S, E = x.shape
    D = cfg.head_dim
    cdt = cfg.compute_dtype
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"].astype(cdt))
    if cross_kv is None:
        k = jnp.einsum("bse,ekd->bskd", x, p["wk"].astype(cdt))
        v = jnp.einsum("bse,ekd->bskd", x, p["wv"].astype(cdt))
    else:
        k, v = cross_kv

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if cross_kv is None:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if positions is None:
        positions = jnp.arange(S)
    q_offset = positions[:, 0] if positions.ndim == 2 else positions[0]

    if cfg.rope_theta > 0 and cross_kv is None:
        cos, sin = rope_freqs(positions, D, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    kv_len = None
    new_cache = None
    if cache is not None and cross_kv is None:
        # per-row positions: "len" is a (B,) vector — each row (serve
        # slot) writes and attends at its own offset, so one decode batch
        # can mix prompt lengths (admission rewinds just its row's len)
        idx = cache["len"]
        Wc = cache["k"].shape[1]
        ring = mask_type == "local" and Wc == window and window > 0
        # padded batched prefill: each row's valid prefix ends at seq_lens[r];
        # garbage keys past it sit at positions >= idx + seq_lens, which the
        # causal/local/prefix position masks already exclude for every valid
        # query, and kv_len masks the rest at decode.
        S_eff = S if seq_lens is None else seq_lens
        if ring and S > 1:
            # prefill a ring buffer: attend over the fresh full-length k/v
            # with the local mask, then store the last W tokens at slots
            # pos % W (softmax is order-free; RoPE already applied).
            if seq_lens is not None:
                # per-row gather: ring slot j holds the highest valid
                # position congruent to j mod Wc (== the roll below when the
                # row is exactly full; rows shorter than the window leave
                # garbage at slots >= seq_lens, masked at decode by kv_len)
                j = jnp.arange(Wc)[None, :]
                lv = seq_lens[:, None]
                src = jnp.clip(j + Wc * ((lv - 1 - j) // Wc), 0, S - 1)
                rk = jnp.take_along_axis(k, src[..., None, None], axis=1)
                rv = jnp.take_along_axis(v, src[..., None, None], axis=1)
            elif S >= Wc:
                rk = jnp.roll(k[:, -Wc:], S % Wc, axis=1)
                rv = jnp.roll(v[:, -Wc:], S % Wc, axis=1)
            else:
                pad = ((0, 0), (0, Wc - S), (0, 0), (0, 0))
                rk, rv = jnp.pad(k, pad), jnp.pad(v, pad)
            new_cache = {"k": rk.astype(cache["k"].dtype),
                         "v": rv.astype(cache["v"].dtype), "len": idx + S_eff}
            q_offset = idx
        elif ring:
            # decode: write at slot idx % W; all live entries are in-window
            slot = jax.lax.rem(idx, Wc)
            k_all = _row_update(cache["k"], k, slot)
            v_all = _row_update(cache["v"], v, slot)
            new_cache = {"k": k_all, "v": v_all, "len": idx + S}
            k, v = k_all.astype(cdt), v_all.astype(cdt)
            kv_len = jnp.minimum(idx + S, Wc)
            mask_type = "full"   # ring membership IS the window mask
            q_offset = idx
        else:
            k_all = _row_update(cache["k"], k, idx)
            v_all = _row_update(cache["v"], v, idx)
            new_cache = {"k": k_all, "v": v_all, "len": idx + S_eff}
            k, v = k_all.astype(cdt), v_all.astype(cdt)
            kv_len = idx + S_eff
            q_offset = idx

    scale = cfg.softmax_scale if cfg.softmax_scale else None
    # sequence-parallel attention (act_q_seq -> model via rules override):
    # shards attention compute over q positions when head count cannot use
    # the model axis (MQA / odd head counts) — kv stays replicated (tiny).
    q = logical(q, ("act_batch", "act_q_seq", "act_heads", None))
    out = attention(
        q, k, v,
        mask_type=mask_type, window=window, prefix_len=prefix_len,
        q_offset=q_offset, kv_len=kv_len, chunk=cfg.attn_chunk,
        softmax_scale=scale, logit_softcap=cfg.attn_softcap,
        bf16_probs=cfg.opt_bf16_probs,
    )
    out = logical(out, ("act_batch", "act_q_seq", "act_heads", None))
    y = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(cdt))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP / GLU feed-forward
# ---------------------------------------------------------------------------


def ffn_defs(cfg, d_ff: Optional[int] = None, layers_prefix: Tuple[int, ...] = ()) -> dict:
    d_ff = d_ff or cfg.d_ff
    lp = layers_prefix
    la = ("layers",) * len(lp)
    defs = {
        "w_up": ParamDef(lp + (cfg.d_model, d_ff), la + ("w_embed", "w_mlp"), cfg.param_dtype),
        "w_down": ParamDef(lp + (d_ff, cfg.d_model), la + ("w_mlp", "w_embed"), cfg.param_dtype),
    }
    if cfg.glu:
        defs["w_gate"] = ParamDef(lp + (cfg.d_model, d_ff), la + ("w_embed", "w_mlp"), cfg.param_dtype)
    return defs


def ffn(p: dict, x: jax.Array, cfg) -> jax.Array:
    cdt = cfg.compute_dtype
    act = ACTIVATIONS[cfg.activation]
    h = jnp.einsum("bse,ef->bsf", x, p["w_up"].astype(cdt))
    if cfg.glu:
        g = jnp.einsum("bse,ef->bsf", x, p["w_gate"].astype(cdt))
        h = act(g) * h
    else:
        h = act(h)
    h = logical(h, ("act_batch", "act_seq", "act_mlp"))
    return jnp.einsum("bsf,fe->bse", h, p["w_down"].astype(cdt))


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (mamba2 / recurrentgemma blocks)
# ---------------------------------------------------------------------------


def causal_conv1d(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None,
                  lengths: Optional[jax.Array] = None):
    """x (B, S, C), w (W, C) depthwise causal conv.

    Returns (y, new_state) where state is the last W-1 inputs (B, W-1, C).
    ``lengths`` (B,) marks each row's valid prefix under right-padded
    batched prefill: the carried state is then gathered per row at its own
    boundary instead of from the padded tail (``lengths[r] == S`` for every
    row reproduces the unpadded slice exactly).
    """
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    if W == 1:
        new_state = None
    elif lengths is None:
        new_state = xp[:, -(W - 1):, :]
    else:
        # row r's last W-1 valid inputs live at xp[lengths[r] : lengths[r]+W-1]
        idx = lengths[:, None] + jnp.arange(W - 1)[None, :]
        new_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    y = jnp.zeros_like(x)
    for i in range(W):
        y = y + xp[:, i : i + x.shape[1], :] * w[i]
    return y, new_state
