"""Grouped, capacity-based, sort-compacted Mixture-of-Experts FFN.

Design (GShard/Switch-style, adapted to a 2-D TPU mesh):

* Tokens are split into ``G`` dispatch *groups* aligned with the data-
  parallel sharding, so dispatch gathers never cross data shards.
* Within each group, assignments (token, expert) are sorted by expert and
  compacted into an ``(E, C)`` slot table (C = capacity).  Overflow tokens
  are dropped (capacity_factor controls slack) — weights of dropped slots
  are zero, preserving differentiability.
* Expert matmuls are dense einsums over the slot table, sharded
  ``experts -> model`` (expert parallelism); when E does not divide the
  model axis (mixtral E=8 on a 16-way axis) the resolver falls back to
  sharding the expert FFN dim (tensor parallelism inside experts).

FLOPs: 3 * N * top_k * capacity_factor * d_model * d_ff_expert per layer —
the capacity-factor overhead (not x E / top_k dense waste) is visible in the
roofline's MODEL_FLOPS / HLO_FLOPs ratio and discussed in EXPERIMENTS.md.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import current_ctx, logical
from repro.models.layers import ACTIVATIONS, ParamDef


def moe_defs(cfg, layers_prefix: Tuple[int, ...] = ()) -> dict:
    E, dff = cfg.n_experts, cfg.d_ff_expert
    lp = layers_prefix
    la = ("layers",) * len(lp)
    defs = {
        # router output dim (E ~ 8-160) stays replicated: sharding it forces
        # an fp32 all-gather of the full prob tensor before top_k.
        "router": ParamDef(lp + (cfg.d_model, E), la + ("w_embed", None), cfg.param_dtype),
        "w_up": ParamDef(lp + (E, cfg.d_model, dff), la + ("w_experts", "w_embed", "w_expert_mlp"), cfg.param_dtype),
        "w_gate": ParamDef(lp + (E, cfg.d_model, dff), la + ("w_experts", "w_embed", "w_expert_mlp"), cfg.param_dtype),
        "w_down": ParamDef(lp + (E, dff, cfg.d_model), la + ("w_experts", "w_expert_mlp", "w_embed"), cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        ds = cfg.d_ff_expert * cfg.n_shared_experts
        defs["shared_up"] = ParamDef(lp + (cfg.d_model, ds), la + ("w_embed", "w_mlp"), cfg.param_dtype)
        defs["shared_gate"] = ParamDef(lp + (cfg.d_model, ds), la + ("w_embed", "w_mlp"), cfg.param_dtype)
        defs["shared_down"] = ParamDef(lp + (ds, cfg.d_model), la + ("w_mlp", "w_embed"), cfg.param_dtype)
    return defs


def _n_groups(cfg, n_tokens: int) -> int:
    if cfg.moe_groups > 0:
        return cfg.moe_groups
    ctx = current_ctx()
    g = 1
    if ctx is not None and ctx.mesh is not None:
        for ax in ("pod", "data"):
            g *= ctx.mesh.shape.get(ax, 1)
    while n_tokens % g:
        g //= 2
    return max(g, 1)


def moe_ffn(p: dict, x: jax.Array, cfg, *, return_aux: bool = False,
            row_groups: bool = False):
    """x (B, S, E_model) -> (B, S, E_model) [, aux dict].

    ``row_groups=True`` pins one dispatch group per batch row (G = B), so
    expert capacity is a per-row resource: row r's routing (and drops) are
    then independent of what shares the batch.  The serve engine's batched
    admission uses this — a k-request prefill routes each request exactly
    as its own single-row prefill would.
    """
    B, S, d = x.shape
    cdt = cfg.compute_dtype
    act = ACTIVATIONS[cfg.activation]
    E, k = cfg.n_experts, cfg.top_k
    N = B * S
    G = B if row_groups else _n_groups(cfg, N)
    n = N // G  # tokens per group
    # capacity per (group, expert)
    C = max(int(math.ceil(n * k / E * cfg.capacity_factor)), 4)
    C = min(C, n * k)

    xf = x.reshape(G, n, d)
    xf = logical(xf, ("act_group", None, "act_embed"))

    # --- routing (fp32) ---
    logits = jnp.einsum("gnd,de->gne", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)           # (G, n, k)
    if cfg.name.startswith("deepseek"):
        # deepseek-v2 normalizes the top-k gate weights
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- sort-compact into (G, E, C) slot table ---
    e_flat = expert_ids.reshape(G, n * k)                      # (G, nk)
    w_flat = gate_vals.reshape(G, n * k)
    tok_flat = jnp.broadcast_to(jnp.arange(n)[:, None], (n, k)).reshape(n * k)
    sort_idx = jnp.argsort(e_flat, axis=-1)                    # stable
    e_sorted = jnp.take_along_axis(e_flat, sort_idx, axis=-1)
    w_sorted = jnp.take_along_axis(w_flat, sort_idx, axis=-1)
    tok_sorted = tok_flat[sort_idx]                            # (G, nk)

    # position within expert group: count of earlier slots w/ same expert
    counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(e_sorted)   # (G, E)
    offsets = jnp.cumsum(counts, axis=-1) - counts                     # (G, E)
    pos = jnp.arange(n * k)[None, :] - jnp.take_along_axis(offsets, e_sorted, axis=-1)
    keep = pos < C

    # scatter token ids into the slot table; slot n is the padding row
    slot_tok = jnp.full((G, E * C), n, jnp.int32)
    slot_w = jnp.zeros((G, E * C), jnp.float32)
    flat_slot = e_sorted * C + jnp.where(keep, pos, 0)
    flat_slot = jnp.where(keep, flat_slot, E * C)  # OOB drop (scatter mode)
    dims = jax.lax.ScatterDimensionNumbers(
        update_window_dims=(), inserted_window_dims=(0,),
        scatter_dims_to_operand_dims=(0,))

    def scat(tab, idx, upd):
        return jax.lax.scatter(
            tab, idx[:, None], upd, dims,
            mode=jax.lax.GatherScatterMode.FILL_OR_DROP)

    slot_tok = jax.vmap(scat)(slot_tok, flat_slot, tok_sorted.astype(jnp.int32))
    slot_w = jax.vmap(scat)(slot_w, flat_slot, w_sorted)
    slot_tok = slot_tok.reshape(G, E, C)
    slot_w = slot_w.reshape(G, E, C)

    # --- gather -> expert matmuls -> weighted scatter-add ---
    xpad = jnp.concatenate([xf, jnp.zeros((G, 1, d), xf.dtype)], axis=1)
    xe = jnp.take_along_axis(
        xpad[:, :, None, :], slot_tok.reshape(G, E * C)[:, :, None, None], axis=1
    ).reshape(G, E, C, d)
    xe = logical(xe, ("act_group", "act_experts", "act_cap", "act_embed"))

    h = jnp.einsum("gecd,edf->gecf", xe.astype(cdt), p["w_up"].astype(cdt))
    g = jnp.einsum("gecd,edf->gecf", xe.astype(cdt), p["w_gate"].astype(cdt))
    h = act(g) * h
    h = logical(h, ("act_group", "act_experts", "act_cap", "act_mlp"))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(cdt))
    ye = ye * slot_w[..., None].astype(cdt)

    y = jnp.zeros((G, n + 1, d), cdt)
    y = jax.vmap(lambda acc, idx, upd: acc.at[idx].add(upd))(
        y, slot_tok.reshape(G, E * C), ye.reshape(G, E * C, d))
    y = y[:, :n].reshape(B, S, d)

    if cfg.n_shared_experts:
        hs = jnp.einsum("bsd,df->bsf", x.astype(cdt), p["shared_up"].astype(cdt))
        gs = jnp.einsum("bsd,df->bsf", x.astype(cdt), p["shared_gate"].astype(cdt))
        y = y + jnp.einsum("bsf,fd->bsd", act(gs) * hs, p["shared_down"].astype(cdt))

    if return_aux:
        # load-balancing aux loss (Switch): E * sum(frac_tokens * frac_prob)
        me = jnp.mean(probs, axis=(0, 1))                       # (E,)
        assign = jax.nn.one_hot(expert_ids[..., 0], E)          # top-1 fraction
        fe = jnp.mean(assign, axis=(0, 1))
        aux = {"load_balance": E * jnp.sum(me * fe),
               "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
        return y.astype(x.dtype), aux
    return y.astype(x.dtype)
