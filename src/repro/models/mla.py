"""Multi-head Latent Attention (DeepSeek-V2).

Train/prefill: decompress the kv latent to per-head K/V (standard path).
Decode: *absorbed* path — fold W_uk into the query and W_uv into the output
projection so attention runs directly against the cached (kv_lora + rope)
latents.  The cache is (B, S, kv_lora + qk_rope_dim) — 576 floats/token
instead of 2*128*192: this IS the paper-technique-relevant memory saving.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import logical
from repro.models.layers import (
    NEG_INF, ParamDef, _row_update, apply_rope, attention, rms_norm,
    rope_freqs,
)


def mla_defs(cfg, layers_prefix: Tuple[int, ...] = ()) -> dict:
    lp = layers_prefix
    la = ("layers",) * len(lp)
    H = cfg.n_heads
    return {
        "wq_a": ParamDef(lp + (cfg.d_model, cfg.q_lora), la + ("w_embed", "w_lora"), cfg.param_dtype),
        "q_a_norm": ParamDef(lp + (cfg.q_lora,), la + ("w_lora",), cfg.param_dtype, "zeros"),
        "wq_b": ParamDef(lp + (cfg.q_lora, H, cfg.qk_nope_dim + cfg.qk_rope_dim), la + ("w_lora", "w_heads", "w_qk"), cfg.param_dtype),
        "wkv_a": ParamDef(lp + (cfg.d_model, cfg.kv_lora + cfg.qk_rope_dim), la + ("w_embed", "w_lora"), cfg.param_dtype),
        "kv_a_norm": ParamDef(lp + (cfg.kv_lora,), la + ("w_lora",), cfg.param_dtype, "zeros"),
        "wk_b": ParamDef(lp + (cfg.kv_lora, H, cfg.qk_nope_dim), la + ("w_lora", "w_heads", "w_qk"), cfg.param_dtype),
        "wv_b": ParamDef(lp + (cfg.kv_lora, H, cfg.v_head_dim), la + ("w_lora", "w_heads", "w_qk"), cfg.param_dtype),
        "wo": ParamDef(lp + (H, cfg.v_head_dim, cfg.d_model), la + ("w_heads", "w_qk", "w_embed"), cfg.param_dtype),
    }


def _project_q(p, x, cfg):
    cdt = cfg.compute_dtype
    q_lat = jnp.einsum("bse,el->bsl", x, p["wq_a"].astype(cdt))
    q_lat = rms_norm(q_lat, p["q_a_norm"], cfg.norm_eps)
    q = jnp.einsum("bsl,lhd->bshd", q_lat, p["wq_b"].astype(cdt))
    return q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]


def _kv_latent(p, x, cfg):
    cdt = cfg.compute_dtype
    kv = jnp.einsum("bse,el->bsl", x, p["wkv_a"].astype(cdt))
    c_kv = rms_norm(kv[..., : cfg.kv_lora], p["kv_a_norm"], cfg.norm_eps)
    k_rope = kv[..., cfg.kv_lora:]
    return c_kv, k_rope


def mla_attention(
    p: dict,
    x: jax.Array,
    cfg,
    *,
    positions: Optional[jax.Array] = None,
    cache: Optional[dict] = None,   # {"ckv": (B,max,kv_lora), "krope": (B,max,R), "len"}
    seq_lens: Optional[jax.Array] = None,   # (B,) valid prefix per row
                                            # (batched padded prefill)
) -> Tuple[jax.Array, Optional[dict]]:
    B, S, _ = x.shape
    cdt = cfg.compute_dtype
    H, Dn, Dr, Dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(Dn + Dr)
    if positions is None:
        positions = jnp.arange(S)

    q_nope, q_rope = _project_q(p, x, cfg)
    cos, sin = rope_freqs(positions, Dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    c_kv, k_rope = _kv_latent(p, x, cfg)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    if cache is None:
        # decompress path (train / one-shot prefill-eval)
        k_nope = jnp.einsum("bsl,lhd->bshd", c_kv, p["wk_b"].astype(cdt))
        v = jnp.einsum("bsl,lhd->bshd", c_kv, p["wv_b"].astype(cdt))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, Dr))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        q_off = positions[:, 0] if positions.ndim == 2 else positions[0]
        out = attention(q, k, v, mask_type="causal", q_offset=q_off,
                        chunk=cfg.attn_chunk, softmax_scale=scale,
                        bf16_probs=cfg.opt_bf16_probs)
        out = logical(out, ("act_batch", "act_seq", "act_heads", None))
        y = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(cdt))
        return y, None

    # --- cached path ---
    idx = cache["len"]                       # (B,) per-row positions
    ckv_all = _row_update(cache["ckv"], c_kv, idx)
    kr_all = _row_update(cache["krope"], k_rope, idx)
    # padded batched prefill: garbage latents past a row's seq_lens sit at
    # positions >= idx + seq_lens — excluded for every valid query by the
    # causal mask here and by kv_len at decode
    new_cache = {"ckv": ckv_all, "krope": kr_all,
                 "len": idx + (S if seq_lens is None else seq_lens)}

    if S > 1:
        # Prefill: write the latent cache but run *chunked decompressed*
        # attention — the absorbed formulation materializes full (Sq x Sk)
        # scores, which at 32k is exactly the quadratic blow-up flash-style
        # chunking avoids (see EXPERIMENTS.md: 221 GB/dev before this path).
        k_nope = jnp.einsum("bsl,lhd->bshd", c_kv, p["wk_b"].astype(cdt))
        v = jnp.einsum("bsl,lhd->bshd", c_kv, p["wv_b"].astype(cdt))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, Dr))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = attention(q, k, v, mask_type="causal", q_offset=idx,
                        chunk=cfg.attn_chunk, softmax_scale=scale,
                        bf16_probs=cfg.opt_bf16_probs)
        out = logical(out, ("act_batch", "act_seq", "act_heads", None))
        y = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(cdt))
        return y, new_cache

    # --- absorbed decode path (S == 1): attention directly on the latents ---
    kv_len = idx + S
    Sk = ckv_all.shape[1]

    # absorb: q_c = q_nope @ W_uk  -> (B,S,H,kv_lora)
    q_c = jnp.einsum("bshd,lhd->bshl", q_nope, p["wk_b"].astype(cdt))
    s = jnp.einsum("bshl,btl->bhst", q_c, ckv_all.astype(cdt)).astype(jnp.float32)
    s = s + jnp.einsum("bshd,btd->bhst", q_rope, kr_all.astype(cdt)).astype(jnp.float32)
    s = s * scale
    q_pos = idx[:, None] + jnp.arange(S)     # (B, S) per-row positions
    t_pos = jnp.arange(Sk)
    allowed = (t_pos[None, None, :] <= q_pos[:, :, None]) \
        & (t_pos[None, None, :] < kv_len[:, None, None])   # (B, S, Sk)
    s = jnp.where(allowed[:, None], s, NEG_INF)            # s: (B, H, S, Sk)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhst,btl->bshl", pr.astype(cdt), ckv_all.astype(cdt))
    out = jnp.einsum("bshl,lhd->bshd", o_lat, p["wv_b"].astype(cdt))
    out = logical(out, ("act_batch", "act_seq", "act_heads", None))
    y = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(cdt))
    return y, new_cache


def mla_cache_defs(cfg, batch: int, max_len: int, layers_prefix: Tuple[int, ...] = ()) -> dict:
    lp = layers_prefix
    la = ("layers",) * len(lp)
    cdt = cfg.compute_dtype
    return {
        "ckv": ParamDef(lp + (batch, max_len, cfg.kv_lora), la + ("cache_batch", "cache_seq", None), cdt, "zeros"),
        "krope": ParamDef(lp + (batch, max_len, cfg.qk_rope_dim), la + ("cache_batch", "cache_seq", None), cdt, "zeros"),
        "len": ParamDef(lp + (batch,), la + ("cache_batch",), jnp.int32, "zeros"),
    }
