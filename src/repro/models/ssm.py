"""Mamba-2 SSD (state-space duality) blocks.

``ssd_chunked`` is the pure-XLA chunked algorithm (also the oracle for the
Pallas kernel in ``repro.kernels.ssd``): quadratic attention-like math
*within* MXU-aligned chunks, a linear recurrence *across* chunks, carried by
``lax.scan``.  ``ssd_sequential`` is the slow per-token reference used in
tests.  ``ssd_step`` is the O(1)-per-token decode update.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import logical
from repro.models.layers import ParamDef, causal_conv1d, rms_norm


# ---------------------------------------------------------------------------
# Core SSD math.  Shapes: x (B,S,H,P), dt (B,S,H) (post-softplus),
# A (H,) negative, Bm/Cm (B,S,N) (n_groups=1, broadcast over heads).
# ---------------------------------------------------------------------------


def ssd_sequential(x, dt, A, Bm, Cm, init_state=None):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    h0 = jnp.zeros((B, H, P, N), jnp.float32) if init_state is None else init_state

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,H,P) (B,H) (B,N) (B,N)
        da = jnp.exp(dtt.astype(jnp.float32) * A)                    # (B,H)
        dbx = dtt[..., None, None] * xt[..., None] * bt[:, None, None, :]
        h = da[..., None, None] * h + dbx
        y = jnp.einsum("bhpn,bn->bhp", h, ct.astype(jnp.float32))
        return h, y

    hT, ys = jax.lax.scan(
        step, h0,
        (x.swapaxes(0, 1).astype(jnp.float32), dt.swapaxes(0, 1),
         Bm.swapaxes(0, 1).astype(jnp.float32), Cm.swapaxes(0, 1).astype(jnp.float32)),
    )
    return ys.swapaxes(0, 1).astype(x.dtype), hT  # (B,S,H,P), (B,H,P,N)


def _segsum(z):
    """z (..., L) -> (..., L, L) lower-tri cumulative sums: out[i,j]=sum(z[j+1..i])."""
    L = z.shape[-1]
    cs = jnp.cumsum(z, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD; exact (up to fp) match of ssd_sequential."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    S0 = S
    if S % L:
        # pad with identity steps (dt=0 -> decay 1, contribution 0)
        pad = L - S % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // L

    xc = x.reshape(B, nc, L, H, P).astype(jnp.float32)
    dtc = dt.reshape(B, nc, L, H).astype(jnp.float32)
    bc = Bm.reshape(B, nc, L, N).astype(jnp.float32)
    cc = Cm.reshape(B, nc, L, N).astype(jnp.float32)
    da = dtc * A  # (B,nc,L,H) log-decay per step

    h0 = jnp.zeros((B, H, P, N), jnp.float32) if init_state is None else init_state.astype(jnp.float32)

    def chunk_step(h, inp):
        xb, dtb, bb, cb, dab = inp  # (B,L,H,P) (B,L,H) (B,L,N) (B,L,N) (B,L,H)
        cum = jnp.cumsum(dab, axis=1)                      # (B,L,H)
        # intra-chunk: decay(i,j) = exp(cum_i - cum_j) for j <= i
        Lmat = jnp.exp(_segsum(dab.transpose(0, 2, 1)))    # (B,H,L,L)
        scores = jnp.einsum("bin,bjn->bij", cb, bb)        # (B,L,L)
        w = scores[:, None] * Lmat                         # (B,H,L,L)
        xdt = xb * dtb[..., None]                          # (B,L,H,P)
        y_intra = jnp.einsum("bhij,bjhp->bihp", w, xdt)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cb, h, jnp.exp(cum))
        # new carried state
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)       # (B,L,H)
        hc = jnp.einsum("bjn,bjhp,bjh->bhpn", bb, xdt, decay_to_end)
        h_new = jnp.exp(cum[:, -1])[:, :, None, None] * h + hc
        return h_new, y_intra + y_inter

    hT, ys = jax.lax.scan(
        chunk_step, h0,
        (xc.swapaxes(0, 1), dtc.swapaxes(0, 1), bc.swapaxes(0, 1),
         cc.swapaxes(0, 1), da.swapaxes(0, 1)),
    )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)[:, :S0]
    return y.astype(x.dtype), hT


def ssd_step(state, xt, dtt, A, bt, ct):
    """One decode step.  state (B,H,P,N); xt (B,H,P); dtt (B,H); bt/ct (B,N)."""
    da = jnp.exp(dtt.astype(jnp.float32) * A)
    dbx = dtt[..., None, None] * xt.astype(jnp.float32)[..., None] * bt.astype(jnp.float32)[:, None, None, :]
    state = da[..., None, None] * state + dbx
    y = jnp.einsum("bhpn,bn->bhp", state, ct.astype(jnp.float32))
    return y.astype(xt.dtype), state


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------


def mamba2_defs(cfg, layers_prefix: Tuple[int, ...] = ()) -> dict:
    lp = layers_prefix
    la = ("layers",) * len(lp)
    di, N, H = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads
    G = cfg.ssm_ngroups
    d_in_proj = 2 * di + 2 * G * N + H   # z, x, B, C, dt
    conv_ch = di + 2 * G * N             # conv over x, B, C
    return {
        "in_proj": ParamDef(lp + (cfg.d_model, d_in_proj), la + ("w_embed", "w_mlp"), cfg.param_dtype),
        "conv_w": ParamDef(lp + (cfg.conv_width, conv_ch), la + ("w_conv", "w_mlp"), cfg.param_dtype, scale=0.2),
        "conv_b": ParamDef(lp + (conv_ch,), la + ("w_mlp",), cfg.param_dtype, "zeros"),
        "A_log": ParamDef(lp + (H,), la + ("w_state",), jnp.float32, "ones"),
        "D": ParamDef(lp + (H,), la + ("w_state",), jnp.float32, "ones"),
        "dt_bias": ParamDef(lp + (H,), la + ("w_state",), jnp.float32, "zeros"),
        "out_norm": ParamDef(lp + (di,), la + ("w_mlp",), cfg.param_dtype, "zeros"),
        "out_proj": ParamDef(lp + (di, cfg.d_model), la + ("w_mlp", "w_embed"), cfg.param_dtype),
    }


def mamba2_cache_defs(cfg, batch: int, layers_prefix: Tuple[int, ...] = ()) -> dict:
    lp = layers_prefix
    la = ("layers",) * len(lp)
    di, N, H, P = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads, cfg.ssm_headdim
    conv_ch = di + 2 * cfg.ssm_ngroups * N
    return {
        "conv": ParamDef(lp + (batch, cfg.conv_width - 1, conv_ch), la + ("cache_batch", None, "cache_heads"), cfg.compute_dtype, "zeros"),
        "ssm": ParamDef(lp + (batch, H, P, N), la + ("cache_batch", "cache_heads", None, "cache_state"), jnp.float32, "zeros"),
        "len": ParamDef(lp + (batch,), la + ("cache_batch",), jnp.int32, "zeros"),
    }


def _split_in_proj(zxbcdt, cfg):
    di, N, H = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads
    G = cfg.ssm_ngroups
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * G * N]
    dt = zxbcdt[..., 2 * di + 2 * G * N:]
    return z, xbc, dt


def mamba2_block(p: dict, u: jax.Array, cfg, cache: Optional[dict] = None,
                 seq_lens: Optional[jax.Array] = None):
    """u (B, S, E) -> (y, new_cache).

    ``seq_lens`` (B,) marks each row's valid prefix under right-padded
    batched prefill: pad steps become identity SSD updates (dt=0 -> decay 1,
    contribution 0 — the same trick ``ssd_chunked`` uses for its own chunk
    padding), so the carried state h_T ignores every row's padded tail.
    """
    B, S, E = u.shape
    cdt = cfg.compute_dtype
    di, N, H, P = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads, cfg.ssm_headdim

    zxbcdt = jnp.einsum("bse,ef->bsf", u, p["in_proj"].astype(cdt))
    z, xbc, dt_raw = _split_in_proj(zxbcdt, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    if seq_lens is not None:
        valid = jnp.arange(S)[None, :] < seq_lens[:, None]
        dt = jnp.where(valid[..., None], dt, 0.0)

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = causal_conv1d(xbc, p["conv_w"].astype(cdt), conv_state,
                                  lengths=seq_lens)
    xbc = jax.nn.silu(xbc + p["conv_b"].astype(cdt))
    x = xbc[..., :di]
    Bm = xbc[..., di : di + N]
    Cm = xbc[..., di + N :]

    x = x.reshape(B, S, H, P)
    x = logical(x, ("act_batch", "act_seq", "act_heads", None))
    A = -jnp.exp(p["A_log"])

    new_cache = None
    if cache is not None and S == 1:
        y, new_state = ssd_step(cache["ssm"], x[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0])
        y = y[:, None]
        new_cache = {"conv": new_conv, "ssm": new_state, "len": cache["len"] + 1}
    else:
        init = cache["ssm"] if cache is not None else None
        y, hT = ssd_chunked(x, dt, A, Bm, Cm, cfg.ssm_chunk, init_state=init)
        if cache is not None:
            adv = S if seq_lens is None else seq_lens
            new_cache = {"conv": new_conv, "ssm": hT, "len": cache["len"] + adv}

    y = y + x * p["D"][:, None].astype(cdt)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsf,fe->bse", y, p["out_proj"].astype(cdt))
    return out, new_cache
