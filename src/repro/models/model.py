"""Unified model API over all assigned architecture families.

``Model`` exposes:
  param_defs() / init(key)                  declaration + materialization
  loss(params, batch)                       training objective (next-token CE)
  forward(params, batch)                    logits (no cache)
  cache_defs(batch, max_len) / init_cache   decode-state declaration
  prefill(params, batch, cache)             fill cache, return last logits
  decode_step(params, tokens, cache)        one token with cache

Layers are stacked and iterated with ``jax.lax.scan`` (small HLO, fast
compile at 48-64 layers) with ``jax.checkpoint`` rematerialization.
Non-uniform stacks (gemma3 5:1 local:global, recurrentgemma rec-rec-attn,
deepseek first-dense-layer) scan over *groups* with the pattern unrolled
inside the group body.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import logical
from repro.models import layers as L
from repro.models import mla, moe, rglru, ssm
from repro.models.layers import ParamDef


def _norm_def(cfg, lp=()):
    return ParamDef(lp + (cfg.d_model,), ("layers",) * len(lp) + ("w_embed",), cfg.param_dtype, "zeros")


# ---------------------------------------------------------------------------
# Block bodies (single layer).  p is that layer's (unstacked) params.
# ---------------------------------------------------------------------------


def _attn_ffn_block(p, x, cfg, *, kind: str, positions, cache, use_moe: bool,
                    d_ff: Optional[int] = None, seq_lens=None):
    mask = "causal" if kind == "global" else "local"
    if kind == "prefix":
        mask = "prefix"
    window = cfg.local_window if mask == "local" else 0
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        h, new_c = mla.mla_attention(p["attn"], h, cfg, positions=positions,
                                     cache=cache, seq_lens=seq_lens)
    else:
        h, new_c = L.gqa_attention(
            p["attn"], h, cfg, mask_type=mask, window=window,
            prefix_len=cfg.n_prefix if kind == "prefix" else 0,
            positions=positions, cache=cache, seq_lens=seq_lens)
    x = x + h
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if use_moe:
        # serving admission (seq_lens set): one dispatch group per row, so
        # expert capacity — a per-group resource — can't couple co-admitted
        # requests' routing (see moe_ffn)
        h = moe.moe_ffn(p["mlp"], h, cfg, row_groups=seq_lens is not None)
    else:
        h = L.ffn(p["mlp"], h, cfg)
    x = x + h
    return logical(x, ("act_batch", "act_seq", "act_embed")), new_c


def _attn_block_defs(cfg, lp, *, use_moe: bool, d_ff=None):
    attn = mla.mla_defs(cfg, lp) if cfg.use_mla else L.gqa_defs(cfg, lp)
    mlp = moe.moe_defs(cfg, lp) if use_moe else L.ffn_defs(cfg, d_ff, lp)
    return {"ln1": _norm_def(cfg, lp), "attn": attn, "ln2": _norm_def(cfg, lp), "mlp": mlp}


def _rec_block(p, x, cfg, *, cache, seq_lens=None):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    h, new_c = rglru.rglru_block(p["rec"], h, cfg, cache=cache,
                                 seq_lens=seq_lens)
    x = x + h
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.ffn(p["mlp"], h, cfg)
    return logical(x, ("act_batch", "act_seq", "act_embed")), new_c


def _rec_block_defs(cfg, lp):
    return {"ln1": _norm_def(cfg, lp), "rec": rglru.rglru_defs(cfg, lp),
            "ln2": _norm_def(cfg, lp), "mlp": L.ffn_defs(cfg, None, lp)}


def _mamba_block(p, x, cfg, *, cache, seq_lens=None):
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    h, new_c = ssm.mamba2_block(p["mix"], h, cfg, cache=cache,
                                seq_lens=seq_lens)
    return logical(x + h, ("act_batch", "act_seq", "act_embed")), new_c


# ---------------------------------------------------------------------------
# Cache defs per layer kind
# ---------------------------------------------------------------------------


def _kv_cache_defs(cfg, batch: int, max_len: int, kind: str, lp=()):
    if cfg.use_mla:
        return mla.mla_cache_defs(cfg, batch, max_len, lp)
    la = ("layers",) * len(lp)
    D = cfg.head_dim
    K = cfg.n_kv_heads
    size = max_len
    if kind == "local" and 0 < cfg.local_window < max_len:
        size = cfg.local_window   # ring buffer
    cdt = cfg.compute_dtype
    return {
        "k": ParamDef(lp + (batch, size, K, D), la + ("cache_batch", "cache_seq", "cache_heads", None), cdt, "zeros"),
        "v": ParamDef(lp + (batch, size, K, D), la + ("cache_batch", "cache_seq", "cache_heads", None), cdt, "zeros"),
        # per-row position vector: each batch row (serve slot) decodes at
        # its own offset, so one decode batch can mix prompt lengths
        "len": ParamDef(lp + (batch,), la + ("cache_batch",), jnp.int32, "zeros"),
    }


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Model:
    def __init__(self, cfg):
        self.cfg = cfg

    # ---------------- params ----------------

    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        d = {
            "embed": ParamDef((cfg.vocab, cfg.d_model), ("w_vocab", "w_embed_pod"),
                              cfg.param_dtype, "embed"),
            "final_norm": _norm_def(cfg),
        }
        if not cfg.tie_embeddings:
            d["lm_head"] = ParamDef((cfg.d_model, cfg.vocab), ("w_embed_pod", "w_vocab"), cfg.param_dtype)
        if cfg.pos_embed == "learned":
            d["pos_embed"] = ParamDef((cfg.max_position, cfg.d_model), (None, "w_embed_pod"),
                                      cfg.param_dtype, "embed", scale=0.02)

        fam = cfg.family
        if fam in ("dense", "vlm"):
            if cfg.global_every > 0:   # gemma3-style pattern
                n_local = cfg.global_every - 1
                G = cfg.n_layers // cfg.global_every
                d["groups"] = {
                    "local": _attn_block_defs(cfg, (G, n_local), use_moe=False),
                    "global": _attn_block_defs(cfg, (G,), use_moe=False),
                }
            else:
                d["blocks"] = _attn_block_defs(cfg, (cfg.n_layers,), use_moe=False)
        elif fam == "moe":
            nd = cfg.first_dense_layers
            if nd:
                d["dense_blocks"] = _attn_block_defs(cfg, (nd,), use_moe=False, d_ff=cfg.d_ff)
            d["blocks"] = _attn_block_defs(cfg, (cfg.n_layers - nd,), use_moe=True)
        elif fam == "ssm":
            d["blocks"] = {"ln": _norm_def(cfg, (cfg.n_layers,)),
                           "mix": ssm.mamba2_defs(cfg, (cfg.n_layers,))}
        elif fam == "hybrid":
            G = cfg.n_layers // (cfg.pattern_rec + 1)
            tail = cfg.n_layers - G * (cfg.pattern_rec + 1)
            d["groups"] = {
                "rec": _rec_block_defs(cfg, (G, cfg.pattern_rec)),
                "attn": _attn_block_defs(cfg, (G,), use_moe=False),
            }
            if tail:
                d["tail"] = _rec_block_defs(cfg, (tail,))
        elif fam == "encdec":
            d["enc_pos_embed"] = ParamDef((cfg.enc_seq, cfg.d_model), (None, "w_embed_pod"),
                                          cfg.param_dtype, "embed", scale=0.02)
            d["enc_blocks"] = _attn_block_defs(cfg, (cfg.n_enc_layers,), use_moe=False)
            d["enc_norm"] = _norm_def(cfg)
            blocks = _attn_block_defs(cfg, (cfg.n_layers,), use_moe=False)
            blocks["ln_cross"] = _norm_def(cfg, (cfg.n_layers,))
            blocks["cross"] = L.gqa_defs(cfg, (cfg.n_layers,))
            d["blocks"] = blocks
        else:  # pragma: no cover
            raise ValueError(fam)
        return d

    def init(self, key) -> Dict[str, Any]:
        return L.init_tree(self.param_defs(), key)

    def abstract_params(self):
        return L.abstract_tree(self.param_defs())

    # ---------------- embedding / head ----------------

    def _embed(self, params, tokens, positions=None):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.compute_dtype)
        if cfg.pos_embed == "learned":
            pos = positions if positions is not None else jnp.arange(tokens.shape[1])
            x = x + jnp.take(params["pos_embed"], pos, axis=0).astype(cfg.compute_dtype)
        return logical(x, ("act_batch", "act_seq", "act_embed"))

    def _head(self, params, x):
        cfg = self.cfg
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bse,ev->bsv", x, w.astype(cfg.compute_dtype))
        if cfg.final_softcap > 0:
            logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
        return logical(logits, ("act_batch", "act_seq", "act_vocab"))

    # ---------------- stacks ----------------

    def _maybe_remat(self, fn):
        if self.cfg.remat == "none":
            return fn
        policy = None
        if self.cfg.remat == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)

    def _scan_stack(self, body, x, stacked_params, stacked_cache, extras=()):
        """Scan ``body(p_i, x, c_i) -> (x, c_i')`` over the layer axis."""
        has_cache = stacked_cache is not None

        def f(carry, inp):
            if has_cache:
                p_i, c_i = inp
                y, c_new = body(p_i, carry, c_i, *extras)
                return y, c_new
            y, _ = body(inp, carry, None, *extras)
            return y, 0.0

        f = self._maybe_remat(f)
        xs = (stacked_params, stacked_cache) if has_cache else stacked_params
        x, ys = jax.lax.scan(f, x, xs)
        return x, (ys if has_cache else None)

    def _run_layers(self, params, x, positions, cache, kind_override=None,
                    enc_out=None, seq_lens=None):
        cfg = self.cfg
        fam = cfg.family
        new_cache: Dict[str, Any] = {}

        if fam in ("dense", "vlm", "moe"):
            prefix_kind = "prefix" if fam == "vlm" else None

            if cfg.global_every > 0:  # gemma3 grouped pattern
                def group_body(p_g, x, c_g):
                    def local_body(p_i, x, c_i):
                        return _attn_ffn_block(p_i, x, cfg, kind="local",
                                               positions=positions, cache=c_i,
                                               use_moe=False, seq_lens=seq_lens)
                    c_loc = c_g["local"] if c_g is not None else None
                    x, c_loc_new = self._scan_stack(local_body, x, p_g["local"], c_loc)
                    x, c_glob_new = _attn_ffn_block(
                        p_g["global"], x, cfg, kind="global", positions=positions,
                        cache=(c_g["global"] if c_g is not None else None),
                        use_moe=False, seq_lens=seq_lens)
                    if c_g is None:
                        return x, 0.0
                    return x, {"local": c_loc_new, "global": c_glob_new}

                c = cache.get("groups") if cache else None
                x, c_new = self._scan_stack(group_body, x, params["groups"], c)
                if cache is not None:
                    new_cache["groups"] = c_new
            else:
                def body(p_i, x, c_i, use_moe):
                    kind = prefix_kind or ("local" if cfg.local_window > 0 else "global")
                    return _attn_ffn_block(p_i, x, cfg, kind=kind, positions=positions,
                                           cache=c_i, use_moe=use_moe,
                                           seq_lens=seq_lens)

                if "dense_blocks" in params:  # deepseek first dense layer(s)
                    c = cache.get("dense_blocks") if cache else None
                    x, c_new = self._scan_stack(partial(body, use_moe=False), x,
                                                params["dense_blocks"], c)
                    if cache is not None:
                        new_cache["dense_blocks"] = c_new
                c = cache.get("blocks") if cache else None
                x, c_new = self._scan_stack(partial(body, use_moe=(fam == "moe")), x,
                                            params["blocks"], c)
                if cache is not None:
                    new_cache["blocks"] = c_new

        elif fam == "ssm":
            def body(p_i, x, c_i):
                return _mamba_block(p_i, x, cfg, cache=c_i, seq_lens=seq_lens)
            c = cache.get("blocks") if cache else None
            x, c_new = self._scan_stack(body, x, params["blocks"], c)
            if cache is not None:
                new_cache["blocks"] = c_new

        elif fam == "hybrid":
            def group_body(p_g, x, c_g):
                def rec_body(p_i, x, c_i):
                    return _rec_block(p_i, x, cfg, cache=c_i, seq_lens=seq_lens)
                c_rec = c_g["rec"] if c_g is not None else None
                x, c_rec_new = self._scan_stack(rec_body, x, p_g["rec"], c_rec)
                x, c_attn_new = _attn_ffn_block(
                    p_g["attn"], x, cfg, kind="local", positions=positions,
                    cache=(c_g["attn"] if c_g is not None else None),
                    use_moe=False, seq_lens=seq_lens)
                if c_g is None:
                    return x, 0.0
                return x, {"rec": c_rec_new, "attn": c_attn_new}

            c = cache.get("groups") if cache else None
            x, c_new = self._scan_stack(group_body, x, params["groups"], c)
            if cache is not None:
                new_cache["groups"] = c_new
            if "tail" in params:
                def rec_body(p_i, x, c_i):
                    return _rec_block(p_i, x, cfg, cache=c_i, seq_lens=seq_lens)
                c = cache.get("tail") if cache else None
                x, c_new = self._scan_stack(rec_body, x, params["tail"], c)
                if cache is not None:
                    new_cache["tail"] = c_new

        elif fam == "encdec":
            def body(p_i, x, c_i):
                # self attention (causal, cached) + cross attention + ffn
                h = L.rms_norm(x, p_i["ln1"], cfg.norm_eps)
                sc = c_i["self"] if c_i is not None else None
                h, new_self = L.gqa_attention(p_i["attn"], h, cfg, mask_type="causal",
                                              positions=positions, cache=sc,
                                              seq_lens=seq_lens)
                x = x + h
                h = L.rms_norm(x, p_i["ln_cross"], cfg.norm_eps)
                cdt = cfg.compute_dtype
                if c_i is not None:
                    ck, cv = c_i["cross_k"].astype(cdt), c_i["cross_v"].astype(cdt)
                else:
                    ck = jnp.einsum("bse,ekd->bskd", enc_out, p_i["cross"]["wk"].astype(cdt))
                    cv = jnp.einsum("bse,ekd->bskd", enc_out, p_i["cross"]["wv"].astype(cdt))
                h, _ = L.gqa_attention(p_i["cross"], h, cfg, mask_type="full",
                                       positions=positions, cross_kv=(ck, cv))
                x = x + h
                h = L.rms_norm(x, p_i["ln2"], cfg.norm_eps)
                x = x + L.ffn(p_i["mlp"], h, cfg)
                x = logical(x, ("act_batch", "act_seq", "act_embed"))
                if c_i is None:
                    return x, 0.0
                return x, {"self": new_self, "cross_k": c_i["cross_k"], "cross_v": c_i["cross_v"]}

            c = cache.get("blocks") if cache else None
            x, c_new = self._scan_stack(body, x, params["blocks"], c)
            if cache is not None:
                new_cache["blocks"] = c_new
        else:  # pragma: no cover
            raise ValueError(fam)

        return x, (new_cache if cache is not None else None)

    # ---------------- encoder (whisper) ----------------

    def encode(self, params, frames):
        """frames (B, enc_seq, d_model) precomputed (stub frontend)."""
        cfg = self.cfg
        x = frames.astype(cfg.compute_dtype) + params["enc_pos_embed"].astype(cfg.compute_dtype)
        x = logical(x, ("act_batch", "act_frames", "act_embed"))

        def body(p_i, x, c_i):
            h = L.rms_norm(x, p_i["ln1"], cfg.norm_eps)
            h, _ = L.gqa_attention(p_i["attn"], h, cfg, mask_type="full")
            x = x + h
            h = L.rms_norm(x, p_i["ln2"], cfg.norm_eps)
            x = x + L.ffn(p_i["mlp"], h, cfg)
            return logical(x, ("act_batch", "act_frames", "act_embed")), None

        x, _ = self._scan_stack(body, x, params["enc_blocks"], None)
        return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # ---------------- public API ----------------

    def forward(self, params, batch, positions=None):
        return self._head(params, self._hidden(params, batch, positions))

    def loss(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        if cfg.opt_ce_chunk > 0:
            # chunked cross-entropy: never materialize the full (B, S, V)
            # fp32 logits — scan over sequence chunks, recomputing each
            # chunk's logits (cheap vs the HBM saved; §Perf cell C).
            hidden = self._hidden(params, batch)
            if cfg.family == "vlm":
                hidden = hidden[:, cfg.n_prefix:]
            hid = hidden[:, :-1]
            targets = tokens[:, 1:]
            B, Sm1, E = hid.shape
            C = min(cfg.opt_ce_chunk, Sm1)
            pad = (C - Sm1 % C) % C
            hid = jnp.pad(hid, ((0, 0), (0, pad), (0, 0)))
            tgt = jnp.pad(targets, ((0, 0), (0, pad)))
            valid = jnp.pad(jnp.ones((B, Sm1), jnp.float32), ((0, 0), (0, pad)))
            nc = (Sm1 + pad) // C
            hid = hid.reshape(B, nc, C, E).swapaxes(0, 1)
            tgt = tgt.reshape(B, nc, C).swapaxes(0, 1)
            valid = valid.reshape(B, nc, C).swapaxes(0, 1)

            def body(acc, inp):
                h, t, vl = inp
                lg = self._head(params, h).astype(jnp.float32)
                logz = jax.nn.logsumexp(lg, axis=-1)
                gold = jnp.take_along_axis(lg, t[..., None], axis=-1)[..., 0]
                return acc + jnp.sum((logz - gold) * vl), None

            total, _ = jax.lax.scan(body, jnp.float32(0.0), (hid, tgt, valid))
            loss = total / (B * Sm1)
            return loss, {"loss": loss, "ppl": jnp.exp(loss)}

        logits = self.forward(params, batch)
        if cfg.family == "vlm":  # predict text tokens only (after the prefix)
            logits = logits[:, cfg.n_prefix:]
        targets = tokens[:, 1:]
        lg = logits[:, :-1].astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
        nll = logz - gold
        loss = jnp.mean(nll)
        return loss, {"loss": loss, "ppl": jnp.exp(loss)}

    def _hidden(self, params, batch, positions=None):
        """Final-norm'd hidden states (forward without the LM head)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens, positions)
        enc_out = None
        if cfg.family == "encdec":
            enc_out = self.encode(params, batch["frames"])
        if cfg.family == "vlm":
            pe = batch["patch_embeds"].astype(cfg.compute_dtype)
            x = jnp.concatenate([pe, x], axis=1)
            x = logical(x, ("act_batch", "act_seq", "act_embed"))
        if positions is None:
            positions = jnp.arange(x.shape[1])
        x, _ = self._run_layers(params, x, positions, None, enc_out=enc_out)
        return x

    # ---------------- caches ----------------

    def cache_defs(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        fam = cfg.family
        d: Dict[str, Any] = {}
        if fam in ("dense", "vlm", "moe"):
            if cfg.global_every > 0:
                G = cfg.n_layers // cfg.global_every
                n_local = cfg.global_every - 1
                d["groups"] = {
                    "local": _kv_cache_defs(cfg, batch, max_len, "local", (G, n_local)),
                    "global": _kv_cache_defs(cfg, batch, max_len, "global", (G,)),
                }
            else:
                kind = "local" if cfg.local_window else "global"
                nd = cfg.first_dense_layers
                if nd:
                    d["dense_blocks"] = _kv_cache_defs(cfg, batch, max_len, kind, (nd,))
                d["blocks"] = _kv_cache_defs(cfg, batch, max_len, kind, (cfg.n_layers - nd,))
        elif fam == "ssm":
            d["blocks"] = ssm.mamba2_cache_defs(cfg, batch, (cfg.n_layers,))
        elif fam == "hybrid":
            G = cfg.n_layers // (cfg.pattern_rec + 1)
            tail = cfg.n_layers - G * (cfg.pattern_rec + 1)
            d["groups"] = {
                "rec": rglru.rglru_cache_defs(cfg, batch, (G, cfg.pattern_rec)),
                "attn": _kv_cache_defs(cfg, batch, max_len, "local", (G,)),
            }
            if tail:
                d["tail"] = rglru.rglru_cache_defs(cfg, batch, (tail,))
        elif fam == "encdec":
            blocks = {"self": _kv_cache_defs(cfg, batch, max_len, "global", (cfg.n_layers,))}
            la = ("layers", "cache_batch", "cache_seq", "cache_heads", None)
            shp = (cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim)
            blocks["cross_k"] = ParamDef(shp, la, cfg.compute_dtype, "zeros")
            blocks["cross_v"] = ParamDef(shp, la, cfg.compute_dtype, "zeros")
            d["blocks"] = {"self": blocks["self"], "cross_k": blocks["cross_k"],
                           "cross_v": blocks["cross_v"]}
        return d

    def init_cache(self, batch: int, max_len: int):
        return jax.tree.map(lambda dd: jnp.zeros(dd.shape, dd.dtype),
                            self.cache_defs(batch, max_len),
                            is_leaf=lambda v: isinstance(v, ParamDef))

    def prefill(self, params, batch, cache, lengths=None):
        """Run the prompt through the model writing the cache.

        Returns (last-position logits, filled cache).

        ``lengths`` (B,) enables right-padded batched prefill (the serve
        engine's bucketed admission): row r's prompt occupies
        ``tokens[r, :lengths[r]]``, pad columns beyond it are masked out of
        attention / recurrent state, per-row cache ``len`` vectors advance
        by the *valid* length, and the returned logits are each row's
        last-valid-position logits.  ``lengths == S`` for every row
        reproduces the unpadded path value-for-value.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        if cfg.family == "encdec":
            enc_out = self.encode(params, batch["frames"])
            cache = self._fill_cross(params, cache, enc_out)
        if cfg.family == "vlm":
            pe = batch["patch_embeds"].astype(cfg.compute_dtype)
            x = jnp.concatenate([pe, x], axis=1)
        positions = jnp.arange(x.shape[1])
        seq_lens = None
        if lengths is not None:
            # valid length in layer coordinates includes the vlm prefix
            seq_lens = lengths + (cfg.n_prefix if cfg.family == "vlm" else 0)
        x, cache = self._run_layers(params, x, positions, cache,
                                    seq_lens=seq_lens)
        if seq_lens is None:
            logits = self._head(params, x[:, -1:])
        else:
            last = jnp.take_along_axis(x, (seq_lens - 1)[:, None, None], axis=1)
            logits = self._head(params, last)
        return logits, cache

    def _fill_cross(self, params, cache, enc_out):
        cfg = self.cfg
        cdt = cfg.compute_dtype

        def proj(wk, wv):
            return (jnp.einsum("bse,ekd->bskd", enc_out, wk.astype(cdt)),
                    jnp.einsum("bse,ekd->bskd", enc_out, wv.astype(cdt)))

        ck, cv = jax.vmap(proj, in_axes=0, out_axes=0)(
            params["blocks"]["cross"]["wk"], params["blocks"]["cross"]["wv"])
        blocks = dict(cache["blocks"])
        blocks["cross_k"] = ck.astype(cache["blocks"]["cross_k"].dtype)
        blocks["cross_v"] = cv.astype(cache["blocks"]["cross_v"].dtype)
        return {**cache, "blocks": blocks}

    def decode_step(self, params, tokens, cache):
        """tokens (B, 1) -> (logits (B,1,V), new cache).

        Positions are per-row: each batch row decodes at its own cache
        offset (the ``len`` vector), so a continuous-batching decode step
        can mix rows whose prompts had different lengths."""
        cfg = self.cfg
        pos = self._cache_len(cache)            # (B,)
        positions = pos[:, None] + jnp.arange(1)  # (B, 1)
        x = self._embed(params, tokens, positions)
        x, cache = self._run_layers(params, x, positions, cache)
        return self._head(params, x), cache

    def _cache_len(self, cache):
        """The per-row position vector (B,) from the first "len" leaf
        (all layers' counters advance identically)."""
        lens = [v for k, v in jax.tree_util.tree_flatten_with_path(cache)[0]
                if k and getattr(k[-1], "key", None) == "len"]
        x = lens[0]
        return x.reshape(-1, x.shape[-1])[0] if x.ndim > 1 else x


def build_model(cfg) -> Model:
    return Model(cfg)
