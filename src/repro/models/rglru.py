"""RG-LRU recurrent block (RecurrentGemma / Griffin).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
a_t = exp(-c * softplus(Lambda) * r_t),  r/i = sigmoid(dense(x))

Implemented as a log-space ``jax.lax.associative_scan`` over the sequence
(the oracle for the Pallas kernel in ``repro.kernels.rglru``), with an O(1)
per-token decode update.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import logical
from repro.models.layers import ParamDef, causal_conv1d

RGLRU_C = 8.0


def _gate_defs(cfg, lp, la, D):
    if cfg.gate_blocks:
        G = cfg.gate_blocks
        shape = lp + (G, D // G, D // G)
        axes = la + ("w_heads", None, None)
        return {
            "w_input_gate": ParamDef(shape, axes, cfg.param_dtype),
            "b_input_gate": ParamDef(lp + (D,), la + ("w_mlp",), cfg.param_dtype, "zeros"),
            "w_rec_gate": ParamDef(shape, axes, cfg.param_dtype),
            "b_rec_gate": ParamDef(lp + (D,), la + ("w_mlp",), cfg.param_dtype, "zeros"),
        }
    dense_axes = la + (("w_expert_mlp", "w_mlp") if cfg.opt_gate_bf16 else ("w_mlp", "w_expert_mlp"))
    return {
        "w_input_gate": ParamDef(lp + (D, D), dense_axes, cfg.param_dtype),
        "b_input_gate": ParamDef(lp + (D,), la + ("w_mlp",), cfg.param_dtype, "zeros"),
        "w_rec_gate": ParamDef(lp + (D, D), dense_axes, cfg.param_dtype),
        "b_rec_gate": ParamDef(lp + (D,), la + ("w_mlp",), cfg.param_dtype, "zeros"),
    }


RGLRU_BLOCK = 512


def _assoc(a, b):
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, bh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return bh


def rglru_scan(x, a, init_state=None):
    """x, a (B, S, D) fp32; returns (h (B,S,D), h_last (B,D)).

    Linear recurrence h_t = a_t h_{t-1} + b_t with b = sqrt(1-a^2)*x.
    Long sequences run block-wise (lax.scan over RGLRU_BLOCK-token blocks,
    associative scan inside, state carried) so fwd+bwd materialization is
    O(block), not O(S) — the same structure as the Pallas kernel.
    """
    B, S, D = x.shape
    if S > RGLRU_BLOCK and S % RGLRU_BLOCK == 0:
        b0 = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * x
        h0 = jnp.zeros((B, D), b0.dtype) if init_state is None else init_state.astype(b0.dtype)
        nb = S // RGLRU_BLOCK
        ab = a.reshape(B, nb, RGLRU_BLOCK, D).swapaxes(0, 1)
        bb = b0.reshape(B, nb, RGLRU_BLOCK, D).swapaxes(0, 1)

        def block(carry, inp):
            a_i, b_i = inp
            a2 = jnp.concatenate([jnp.zeros_like(a_i[:, :1]), a_i], axis=1)
            b2 = jnp.concatenate([carry[:, None], b_i], axis=1)
            h = _assoc(a2, b2)[:, 1:]
            return h[:, -1], h

        h_last, hs = jax.lax.scan(block, h0, (ab, bb))
        return hs.swapaxes(0, 1).reshape(B, S, D), h_last

    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * x
    if init_state is not None:
        # fold the carried state in as a virtual step 0
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([init_state[:, None].astype(b.dtype), b], axis=1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    ah, bh = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = bh
    if init_state is not None:
        h = h[:, 1:]
    return h, h[:, -1]


def rglru_step(state, xt, at):
    """state/xt/at (B, D) -> (h_t, h_t)."""
    bt = jnp.sqrt(jnp.maximum(1.0 - jnp.square(at), 1e-12)) * xt
    h = at * state + bt
    return h, h


def rglru_defs(cfg, layers_prefix: Tuple[int, ...] = ()) -> dict:
    lp = layers_prefix
    la = ("layers",) * len(lp)
    D = cfg.lru_width
    return {
        # Griffin recurrent block: two input branches, conv+LRU on one
        "w_x": ParamDef(lp + (cfg.d_model, D), la + ("w_embed", "w_mlp"), cfg.param_dtype),
        "w_gate_branch": ParamDef(lp + (cfg.d_model, D), la + ("w_embed", "w_mlp"), cfg.param_dtype),
        "conv_w": ParamDef(lp + (cfg.conv_width, D), la + ("w_conv", "w_mlp"), cfg.param_dtype, scale=0.2),
        "conv_b": ParamDef(lp + (D,), la + ("w_mlp",), cfg.param_dtype, "zeros"),
        # Griffin uses block-diagonal gate matrices (gate_blocks > 0): each
        # block is local to a model shard — no cross-shard contraction, no
        # TP psum in fwd or bwd (§Perf cell B).  gate_blocks=0 is a dense
        # ablation (contraction-sharded -> one psum per gate per direction).
        **_gate_defs(cfg, lp, la, D),
        "lambda_p": ParamDef(lp + (D,), la + ("w_mlp",), jnp.float32, "ones"),
        "w_out": ParamDef(lp + (D, cfg.d_model), la + ("w_mlp", "w_embed"), cfg.param_dtype),
    }


def rglru_cache_defs(cfg, batch: int, layers_prefix: Tuple[int, ...] = ()) -> dict:
    lp = layers_prefix
    la = ("layers",) * len(lp)
    D = cfg.lru_width
    return {
        "conv": ParamDef(lp + (batch, cfg.conv_width - 1, D), la + ("cache_batch", None, "cache_heads"), cfg.compute_dtype, "zeros"),
        "h": ParamDef(lp + (batch, D), la + ("cache_batch", "cache_heads"), jnp.float32, "zeros"),
        "len": ParamDef(lp + (batch,), la + ("cache_batch",), jnp.int32, "zeros"),
    }


def rglru_block(p: dict, u: jax.Array, cfg, cache: Optional[dict] = None,
                seq_lens: Optional[jax.Array] = None):
    """Griffin recurrent block.  u (B, S, E) -> (y, new_cache).

    ``seq_lens`` (B,) marks each row's valid prefix under right-padded
    batched prefill: pad steps become identity recurrence updates (a=1,
    gated input 0 -> h_t = h_{t-1}), so the carried state h_last ignores
    every row's padded tail.
    """
    B, S, E = u.shape
    cdt = cfg.compute_dtype

    gate = jax.nn.gelu(jnp.einsum("bse,ed->bsd", u, p["w_gate_branch"].astype(cdt)))
    x = jnp.einsum("bse,ed->bsd", u, p["w_x"].astype(cdt))
    conv_state = cache["conv"] if cache is not None else None
    x, new_conv = causal_conv1d(x, p["conv_w"].astype(cdt), conv_state,
                                lengths=seq_lens)
    x = x + p["conv_b"].astype(cdt)
    x = logical(x, ("act_batch", "act_seq", "act_mlp"))

    xf = x.astype(jnp.float32)
    gdt = cdt if cfg.opt_gate_bf16 else jnp.float32
    # bf16 end-to-end gate matmuls (no forced-f32 output): forward psums and
    # backward cotangent collectives stay bf16 (§Perf cell B).
    if cfg.gate_blocks:
        G = cfg.gate_blocks
        xg = x.astype(gdt).reshape(B, S, G, -1)
        xg = logical(xg, ("act_batch", "act_seq", "act_heads", None))
        i_pre = jnp.einsum("bsgd,gdf->bsgf", xg, p["w_input_gate"].astype(gdt)).reshape(B, S, -1)
        r_pre = jnp.einsum("bsgd,gdf->bsgf", xg, p["w_rec_gate"].astype(gdt)).reshape(B, S, -1)
    else:
        i_pre = jnp.einsum("bsd,df->bsf", x.astype(gdt), p["w_input_gate"].astype(gdt))
        r_pre = jnp.einsum("bsd,df->bsf", x.astype(gdt), p["w_rec_gate"].astype(gdt))
    i_gate = jax.nn.sigmoid(i_pre.astype(jnp.float32) + p["b_input_gate"].astype(jnp.float32))
    r_gate = jax.nn.sigmoid(r_pre.astype(jnp.float32) + p["b_rec_gate"].astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lambda_p"]) * r_gate
    a = jnp.exp(log_a)
    gated_x = i_gate * xf
    if seq_lens is not None:
        valid = (jnp.arange(S)[None, :] < seq_lens[:, None])[..., None]
        a = jnp.where(valid, a, 1.0)
        gated_x = jnp.where(valid, gated_x, 0.0)

    new_cache = None
    if cache is not None and S == 1:
        h, h_last = rglru_step(cache["h"], gated_x[:, 0], a[:, 0])
        h = h[:, None]
        new_cache = {"conv": new_conv, "h": h_last, "len": cache["len"] + 1}
    else:
        init = cache["h"] if cache is not None else None
        h, h_last = rglru_scan(gated_x, a, init_state=init)
        if cache is not None:
            adv = S if seq_lens is None else seq_lens
            new_cache = {"conv": new_conv, "h": h_last, "len": cache["len"] + adv}

    y = h.astype(cdt) * gate
    out = jnp.einsum("bsd,de->bse", y, p["w_out"].astype(cdt))
    return out, new_cache
