from repro.runtime.supervisor import (  # noqa: F401
    ElasticPlan, HeartbeatMonitor, Supervisor, elastic_rescale_plan,
)
