"""Cluster-runtime layer: restart supervision, straggler detection, elastic
rescale planning.

On a real TPU fleet this wraps the per-host training processes; the control
logic is hardware-independent and is exercised end-to-end by the tests and
``examples/fault_tolerance.py`` with simulated failures:

* ``Supervisor.run`` — step loop with checkpoint/restart: any exception in a
  step (a lost host surfaces as one) rolls back to the latest checkpoint and
  replays, with bounded retries.  The deterministic data pipeline makes the
  replay bit-exact.
* ``HeartbeatMonitor`` — per-host step-time tracking; hosts slower than
  ``straggler_factor`` x the running median are flagged.  Policy hooks:
  "observe" (log), "evict" (remove from the healthy set -> triggers elastic
  rescale), mirroring what MaxText/Borg-style schedulers do.
* ``elastic_rescale_plan`` — given the healthy device count, recompute the
  largest (data, model) mesh <= available chips that preserves model-axis
  divisibility, and the per-axis migration (which checkpoint shards each new
  host loads).  Scale-down keeps global batch by raising per-replica batch.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

log = logging.getLogger("repro.runtime")


# ---------------------------------------------------------------------------
# Straggler detection
# ---------------------------------------------------------------------------


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, straggler_factor: float = 1.5, window: int = 16):
        self.n_hosts = n_hosts
        self.factor = straggler_factor
        self.window = window
        self._times: Dict[int, List[float]] = {h: [] for h in range(n_hosts)}
        self.healthy = set(range(n_hosts))

    def report(self, host: int, step_time: float) -> None:
        t = self._times[host]
        t.append(step_time)
        if len(t) > self.window:
            t.pop(0)

    def last_beat(self, host: int) -> Optional[float]:
        t = self._times[host]
        return t[-1] if t else None

    def stragglers(self) -> List[int]:
        med = [np.median(self._times[h]) for h in self.healthy if self._times[h]]
        if not med:
            return []
        fleet_median = float(np.median(med))
        out = []
        for h in sorted(self.healthy):
            if self._times[h] and np.median(self._times[h]) > self.factor * fleet_median:
                out.append(h)
        return out

    def evict(self, host: int) -> None:
        self.healthy.discard(host)


# ---------------------------------------------------------------------------
# Elastic rescale planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    per_replica_batch_multiplier: int
    dropped_chips: int
    note: str


def elastic_rescale_plan(
    healthy_chips: int,
    *,
    model_parallel: int = 16,
    global_batch: int = 256,
    multi_pod: bool = False,
) -> ElasticPlan:
    """Largest coherent mesh under the healthy-chip budget.

    The model axis is load-bearing (weights are TP-sharded over it) so it is
    preserved; the data axis shrinks to the largest divisor of the remaining
    chips that also divides global_batch (keeping the batch exact).
    """
    assert healthy_chips >= model_parallel, "cannot keep model axis"
    data = healthy_chips // model_parallel
    while data > 1 and global_batch % data:
        data -= 1
    used = data * model_parallel
    shape: Tuple[int, ...]
    names: Tuple[str, ...]
    if multi_pod and data % 2 == 0:
        shape, names = (2, data // 2, model_parallel), ("pod", "data", "model")
    else:
        shape, names = (data, model_parallel), ("data", "model")
    return ElasticPlan(
        mesh_shape=shape,
        axis_names=names,
        per_replica_batch_multiplier=global_batch // data,
        dropped_chips=healthy_chips - used,
        note=f"kept model={model_parallel}, data {data}; "
             f"{healthy_chips - used} chips idle until next rescale window",
    )


# ---------------------------------------------------------------------------
# Restart supervision
# ---------------------------------------------------------------------------


class Supervisor:
    """Checkpoint/restart step-loop wrapper with bounded retries.

    ``step_fn(state, step) -> state`` may raise (injected faults in tests,
    real XLA/host errors in production).  On failure the supervisor restores
    the latest checkpoint and replays from there.

    ``backoff_s > 0`` sleeps before each replay, doubling per consecutive
    restart (capped at 32x) — a crash-looping service must not hammer its
    own scheduler.  ``sleep`` is injectable (a virtual clock in tests);
    the default 0.0 keeps the original immediate-replay behaviour.
    """

    def __init__(self, ckpt_manager, *, save_every: int = 10, max_restarts: int = 5,
                 monitor: Optional[HeartbeatMonitor] = None,
                 backoff_s: float = 0.0,
                 sleep: Callable[[float], None] = time.sleep):
        self.ckpt = ckpt_manager
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.monitor = monitor
        self.backoff_s = backoff_s
        self._sleep = sleep
        self.restarts = 0
        self.events: List[str] = []

    def run(self, state, step_fn: Callable[[Any, int], Any], n_steps: int,
            *, start_step: int = 0):
        step = start_step
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                state = step_fn(state, step)
                dt = time.perf_counter() - t0
                if self.monitor is not None:
                    self.monitor.report(0, dt)
                step += 1
                if step % self.save_every == 0:
                    self.ckpt.save(state, step)
                    self.events.append(f"ckpt@{step}")
            except Exception as e:  # noqa: BLE001 — any step fault is restartable
                self.restarts += 1
                self.events.append(f"fault@{step}:{type(e).__name__}")
                if self.restarts > self.max_restarts:
                    raise RuntimeError(f"exceeded {self.max_restarts} restarts") from e
                if self.backoff_s > 0:
                    delay = min(self.backoff_s * (2 ** (self.restarts - 1)),
                                32 * self.backoff_s)
                    self.events.append(f"backoff@{step}:{delay:g}s")
                    self._sleep(delay)
                self.ckpt.wait()
                restored, ck_step = self.ckpt.restore_latest(state)
                if restored is None:
                    ck_step = start_step
                    self.events.append("restart@init")
                else:
                    state = restored
                    self.events.append(f"restore@{ck_step}")
                step = ck_step or start_step
        self.ckpt.wait()
        return state, step
