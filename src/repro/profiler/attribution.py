"""Measured op-class time attribution: merge a cell's measured phase
timeline with its trip-count-aware HLO op-class costs.

The analytic breakdown (``core/breakdown.py``) can only say what the
hardware *should* do; this module says where the measured time *went*:

* the measured **dispatch** share is taken directly from the timeline;
* the measured **device** share is distributed over the HLO op classes
  (``hloanalysis.OP_CLASSES``: matmul / attention / collective /
  elementwise / other) proportionally to each class's roofline time —
  ``max(flops_c / peak, bytes_c / hbm_bw)`` per class, collective wire
  bytes over link bandwidth — so the *relative* weights survive running
  on a host much slower than the modeled accelerator;
* each non-collective class's share is further split into **compute** vs
  **memory** by its own flops-time : bytes-time ratio, giving measured
  compute / memory / collective / dispatch / idle fractions that sum to
  exactly 1.0 per cell (the acceptance invariant).

``util`` is the roofline-utilization proxy: the cell's analytic device
bound over its measured device time.  Its absolute value is only
meaningful on the modeled hardware; the inefficiency detectors therefore
compare it *across* cells of one sweep (host speed cancels out).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

from repro.core.hardware import DEFAULT_HW, HardwareProfile
from repro.core.hloanalysis import OP_CLASSES, HloCost, analyze_hlo

from repro.profiler.timeline import Timeline


@dataclasses.dataclass
class Attribution:
    """Measured time attribution for one profiled cell."""
    class_us: Dict[str, float]      # measured device us per op class
    class_frac: Dict[str, float]    # same, as fractions of device time
    frac_compute: float
    frac_memory: float
    frac_collective: float
    frac_dispatch: float
    frac_idle: float
    bound_us: float                 # analytic roofline device bound
    util: float                     # bound_us / measured device us
    flops: float
    bytes_accessed: float
    collective_bytes: float
    source: str = "measured"

    def fractions(self) -> Dict[str, float]:
        return {"compute": self.frac_compute, "memory": self.frac_memory,
                "collective": self.frac_collective,
                "dispatch": self.frac_dispatch, "idle": self.frac_idle}

    def to_extra(self) -> Dict[str, Any]:
        """The attribution's share of the well-known ``extra["prof_*"]``
        keys (see ``repro/runner/results.py``)."""
        return {
            "prof_source": self.source,
            "prof_frac_compute": self.frac_compute,
            "prof_frac_memory": self.frac_memory,
            "prof_frac_collective": self.frac_collective,
            "prof_frac_dispatch": self.frac_dispatch,
            "prof_frac_idle": self.frac_idle,
            "prof_class_us": {k: round(v, 2)
                              for k, v in self.class_us.items()},
            "prof_class_frac": dict(self.class_frac),
            "prof_bound_us": self.bound_us,
            "prof_util": self.util,
            "prof_flops": self.flops,
            "prof_bytes": self.bytes_accessed,
            "prof_collective_bytes": self.collective_bytes,
        }


def class_times(cost: HloCost,
                hw: HardwareProfile = DEFAULT_HW
                ) -> Dict[str, Tuple[float, float, float]]:
    """Per-class roofline terms ``{class: (flops_s, bytes_s, bound_s)}``.

    The collective class is bounded by its wire bytes over link bandwidth
    (its HBM-side bytes stay in the memory term like any other class's)."""
    out: Dict[str, Tuple[float, float, float]] = {}
    for cls in OP_CLASSES:
        f_s = cost.flops_by_class.get(cls, 0.0) / hw.peak_flops_bf16
        b_s = cost.bytes_by_class.get(cls, 0.0) / hw.hbm_bw
        bound = max(f_s, b_s)
        if cls == "collective":
            bound = max(bound, cost.collective_bytes / hw.link_bw)
        out[cls] = (f_s, b_s, bound)
    return out


def attribute(timeline: Timeline, cost: HloCost,
              hw: HardwareProfile = DEFAULT_HW) -> Attribution:
    """Distribute the timeline's measured time over op classes and the
    compute/memory/collective/dispatch/idle decomposition.

    The five fractions sum to exactly 1.0 whenever the timeline has any
    time at all; device time the HLO costs cannot explain (an empty or
    unparseable module) lands in ``idle``, never silently vanishes."""
    disp = timeline.dispatch_us
    dev = timeline.device_us
    idle = timeline.idle_us
    total = disp + dev + idle
    per_class = class_times(cost, hw)
    weight = sum(b for _, _, b in per_class.values())
    class_us = {cls: 0.0 for cls in OP_CLASSES}
    unattributed = dev
    if weight > 0.0 and dev > 0.0:
        class_us = {cls: dev * b / weight
                    for cls, (_, _, b) in per_class.items()}
        unattributed = 0.0
    frac_compute = frac_memory = 0.0
    if total > 0.0:
        for cls, (f_s, b_s, _) in per_class.items():
            if cls == "collective" or f_s + b_s == 0.0:
                continue
            share = class_us[cls] / total
            frac_compute += share * (f_s / (f_s + b_s))
            frac_memory += share * (b_s / (f_s + b_s))
    # util compares the ONE-step analytic bound against the measured
    # PER-STEP device time — never the whole-timeline sum, which would
    # scale utilization by 1/steps and skew cells with different sample
    # counts (a serve cell's N decode steps vs a step cell's N runs)
    dev_per_step = dev / timeline.steps if timeline.steps else 0.0
    return Attribution(
        class_us=class_us,
        class_frac={cls: (us / dev if dev else 0.0)
                    for cls, us in class_us.items()},
        frac_compute=frac_compute,
        frac_memory=frac_memory,
        frac_collective=class_us["collective"] / total if total else 0.0,
        frac_dispatch=disp / total if total else 0.0,
        frac_idle=(idle + unattributed) / total if total else 0.0,
        bound_us=weight * 1e6,
        util=(weight * 1e6) / dev_per_step if dev_per_step else 0.0,
        flops=cost.flops, bytes_accessed=cost.bytes_accessed,
        collective_bytes=cost.collective_bytes)


def cost_for_executable(lower: Callable[[], Any]) -> HloCost:
    """Trip-count-aware HLO cost for an already-traced jitted callable.

    ``lower`` is a thunk returning ``jitted.lower(*args)`` — lowering an
    already-traced call is ~1 ms, but the AOT ``compile()`` here is a
    fresh XLA compile (seconds); callers cache the returned cost per
    scenario (``BenchmarkRunner._prof_costs``) so repeated profiled
    re-measures pay it once.  Runs strictly outside any timed region."""
    return analyze_hlo(lower().compile().as_text())
