"""Measured profiling subsystem: where did a benchmark cell's time go?

Four layers, all flowing through the unified BenchmarkRunner
(``runner.run(..., profile=True)`` / ``benchmarks.run --profile``):

    timeline     per-step phase capture — host dispatch vs device
                 execution via block_until_ready deltas; per-decode-step
                 timelines for serve cells; device memory stats when the
                 backend exposes them
    attribution  merge the measured timeline with trip-count-aware HLO
                 op-class costs (``core.hloanalysis``) into measured
                 matmul/attention/collective/elementwise/other shares and
                 compute/memory/collective/dispatch/idle fractions that
                 sum to 1.0
    detectors    rule-based inefficiency findings (the paper's
                 optimization-catalog spirit): data-movement-bound,
                 low relative utilization, compile outliers, serve queue
                 saturation, shard imbalance, dispatch-bound
    report       ranked findings with severity + evidence, JSON + table

The profile lands under the well-known ``extra["prof_*"]`` keys
documented in ``repro/runner/results.py`` (schema stays v1) — so every
downstream surface (``fig12_breakdown``, ``profile_report``, regression
CI) reads profiles from the same ResultStore records as timings.
"""
from repro.profiler.attribution import (Attribution, attribute, class_times,
                                        cost_for_executable)
from repro.profiler.detectors import Finding, Thresholds, detect
from repro.profiler.report import build_report, format_table
from repro.profiler.timeline import (TIMELINE_CAP, PhaseSample, Timeline,
                                     device_memory_stats)

__all__ = ["Timeline", "PhaseSample", "TIMELINE_CAP", "device_memory_stats",
           "Attribution", "attribute", "class_times", "cost_for_executable",
           "Finding", "Thresholds", "detect",
           "build_report", "format_table"]
