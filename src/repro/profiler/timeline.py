"""Measured per-step phase timelines — the profiler's raw signal.

The measurement harness can only see two phase boundaries on this backend:
the jitted call *returning* (end of host dispatch — argument validation,
cache lookup, async enqueue) and ``block_until_ready`` completing (end of
device execution).  A ``Timeline`` is the per-sample record of that split:

* step cells (train / infer_prefill / infer_decode): one ``PhaseSample``
  per measured iteration of ``harness.measure`` (warmup excluded);
* serve cells: one ``PhaseSample`` per batched decode step of the
  measured trace replay, plus ``idle_us`` — replay wall time spent
  *outside* decode steps (admission, per-request prefill, host queue
  management), which has no step-cell analogue.

Device memory stats (peak / in-use bytes) ride along when the backend
exposes ``Device.memory_stats()`` (TPU/GPU; the CPU backend returns None
and the fields are simply absent from the profile).

Backend-native traces (``jax.profiler``) are a future extension point —
see ROADMAP.md; this module is deliberately trace-free so it works on any
host the benchmark suite runs on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

#: cap on the per-sample timeline recorded into ``extra["prof_timeline"]``
#: (serve replays can run thousands of decode steps; aggregates are exact,
#: the sample list is a debugging aid)
TIMELINE_CAP = 128


@dataclasses.dataclass
class PhaseSample:
    """One measured step, split at the dispatch/execution boundary (us)."""
    dispatch_us: float
    device_us: float

    @property
    def total_us(self) -> float:
        return self.dispatch_us + self.device_us


@dataclasses.dataclass
class Timeline:
    """Per-step phase capture for one profiled cell."""
    kind: str                                   # "step" | "decode_step"
    samples: List[PhaseSample] = dataclasses.field(default_factory=list)
    #: serve only: replay wall time outside the decode steps (us)
    idle_us: float = 0.0
    #: backend memory stats snapshot, when available
    memory: Optional[Dict[str, int]] = None

    @classmethod
    def from_phase_log(cls, log: Sequence[Tuple[float, float]], *,
                       kind: str = "step", wall_s: float = 0.0,
                       memory: Optional[Dict[str, int]] = None) -> "Timeline":
        """Build from a harness ``phase_log`` — (dispatch_s, device_s)
        tuples in **seconds** as appended by ``harness.measure`` /
        ``ServeEngine.run``.  ``wall_s`` (serve) is the measured replay
        wall; any part of it not inside the logged steps becomes idle."""
        samples = [PhaseSample(d * 1e6, v * 1e6) for d, v in log]
        idle = 0.0
        if wall_s:
            stepped = sum(s.total_us for s in samples)
            idle = max(0.0, wall_s * 1e6 - stepped)
        return cls(kind=kind, samples=samples, idle_us=idle, memory=memory)

    # ---- aggregates ------------------------------------------------------

    @property
    def steps(self) -> int:
        return len(self.samples)

    @property
    def dispatch_us(self) -> float:
        return sum(s.dispatch_us for s in self.samples)

    @property
    def device_us(self) -> float:
        return sum(s.device_us for s in self.samples)

    @property
    def total_us(self) -> float:
        """Everything the profile accounts for: steps + (serve) idle."""
        return self.dispatch_us + self.device_us + self.idle_us

    def to_extra(self) -> Dict[str, object]:
        """The timeline's share of the well-known ``extra["prof_*"]`` keys
        (see ``repro/runner/results.py``)."""
        n = max(1, self.steps)
        out: Dict[str, object] = {
            "prof_kind": self.kind,
            "prof_steps": self.steps,
            "prof_dispatch_us_mean": self.dispatch_us / n,
            "prof_device_us_mean": self.device_us / n,
            "prof_timeline": [[round(s.dispatch_us, 2), round(s.device_us, 2)]
                              for s in self.samples[:TIMELINE_CAP]],
        }
        if self.idle_us:
            out["prof_idle_us"] = self.idle_us
        if self.memory:
            if self.memory.get("peak_bytes"):
                out["prof_device_peak_bytes"] = self.memory["peak_bytes"]
            if self.memory.get("bytes_in_use"):
                out["prof_device_bytes_in_use"] = self.memory["bytes_in_use"]
        return out


def device_memory_stats() -> Optional[Dict[str, int]]:
    """Peak/live device bytes when the backend exposes them, else None
    (the CPU backend has no allocator stats — readers must tolerate
    absence, exactly like every other well-known extra)."""
    try:
        import jax
        stats = jax.local_devices()[0].memory_stats()
    except Exception:  # noqa: BLE001 — any backend without the API
        return None
    if not stats:
        return None
    return {"peak_bytes": int(stats.get("peak_bytes_in_use", 0)),
            "bytes_in_use": int(stats.get("bytes_in_use", 0))}
