"""Findings report: ranked inefficiency findings with evidence, as JSON
and as a human table (``benchmarks/profile_report.py`` is the sweep-level
surface; ``scripts/dump_cell.py --profile`` the single-cell one)."""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional

from repro.profiler.detectors import SEVERITIES, Finding

REPORT_SCHEMA = 1


def build_report(records: Iterable[dict], findings: List[Finding], *,
                 meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """One JSON-able report over a profiled sweep.  ``findings`` are
    assumed ranked (``detectors.detect`` ranks); the report preserves
    their order and adds per-rule / per-severity tallies."""
    recs = [r.to_dict() if hasattr(r, "to_dict") else dict(r)
            for r in records]
    ok = [r for r in recs if r.get("status") == "ok"]
    profiled = [r for r in ok
                if "prof_frac_memory" in (r.get("extra") or {})]
    by_rule: Dict[str, int] = {}
    by_severity = {s: 0 for s in SEVERITIES}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
        by_severity[f.severity] = by_severity.get(f.severity, 0) + 1
    return {
        "schema": REPORT_SCHEMA,
        "meta": dict(meta or {}),
        "cells": len(recs),
        "cells_ok": len(ok),
        "cells_profiled": len(profiled),
        "by_rule": by_rule,
        "by_severity": by_severity,
        "findings": [f.to_dict() for f in findings],
    }


def format_table(report: Dict[str, Any], *, max_rows: int = 40) -> str:
    """The report as fixed-width text lines (severity, rule, cell,
    summary), most severe first."""
    lines = [f"profiled {report['cells_profiled']}/{report['cells']} cells "
             f"-> {len(report['findings'])} findings "
             f"(crit={report['by_severity'].get('crit', 0)} "
             f"warn={report['by_severity'].get('warn', 0)} "
             f"info={report['by_severity'].get('info', 0)})"]
    for f in report["findings"][:max_rows]:
        lines.append(f"  {f['severity']:<4} {f['rule']:<20} "
                     f"{f['cell']:<44} {f['summary']}")
    dropped = len(report["findings"]) - max_rows
    if dropped > 0:
        lines.append(f"  ... {dropped} more findings (see JSON)")
    return "\n".join(lines)
