"""Rule-based inefficiency detection over profiled RunResults.

The paper's first use case is profiling the suite to *find* GPU
performance inefficiencies and drive optimization patches; these rules
are the measured-profile analogue of that optimization catalog.  Each
rule inspects RunResult dicts (the ``extra["prof_*"]`` payload plus the
serve / sharding extras) and emits ranked ``Finding``s:

    data_movement_bound    the cell's measured memory fraction dominates —
                           the classic fusion / layout / dtype patch target
    low_util               roofline utilization far below the sweep's
                           median — the cell leaves the most machine on
                           the table *relative to its peers* (absolute
                           utilization is host-dependent; the relative
                           comparison cancels host speed)
    compile_outlier        compile time a large multiple of the sweep's
                           median — guard-heavy or recompiling cells
    queue_saturation       serve cells whose arrival load sustainedly
                           exceeds the decode slots (queue_depth extras)
    shard_imbalance        sharded sweeps whose slowest shard dwarfs the
                           fastest — the LPT balance lost to a bad weight
                           guess or a straggler cell
    dispatch_bound         host dispatch overhead rivals device work —
                           batch-too-small / sync-heavy cells

Rules that need sweep context (low_util, compile_outlier,
shard_imbalance) compute it from the record batch they're given; single
records never fire them.  Thresholds live in one ``Thresholds`` config so
tests can pin them and future backends can recalibrate.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional

from repro.runner.latency import percentile

#: ranking order: crit first, then warn, then info
SEVERITIES = ("crit", "warn", "info")


@dataclasses.dataclass
class Finding:
    rule: str
    severity: str           # "crit" | "warn" | "info"
    cell: str               # scenario name ("<sweep>" for cross-cell rules)
    summary: str
    score: float            # rule-specific magnitude, ranks within severity
    evidence: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Thresholds:
    #: memory fraction above which a cell is data-movement-bound
    memory_frac: float = 0.5
    #: escalate to crit above this memory fraction
    memory_frac_crit: float = 0.75
    #: fire low_util below this multiple of the sweep's median utilization
    util_rel: float = 0.33
    #: minimum profiled cells for the relative-utilization comparison
    util_min_cells: int = 3
    #: fire compile_outlier above this multiple of the median compile time
    compile_rel: float = 3.0
    #: ... but never below this absolute compile time (us)
    compile_min_us: float = 1e6
    #: serve: mean queue depth above slots * factor is saturation
    queue_factor: float = 1.0
    #: escalate to crit above slots * this factor
    queue_factor_crit: float = 2.0
    #: sharded sweeps: slowest/fastest shard wall ratio that fires
    shard_ratio: float = 1.5
    #: host dispatch fraction that rivals device work
    dispatch_frac: float = 0.35


def _ok(rec: dict) -> bool:
    return rec.get("status") == "ok"


def _extra(rec: dict) -> dict:
    return rec.get("extra") or {}


def _profiled(rec: dict) -> bool:
    return _ok(rec) and "prof_frac_memory" in _extra(rec)


def _median(vals: List[float]) -> float:
    """p50 via the shared percentile helper (one interpolation semantic
    across the whole codebase); call sites guarantee non-empty input."""
    return percentile(vals, 50)


# ---- per-cell rules --------------------------------------------------------

def _data_movement_bound(rec: dict, th: Thresholds) -> Optional[Finding]:
    e = _extra(rec)
    mem = e.get("prof_frac_memory", 0.0)
    if mem <= th.memory_frac or mem <= e.get("prof_frac_compute", 0.0):
        return None
    sev = "crit" if mem > th.memory_frac_crit else "warn"
    return Finding(
        rule="data_movement_bound", severity=sev, cell=rec["name"],
        summary=f"{mem:.0%} of measured time is data movement "
                f"(compute {e.get('prof_frac_compute', 0.0):.0%}) — "
                f"fusion/layout/dtype patch target",
        score=mem,
        evidence={"frac_memory": mem,
                  "frac_compute": e.get("prof_frac_compute", 0.0),
                  "class_frac": e.get("prof_class_frac", {})})


def _dispatch_bound(rec: dict, th: Thresholds) -> Optional[Finding]:
    e = _extra(rec)
    disp = e.get("prof_frac_dispatch", 0.0)
    if disp <= th.dispatch_frac:
        return None
    return Finding(
        rule="dispatch_bound", severity="warn", cell=rec["name"],
        summary=f"host dispatch is {disp:.0%} of measured time — "
                f"step too small or sync-heavy",
        score=disp,
        evidence={"frac_dispatch": disp,
                  "dispatch_us_mean": e.get("prof_dispatch_us_mean"),
                  "device_us_mean": e.get("prof_device_us_mean")})


def _queue_saturation(rec: dict, th: Thresholds) -> Optional[Finding]:
    if rec.get("task") != "serve" or not _ok(rec):
        return None
    e = _extra(rec)
    slots = e.get("slots") or 0
    qmean = e.get("queue_depth_mean")
    if not slots or qmean is None or qmean <= slots * th.queue_factor:
        return None
    sev = "crit" if qmean > slots * th.queue_factor_crit else "warn"
    return Finding(
        rule="queue_saturation", severity=sev, cell=rec["name"],
        summary=f"mean queue depth {qmean:.1f} exceeds {slots} decode "
                f"slots (max {e.get('queue_depth_max')}) — arrival load "
                f"saturates the batch",
        score=qmean / slots,
        evidence={"queue_depth_mean": qmean,
                  "queue_depth_max": e.get("queue_depth_max"),
                  "slots": slots, "trace": e.get("trace")})


# ---- sweep-context rules ---------------------------------------------------

def _low_util(records: List[dict], th: Thresholds) -> List[Finding]:
    utils = [(r, _extra(r)["prof_util"]) for r in records
             if _profiled(r) and _extra(r).get("prof_util", 0.0) > 0.0]
    if len(utils) < th.util_min_cells:
        return []
    med = _median([u for _, u in utils])
    out = []
    for rec, u in utils:
        if med <= 0.0 or u >= med * th.util_rel:
            continue
        out.append(Finding(
            rule="low_util", severity="warn", cell=rec["name"],
            summary=f"roofline utilization {u:.2e} is "
                    f"{u / med:.0%} of the sweep median ({med:.2e}) — "
                    f"the cell leaves the most machine idle",
            score=1.0 - u / med,
            evidence={"util": u, "sweep_median": med,
                      "bound_us": _extra(rec).get("prof_bound_us"),
                      "device_us_mean": _extra(rec).get("prof_device_us_mean")}))
    return out


def _compile_outliers(records: List[dict], th: Thresholds) -> List[Finding]:
    comp = [(r, r.get("compile_us", 0.0)) for r in records
            if _ok(r) and r.get("compile_us", 0.0) > 0.0]
    if len(comp) < 2:
        return []
    med = _median([c for _, c in comp])
    out = []
    for rec, c in comp:
        if med <= 0.0 or c <= max(med * th.compile_rel, th.compile_min_us):
            continue
        out.append(Finding(
            rule="compile_outlier", severity="info", cell=rec["name"],
            summary=f"compile time {c / 1e6:.1f}s is {c / med:.1f}x the "
                    f"sweep median ({med / 1e6:.1f}s)",
            score=c / med,
            evidence={"compile_us": c, "sweep_median_us": med}))
    return out


def _shard_imbalance(records: List[dict], th: Thresholds) -> List[Finding]:
    walls: Dict[int, float] = {}
    for r in records:
        shard = _extra(r).get("shard")
        if shard is None or not _ok(r):
            continue
        walls[shard] = walls.get(shard, 0.0) + (r.get("wall_s") or 0.0)
    if len(walls) < 2:
        return []
    slow, fast = max(walls.values()), min(walls.values())
    if fast <= 0.0 or slow / fast <= th.shard_ratio:
        return []
    return [Finding(
        rule="shard_imbalance", severity="info", cell="<sweep>",
        summary=f"slowest shard ran {slow:.1f}s vs fastest {fast:.1f}s "
                f"({slow / fast:.1f}x) over {len(walls)} shards — "
                f"rebalance weights or steal work",
        score=slow / fast,
        evidence={"shard_wall_s": {str(k): round(v, 2)
                                   for k, v in sorted(walls.items())}})]


def detect(records: Iterable[dict],
           th: Optional[Thresholds] = None) -> List[Finding]:
    """Run every rule over a batch of RunResult dicts; returns findings
    ranked most-severe first (severity order, then score descending)."""
    th = th or Thresholds()
    recs = [r.to_dict() if hasattr(r, "to_dict") else dict(r)
            for r in records]
    findings: List[Finding] = []
    for rec in recs:
        if _profiled(rec):
            for rule in (_data_movement_bound, _dispatch_bound):
                f = rule(rec, th)
                if f:
                    findings.append(f)
        f = _queue_saturation(rec, th)
        if f:
            findings.append(f)
    findings += _low_util(recs, th)
    findings += _compile_outliers(recs, th)
    findings += _shard_imbalance(recs, th)
    findings.sort(key=lambda f: (SEVERITIES.index(f.severity), -f.score))
    return findings
