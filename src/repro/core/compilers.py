"""Execution-mode ("compiler backend") comparison — paper §3.2 analogue.

TorchBench compares PyTorch eager vs TorchInductor on time / CPU-mem /
GPU-mem.  The JAX stack's execution modes:

  eager          op-by-op dispatch (jax.disable_jit) — PyTorch-eager analogue
  jit            whole-step XLA compilation — the TorchInductor analogue
  jit_donated    + buffer donation (in-place state update)
  jit_unrolled   layer scan unrolled (bigger program, more fusion scope)
  jit_noremat    no activation rematerialization (time/memory trade)

Reported per mode: median step time, host peak bytes, device bytes — the
same T/CM/GM triple as the paper's Figs. 3-4.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.core.harness import Measurement, measure

MODES = ("eager", "jit", "jit_donated", "jit_unrolled", "jit_noremat")


def compare_modes(bench, *, batch: int = 2, seq: int = 64, runs: int = 5,
                  modes: Tuple[str, ...] = MODES) -> Dict[str, Measurement]:
    out: Dict[str, Measurement] = {}
    for mode in modes:
        if mode == "eager":
            step, args, donate = bench.make(batch=batch, seq=seq)
            import time as _t, numpy as np, tracemalloc
            with jax.disable_jit():
                jax.block_until_ready(step(*args))   # warm
                tracemalloc.start()
                times = []
                for _ in range(max(2, runs // 2)):
                    t0 = _t.perf_counter()
                    jax.block_until_ready(step(*args))
                    times.append((_t.perf_counter() - t0) * 1e6)
                _, peak = tracemalloc.get_traced_memory()
                tracemalloc.stop()
            arr = np.array(times)
            out[mode] = Measurement(
                name=f"{bench.name}/{mode}", median_us=float(np.median(arr)),
                mean_us=float(arr.mean()), p10_us=float(arr.min()),
                p90_us=float(arr.max()), compile_us=0.0,
                host_peak_bytes=int(peak), device_bytes_delta=0, runs=len(times))
            continue

        overrides: Dict[str, Any] = {}
        if mode == "jit_unrolled":
            overrides["scan_layers"] = False
        if mode == "jit_noremat":
            overrides["remat"] = "none"
        if overrides:
            bench2 = _with_cfg(bench, overrides)
        else:
            bench2 = bench
        step, args, donate = bench2.make(batch=batch, seq=seq)
        d = donate if mode == "jit_donated" else ()
        out[mode] = measure(f"{bench.name}/{mode}", step, args, d, runs=runs)
    return out


def _with_cfg(bench, overrides: Dict[str, Any]):
    """Clone a Benchmark whose make() applies reduced-config overrides."""
    import copy
    from repro.configs import get_arch, register_arch
    import dataclasses as dc
    b2 = copy.copy(bench)
    orig_make = type(bench).make

    def make(self=b2, *, batch=2, seq=64):
        cfg = get_arch(bench.arch).reduced(**overrides)
        # temporarily register a variant so Benchmark.make picks it up
        name = cfg.name
        from repro.configs.base import ARCHS
        saved = ARCHS.get(bench.arch)
        try:
            ARCHS[bench.arch] = dc.replace(cfg, name=bench.arch)
            return orig_make(self, batch=batch, seq=seq)
        finally:
            ARCHS[bench.arch] = saved
    b2.make = make
    return b2


def ratio_table(results: Dict[str, Dict[str, Measurement]], base: str = "jit",
                rel: str = "eager") -> List[Dict[str, Any]]:
    """Per-benchmark T/CM ratios (mode / base), like the paper's <1 / >1 bars."""
    rows = []
    for bname, modes in results.items():
        if base not in modes:
            continue
        b = modes[base]
        for mode, m in modes.items():
            if mode == base:
                continue
            rows.append({
                "benchmark": bname, "mode": mode,
                "time_ratio": m.median_us / b.median_us if b.median_us else 0.0,
                "host_mem_ratio": (m.host_peak_bytes / b.host_peak_bytes) if b.host_peak_bytes else 0.0,
                "device_mem_ratio": (m.device_bytes_delta / b.device_bytes_delta) if b.device_bytes_delta else 0.0,
            })
    return rows
