"""Execution-mode ("compiler backend") comparison — paper §3.2 analogue.

TorchBench compares PyTorch eager vs TorchInductor on time / CPU-mem /
GPU-mem.  The JAX stack's execution modes (see ``repro.runner.scenario``):

  eager          op-by-op dispatch (jax.disable_jit) — PyTorch-eager analogue
  jit            whole-step XLA compilation — the TorchInductor analogue
  jit_donated    + buffer donation (in-place state update)
  jit_unrolled   layer scan unrolled (bigger program, more fusion scope)
  jit_noremat    no activation rematerialization (time/memory trade)

Mode execution lives in the unified ``BenchmarkRunner`` (one arch build is
shared by eager/jit/jit_donated; the cfg-override modes build their own
variant).  This module keeps the comparison front-end: ``compare_modes``
for a single benchmark and ``ratio_table`` for the paper's T/CM/GM ratios.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.core.harness import Measurement
from repro.runner.scenario import MODES, Scenario

__all__ = ["MODES", "compare_modes", "ratio_table"]


def compare_modes(bench, *, batch: int = 2, seq: int = 64, runs: int = 5,
                  modes: Tuple[str, ...] = MODES,
                  runner=None) -> Dict[str, Measurement]:
    """Measure one suite benchmark under each execution mode."""
    from repro.runner.runner import BenchmarkRunner
    runner = runner or BenchmarkRunner(runs=runs)
    out: Dict[str, Measurement] = {}
    for mode in modes:
        sc = Scenario(arch=bench.arch, task=bench.task, batch=batch, seq=seq,
                      mode=mode)
        rr = runner.run(sc, runs=runs)
        if rr.status != "ok":
            raise RuntimeError(f"{sc.name}: {rr.error}")
        out[mode] = Measurement(
            name=f"{bench.name}/{mode}", median_us=rr.median_us,
            mean_us=rr.mean_us, p10_us=rr.p10_us, p90_us=rr.p90_us,
            compile_us=rr.compile_us, host_peak_bytes=rr.host_peak_bytes,
            device_bytes_delta=rr.device_bytes_delta, runs=rr.runs)
    return out


def ratio_table(results: Dict[str, Dict[str, Any]], base: str = "jit",
                rel: str = "eager") -> List[Dict[str, Any]]:
    """Per-benchmark T/CM ratios (mode / base), like the paper's <1 / >1 bars.

    ``results`` maps benchmark -> mode -> any object with ``median_us`` /
    ``host_peak_bytes`` / ``device_bytes_delta`` attributes (Measurement or
    RunResult).
    """
    rows = []
    for bname, modes in results.items():
        if base not in modes:
            continue
        b = modes[base]
        for mode, m in modes.items():
            if mode == base:
                continue
            rows.append({
                "benchmark": bname, "mode": mode,
                "time_ratio": m.median_us / b.median_us if b.median_us else 0.0,
                "host_mem_ratio": (m.host_peak_bytes / b.host_peak_bytes) if b.host_peak_bytes else 0.0,
                "device_mem_ratio": (m.device_bytes_delta / b.device_bytes_delta) if b.device_bytes_delta else 0.0,
            })
    return rows
