"""Inference batch-size search (paper §2.2 batch-size configuration).

TorchBench doubles the inference batch size until GPU utilization peaks; the
analogue here maximizes decode throughput (tokens/s) on the measured path,
stopping when throughput stops improving or memory fails.

The doubling loop runs through the unified ``BenchmarkRunner``: one arch
build (model + params) is shared by every batch size probed, so each probe
pays only for its own cache init and compile.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple


def search_batch_size(bench, *, seq: int = 64, start: int = 1, max_batch: int = 64,
                      runs: int = 3, runner=None) -> Tuple[int, List[Dict]]:
    from repro.runner.runner import BenchmarkRunner
    from repro.runner.scenario import Scenario
    runner = runner or BenchmarkRunner(runs=runs)
    best_b, best_tps = start, 0.0
    history = []
    b = start
    while b <= max_batch:
        sc = Scenario(arch=bench.arch, task=bench.task, batch=b, seq=seq)
        rr = runner.run(sc, runs=runs)
        if rr.status != "ok":
            history.append({"batch": b, "error": (rr.error or "")[:100]})
            break
        tps = b / (rr.median_us / 1e6)
        history.append({"batch": b, "median_us": rr.median_us, "items_per_s": tps})
        if tps > best_tps * 1.05:
            best_tps, best_b = tps, b
        elif tps < best_tps * 0.95:
            break   # throughput declining: past the knee
        b *= 2
    return best_b, history
