"""Inference batch-size search (paper §2.2 batch-size configuration).

TorchBench doubles the inference batch size until GPU utilization peaks; the
analogue here maximizes decode throughput (tokens/s) on the measured path,
stopping when throughput stops improving or memory fails.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax

from repro.core.harness import measure


def search_batch_size(bench, *, seq: int = 64, start: int = 1, max_batch: int = 64,
                      runs: int = 3) -> Tuple[int, List[Dict]]:
    best_b, best_tps = start, 0.0
    history = []
    b = start
    while b <= max_batch:
        try:
            step, args, donate = bench.make(batch=b, seq=seq)
            m = measure(f"{bench.name}/b{b}", step, args, donate, runs=runs)
            tps = b / (m.median_us / 1e6)
            history.append({"batch": b, "median_us": m.median_us, "items_per_s": tps})
            if tps > best_tps * 1.05:
                best_tps, best_b = tps, b
            elif tps < best_tps * 0.95:
                break   # throughput declining: past the knee
        except (RuntimeError, MemoryError) as e:
            history.append({"batch": b, "error": str(e)[:100]})
            break
        b *= 2
    return best_b, history
