"""Accelerator profiles for roofline projection (paper Fig. 5 / Table 3 analogue).

The TorchBench hardware comparison (A100 vs MI210) becomes a roofline
projection onto several accelerator profiles from the same compiled
FLOPs/bytes/collective terms.  TPU v5e is the deployment target.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    peak_flops_bf16: float      # FLOP/s per chip
    peak_flops_fp32: float
    hbm_bw: float               # bytes/s per chip
    hbm_bytes: float            # capacity per chip
    link_bw: float              # bytes/s per inter-chip link
    chips_per_pod: int

    def peak(self, dtype: str = "bf16") -> float:
        return self.peak_flops_bf16 if dtype == "bf16" else self.peak_flops_fp32


HW_PROFILES: Dict[str, HardwareProfile] = {
    # assignment constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
    "tpu_v5e": HardwareProfile("tpu_v5e", 197e12, 98.5e12, 819e9, 16e9, 50e9, 256),
    "tpu_v4": HardwareProfile("tpu_v4", 275e12, 137e12, 1200e9, 32e9, 100e9, 1024),
    # GPU-profile analogues of the paper's Fig.5 comparison
    "a100_like": HardwareProfile("a100_like", 312e12, 19.5e12, 1555e9, 40e9, 75e9, 8),
    "mi210_like": HardwareProfile("mi210_like", 181e12, 22.6e12, 1638e9, 64e9, 50e9, 8),
}

DEFAULT_HW = HW_PROFILES["tpu_v5e"]
