"""Cross-accelerator comparison (paper §3.3 / Fig. 5 analogue).

TorchBench's A100-vs-MI210 study found no universal winner: the outcome per
model hinges on which numeric format its kernels can use (TF32 vs FP32).
The roofline projection reproduces that structure: for each benchmark cell
we project step time onto two hardware profiles and report the ratio
T_a / T_b; the "format" effect is modeled by each profile's bf16:fp32 peak
ratio applied to the compute term (softmax/normalization FLOPs run at fp32
rate — approximated by the fp32_frac argument).
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List

from repro.core.hardware import HW_PROFILES, HardwareProfile


def project_step_time(rl: Dict[str, Any], hw: HardwareProfile, *,
                      fp32_frac: float = 0.05, overlap: bool = False) -> float:
    """Project a roofline record (see Roofline.to_dict) onto a profile."""
    chips = rl["chips"]
    f = rl["flops_global"]
    compute = (f * (1 - fp32_frac) / hw.peak_flops_bf16 +
               f * fp32_frac / hw.peak_flops_fp32) / chips
    memory = rl["bytes_global"] / (chips * hw.hbm_bw)
    collective = rl["collective_bytes_global"] / (chips * hw.link_bw)
    terms = (compute, memory, collective)
    return max(terms) if overlap else sum(terms)


def hardware_ratio_table(dryrun_results: Iterable[Dict[str, Any]],
                         hw_a: str = "a100_like", hw_b: str = "mi210_like",
                         **kw) -> List[Dict[str, Any]]:
    rows = []
    a, b = HW_PROFILES[hw_a], HW_PROFILES[hw_b]
    for r in dryrun_results:
        if "roofline" not in r:
            continue
        rl = r["roofline"]
        ta = project_step_time(rl, a, **kw)
        tb = project_step_time(rl, b, **kw)
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            f"t_{hw_a}_s": ta, f"t_{hw_b}_s": tb,
            "ratio": ta / tb if tb else 0.0,
            "winner": hw_a if ta < tb else hw_b,
            "dominant": rl["dominant"],
        })
    return rows
