"""Trip-count-aware HLO cost analysis from ``compiled.as_text()``.

Why not ``compiled.cost_analysis()``?  Two verified limitations (see
EXPERIMENTS.md §Dry-run methodology):

1. **while bodies are counted once** — a 60-layer ``lax.scan`` model reports
   1/60th of its FLOPs.  This module parses the HLO module text, derives each
   while loop's trip count from its condition computation and multiplies the
   body cost through (recursively, for nested scans).
2. Numbers are **per partition** under SPMD — the caller scales by chip count.

It also extracts what cost_analysis cannot: per-collective byte counts
(all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute,
including async -start forms), with ring-cost multipliers, for the roofline
collective term.

The parser is deliberately tolerant: anything it cannot parse contributes
zero and is recorded in ``notes``.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_CALL_RE = re.compile(r"(?:^|\s)([a-z][a-z0-9\-]*)\(")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
# the terse dump style (xla pass dumps): "region_0.36 {" / "ENTRY main.497_spmd {"
_COMP_START_TERSE_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\{$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
# terse operand lists carry bare names ("dot(dynamic-slice.5, collective-permute)")
_BARE_OPERAND_RE = re.compile(r"(?<![\w.\-])([A-Za-z_][\w\-]*(?:\.\d+)?)")
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_INT_RE = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

#: time-attribution op classes (profiler subsystem, src/repro/profiler/):
#:   matmul      dot / convolution contractions (MXU work)
#:   attention   custom attention kernels (pallas / flash custom-calls;
#:               plain dot-product attention lowers to dots -> matmul)
#:   collective  inter-chip communication
#:   elementwise fusible pointwise ops
#:   other       everything else (reductions, slices, scatter/gather, ...)
OP_CLASSES = ("matmul", "attention", "collective", "elementwise", "other")

_ATTENTION_CALL_RE = re.compile(r"attention|flash|pallas|rglru|ssd|mosaic",
                                re.IGNORECASE)


def op_class(op: str, rest: str = "") -> str:
    """The attribution class of one HLO opcode (see OP_CLASSES)."""
    base = op[:-6] if op.endswith("-start") else op
    if base in COLLECTIVE_OPS:
        return "collective"
    if op in ("dot", "convolution"):
        return "matmul"
    if op == "custom-call":
        return "attention" if _ATTENTION_CALL_RE.search(rest or "") else "other"
    if op in _ELEMENTWISE_OPS:
        return "elementwise"
    return "other"


def _shape_info(type_str: str) -> Tuple[int, int]:
    """-> (total bytes, elems of first array) for a possibly-tuple type."""
    total = 0
    first_elems = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total += elems * _DTYPE_BYTES[dt]
        if first_elems == 0:
            first_elems = elems
    return total, first_elems


def _dims_of(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    rest: str            # operands + attributes (raw)
    operands: List[str]


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    collective_bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    notes: List[str] = dataclasses.field(default_factory=list)
    # per-op-class tallies (see OP_CLASSES); invariants maintained by the
    # walker: sum(flops_by_class) == flops, sum(bytes_by_class) == bytes_accessed
    flops_by_class: Dict[str, float] = dataclasses.field(default_factory=dict)
    bytes_by_class: Dict[str, float] = dataclasses.field(default_factory=dict)

    def tally_flops(self, cls: str, flops: float) -> None:
        self.flops += flops
        self.flops_by_class[cls] = self.flops_by_class.get(cls, 0.0) + flops

    def tally_bytes(self, cls: str, nbytes: float) -> None:
        self.bytes_accessed += nbytes
        self.bytes_by_class[cls] = self.bytes_by_class.get(cls, 0.0) + nbytes

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + int(v * mult)
        for k, v in other.collective_bytes_by_op.items():
            self.collective_bytes_by_op[k] = self.collective_bytes_by_op.get(k, 0.0) + v * mult
        for k, v in other.flops_by_class.items():
            self.flops_by_class[k] = self.flops_by_class.get(k, 0.0) + v * mult
        for k, v in other.bytes_by_class.items():
            self.bytes_by_class[k] = self.bytes_by_class.get(k, 0.0) + v * mult


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

# Ops that fuse into their producers/consumers on TPU — when analyzing the
# *pre-fusion* (post-SPMD-partitioning) module, counting their bytes would
# double-count traffic the fused kernel never pays.  Their FLOPs still count.
_ELEMENTWISE_OPS = {
    "add", "subtract", "multiply", "divide", "power", "negate", "abs",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "sqrt", "rsqrt", "cbrt", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "convert", "compare",
    "select", "and", "or", "xor", "not", "clamp", "maximum", "minimum",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "broadcast", "reshape", "copy", "cosine", "sine", "atan2", "expm1",
    "erf", "is-finite", "real", "imag", "reverse", "map", "pad", "slice",
}


class _Module:
    def __init__(self, text: str, fused_bytes: bool = False):
        self.computations: Dict[str, List[_Instr]] = {}
        self.params: Dict[str, Dict[str, str]] = {}   # comp -> param name -> type
        self.fused_bytes = fused_bytes   # True: pre-fusion module, skip elementwise bytes
        self._parse(text)
        self._memo: Dict[str, HloCost] = {}
        self.notes: List[str] = []

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        self.entry: Optional[str] = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_START_RE.match(line.strip())
                if m and line.rstrip().endswith("{"):
                    cur = m.group(2)
                    self.computations[cur] = []
                    self.params[cur] = {}
                    # parse parameter types from the signature
                    sig = m.group(3)
                    for pm in re.finditer(r"%?([\w.\-]+)\s*:\s*([^,()]+(?:\([^)]*\))?)", sig):
                        self.params[cur][pm.group(1)] = pm.group(2)
                    if m.group(1):
                        self.entry = cur
                    continue
                # terse style: no signature — parameter types come from the
                # "name = TYPE parameter(N)" instructions inside the body
                m = _COMP_START_TERSE_RE.match(line.strip())
                if m:
                    cur = m.group(2)
                    self.computations[cur] = []
                    self.params[cur] = {}
                    if m.group(1):
                        self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            am = _ASSIGN_RE.match(line)
            if am:
                name, rhs = am.groups()
                # rhs = "TYPE opname(operands), attrs"; TYPE may be a tuple
                # containing /*index=N*/ comments — find the first op call
                # token preceded by whitespace (layout tiles like T(256) are
                # preceded by ':', never by a space).
                om = _OP_CALL_RE.search(rhs)
                if not om:
                    continue
                type_str = rhs[: om.start()].strip()
                op = om.group(1)
                rest = rhs[om.end():]
                # operands run until the matching close paren; attrs follow.
                seg = rest.split("), ")[0] if ")" in rest else rest
                operands = _OPERAND_RE.findall(seg)
                if not operands:
                    # terse style: bare instruction names, no '%' sigil
                    operands = _BARE_OPERAND_RE.findall(seg.split(")")[0])
                self.computations[cur].append(_Instr(name, type_str, op, rest, operands))

    # ---- symbol table ----
    def _type_of(self, comp: str, name: str) -> Optional[str]:
        for ins in self.computations.get(comp, ()):
            if ins.name == name:
                return ins.type_str
        return self.params.get(comp, {}).get(name)

    # ---- trip count ----
    def trip_count(self, cond_comp: str) -> Optional[int]:
        best = None
        for ins in self.computations.get(cond_comp, ()):
            m = _CONST_INT_RE.search(f"= {ins.type_str} {ins.op}({ins.rest}")
            if ins.op == "constant":
                mm = re.search(r"constant\((\d+)\)", ins.rest[: 64] if ins.rest else "")
                # rest holds "N)" for scalar int constants
                if mm:
                    v = int(mm.group(1))
                    best = v if best is None else max(best, v)
            if m:
                v = int(m.group(1))
                best = v if best is None else max(best, v)
        return best

    # ---- cost ----
    def cost_of(self, comp: str) -> HloCost:
        if comp in self._memo:
            return self._memo[comp]
        c = HloCost()
        self._memo[comp] = c   # break cycles defensively
        for ins in self.computations.get(comp, ()):
            self._instr_cost(comp, ins, c)
        return c

    def _operand_bytes(self, comp: str, ins: _Instr) -> float:
        total = 0.0
        for op_name in ins.operands:
            t = self._type_of(comp, op_name)
            if t:
                total += _shape_info(t)[0]
        return total

    def _instr_cost(self, comp: str, ins: _Instr, c: HloCost) -> None:
        out_bytes, out_elems = _shape_info(ins.type_str)
        op = ins.op

        if op == "while":
            body = cond = None
            bm = re.search(r"body=%?([\w.\-]+)", ins.rest)
            cm = _COND_RE.search(ins.rest)
            if bm:
                body = bm.group(1)
            if cm:
                cond = cm.group(1)
            trip = self.trip_count(cond) if cond else None
            if trip is None:
                trip = 1
                c.notes.append(f"while {ins.name}: unknown trip count, using 1")
            if body:
                c.add(self.cost_of(body), float(trip))
            return

        if op in ("call", "fusion"):
            m = _CALLS_RE.search(ins.rest)
            # the fusion's HBM traffic gets the class of its dominant inner
            # FLOPs contributor (a matmul fusion's reads are matmul reads);
            # pure-pointwise fusions fall back to elementwise
            bytes_cls = "elementwise"
            if m:
                inner = self.cost_of(m.group(1))
                c.flops += inner.flops
                c.collective_bytes += inner.collective_bytes
                for k, v in inner.collective_counts.items():
                    c.collective_counts[k] = c.collective_counts.get(k, 0) + v
                for k, v in inner.collective_bytes_by_op.items():
                    c.collective_bytes_by_op[k] = c.collective_bytes_by_op.get(k, 0.0) + v
                for k, v in inner.flops_by_class.items():
                    c.flops_by_class[k] = c.flops_by_class.get(k, 0.0) + v
                if inner.flops_by_class:
                    bytes_cls = max(inner.flops_by_class,
                                    key=inner.flops_by_class.get)
            # fusion HBM traffic = its own operands + result (interior is on-chip)
            c.tally_bytes(bytes_cls, out_bytes + self._operand_bytes(comp, ins))
            return

        if op == "conditional":
            branches = re.findall(r"(?:true_computation|false_computation|branch_computations=\{)([^,}]+)", ins.rest)
            best = HloCost()
            for b in branches:
                bc = self.cost_of(b.strip().lstrip("%"))
                if bc.flops >= best.flops:
                    best = bc
            c.add(best)
            c.tally_bytes("other", out_bytes + self._operand_bytes(comp, ins))
            return

        base_op = op[:-6] if op.endswith("-start") else op
        if base_op in COLLECTIVE_OPS:
            in_bytes = self._operand_bytes(comp, ins)
            if base_op == "all-reduce":
                wire = 2.0 * in_bytes
            elif base_op == "all-gather":
                wire = float(out_bytes)
            else:   # reduce-scatter, all-to-all, collective-permute
                wire = in_bytes
            c.collective_bytes += wire
            c.collective_counts[base_op] = c.collective_counts.get(base_op, 0) + 1
            c.collective_bytes_by_op[base_op] = c.collective_bytes_by_op.get(base_op, 0.0) + wire
            c.tally_bytes("collective", out_bytes + in_bytes)
            return
        if op.endswith("-done"):
            return

        if op in _SKIP_BYTES_OPS:
            return

        cls = op_class(op, ins.rest)
        # FLOPs
        if op == "dot":
            lhs_t = self._type_of(comp, ins.operands[0]) if ins.operands else None
            contract = 1
            cm = _CONTRACT_RE.search(ins.rest)
            if lhs_t and cm and cm.group(1):
                dims = _dims_of(lhs_t)
                for idx in cm.group(1).split(","):
                    i = int(idx)
                    if i < len(dims):
                        contract *= dims[i]
            c.tally_flops(cls, 2.0 * out_elems * contract)
        elif op == "convolution":
            rhs_t = self._type_of(comp, ins.operands[1]) if len(ins.operands) > 1 else None
            kdims = _dims_of(rhs_t) if rhs_t else []
            kelems = 1
            for d in kdims:
                kelems *= d
            out_feat = kdims[-1] if kdims else 1
            c.tally_flops(cls, 2.0 * out_elems * (kelems / max(out_feat, 1)))
        elif op in ("custom-call", "sort", "rng", "rng-bit-generator"):
            pass  # negligible / opaque
        else:
            c.tally_flops(cls, float(out_elems))   # elementwise estimate

        if self.fused_bytes and op in _ELEMENTWISE_OPS:
            return   # fuses into neighbours on TPU: no HBM round-trip
        c.tally_bytes(cls, out_bytes + self._operand_bytes(comp, ins))


def analyze_hlo(hlo_text: str, fused_bytes: bool = False) -> HloCost:
    """fused_bytes=True for pre-fusion (post-SPMD-partitioning) modules:
    elementwise ops contribute FLOPs but no HBM bytes (they fuse on TPU)."""
    mod = _Module(hlo_text, fused_bytes=fused_bytes)
    if mod.entry is None:
        cost = HloCost()
        cost.notes.append("no ENTRY computation found")
        return cost
    cost = HloCost()
    cost.add(mod.cost_of(mod.entry))
    return cost
