# The paper's primary contribution: TorchBench-style benchmarking
# infrastructure for the JAX/TPU stack (suite, harness, coverage,
# breakdown, compiler & hardware comparison, CI regression detection).
from repro.core.hardware import HW_PROFILES, HardwareProfile  # noqa: F401
from repro.core.harness import Measurement, RegressionHook, measure  # noqa: F401
from repro.core.hloanalysis import HloCost, analyze_hlo  # noqa: F401
from repro.core.regression import Commit, Issue, MetricStore, bisect_commits, detect  # noqa: F401
from repro.core.roofline import Roofline, roofline_from_cost  # noqa: F401
from repro.core.suite import Benchmark, build_suite  # noqa: F401
