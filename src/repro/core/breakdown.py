"""Execution-time breakdown (paper Figs. 1-2 / Table 2 analogue).

TorchBench decomposes wall time into GPU-active / data-movement / idle with
a profiler.  On the TPU target (no profiler in this container) the same
decomposition is derived from the dry-run roofline terms:

    busy fraction     = compute_s / step_upper           (MXU active)
    data movement     = memory_s / step_upper            (HBM-bound exposure)
    idle (comm-bound) = collective_s / step_upper        (ICI wait)

and aggregated per domain exactly like the paper's Table 2.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Any, Dict, Iterable, List

from repro.configs import ARCHS


def breakdown_rows(dryrun_results: Iterable[Dict[str, Any]]) -> List[Dict[str, Any]]:
    rows = []
    for r in dryrun_results:
        if "roofline" not in r:
            continue
        rl = r["roofline"]
        total = rl["compute_s"] + rl["memory_s"] + rl["collective_s"]
        if not total:
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r.get("mesh", ""),
            "domain": ARCHS[r["arch"]].domain if r["arch"] in ARCHS else "?",
            "compute_frac": rl["compute_s"] / total,
            "memory_frac": rl["memory_s"] / total,
            "collective_frac": rl["collective_s"] / total,
            "dominant": rl["dominant"],
        })
    return rows


def domain_table(rows: List[Dict[str, Any]], kind_filter=None) -> List[Dict[str, Any]]:
    acc: Dict[str, List[Dict]] = defaultdict(list)
    for r in rows:
        if kind_filter and not kind_filter(r):
            continue
        acc[r["domain"]].append(r)
    out = []
    for dom, rs in sorted(acc.items()):
        out.append({
            "domain": dom,
            "n": len(rs),
            "compute_frac": sum(r["compute_frac"] for r in rs) / len(rs),
            "memory_frac": sum(r["memory_frac"] for r in rs) / len(rs),
            "collective_frac": sum(r["collective_frac"] for r in rs) / len(rs),
        })
    return out
