"""Execution-time breakdown (paper Figs. 1-2 / Table 2 analogue).

TorchBench decomposes wall time into GPU-active / data-movement / idle with
a profiler.  Two sources feed the same row/table shape here, each row
labeled with its provenance so mixed tables stay unambiguous:

* ``source="measured"`` — the measured profiling subsystem
  (``src/repro/profiler/``): per-cell phase timelines + op-class
  attribution recorded by a profiled runner sweep.  Fractions are of
  *measured* step time and include the dispatch/idle shares the analytic
  model cannot see.
* ``source="analytic"`` — the dry-run roofline estimate (no real device
  for the production shapes in this container):

      busy fraction     = compute_s / step_upper         (MXU active)
      data movement     = memory_s / step_upper          (HBM-bound exposure)
      idle (comm-bound) = collective_s / step_upper      (ICI wait)

Both aggregate per domain exactly like the paper's Table 2.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Any, Dict, Iterable, List

from repro.configs import ARCHS


def breakdown_rows(dryrun_results: Iterable[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Analytic rows from dry-run cells (roofline-term fractions)."""
    rows = []
    for r in dryrun_results:
        if "roofline" not in r:
            continue
        rl = r["roofline"]
        total = rl["compute_s"] + rl["memory_s"] + rl["collective_s"]
        if not total:
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r.get("mesh", ""),
            "domain": ARCHS[r["arch"]].domain if r["arch"] in ARCHS else "?",
            "compute_frac": rl["compute_s"] / total,
            "memory_frac": rl["memory_s"] / total,
            "collective_frac": rl["collective_s"] / total,
            "dominant": rl["dominant"],
            "source": "analytic",
        })
    return rows


def measured_breakdown_rows(results: Iterable[Any]) -> List[Dict[str, Any]]:
    """Measured rows from profiled RunResults (dicts or RunResult objects).

    Same row shape as ``breakdown_rows`` — ``shape`` holds the task so the
    train/inference split works — plus the measured-only ``dispatch_frac``
    / ``idle_frac`` columns (the three roofline fractions deliberately do
    NOT sum to 1 on measured rows: the remainder is measured overhead).
    Cells without a profile (errors, eager, unprofiled) are skipped."""
    rows = []
    for r in results:
        rec = r.to_dict() if hasattr(r, "to_dict") else dict(r)
        extra = rec.get("extra") or {}
        if rec.get("status") != "ok" or "prof_frac_compute" not in extra:
            continue
        fracs = {
            "compute": extra["prof_frac_compute"],
            "memory": extra["prof_frac_memory"],
            "collective": extra["prof_frac_collective"],
            "dispatch": extra["prof_frac_dispatch"],
            "idle": extra["prof_frac_idle"],
        }
        rows.append({
            "arch": rec["arch"], "shape": rec["task"], "mesh": "host",
            "domain": ARCHS[rec["arch"]].domain if rec["arch"] in ARCHS else "?",
            "compute_frac": fracs["compute"],
            "memory_frac": fracs["memory"],
            "collective_frac": fracs["collective"],
            "dispatch_frac": fracs["dispatch"],
            "idle_frac": fracs["idle"],
            "dominant": max(fracs, key=fracs.get),
            "source": "measured",
            "cell": rec["name"],
        })
    return rows


def domain_table(rows: List[Dict[str, Any]], kind_filter=None) -> List[Dict[str, Any]]:
    acc: Dict[str, List[Dict]] = defaultdict(list)
    for r in rows:
        if kind_filter and not kind_filter(r):
            continue
        acc[r["domain"]].append(r)
    out = []
    for dom, rs in sorted(acc.items()):
        out.append({
            "domain": dom,
            "n": len(rs),
            "compute_frac": sum(r["compute_frac"] for r in rs) / len(rs),
            "memory_frac": sum(r["memory_frac"] for r in rs) / len(rs),
            "collective_frac": sum(r["collective_frac"] for r in rs) / len(rs),
        })
    return out
