"""Measurement harness (paper §2.2 discipline).

* measures ONLY the computation phase: inputs are device-resident before
  timing starts, ``block_until_ready`` bounds the region;
* runs each benchmark N times and reports the run with the **median**
  execution time (exactly the paper's protocol), plus mean/p10/p90;
* collects host peak memory (tracemalloc of the run) and device buffer
  deltas (live device arrays before/after);
* a regression-injection hook lets the CI tests create known slowdowns
  (sleep) and memory bloat (retained buffers) to validate detection.
"""
from __future__ import annotations

import dataclasses
import gc
import time
import tracemalloc
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Measurement:
    name: str
    median_us: float
    mean_us: float
    p10_us: float
    p90_us: float
    compile_us: float
    host_peak_bytes: int
    device_bytes_delta: int
    runs: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _live_device_bytes() -> int:
    total = 0
    for d in jax.live_arrays():
        try:
            total += d.nbytes
        except Exception:   # noqa: BLE001
            pass
    return total


class RegressionHook:
    """Injected fault for CI validation: slows steps / leaks buffers."""

    def __init__(self, slowdown_s: float = 0.0, leak_bytes: int = 0):
        self.slowdown_s = slowdown_s
        self.leak_bytes = leak_bytes
        self._leaked = []

    def fire(self) -> None:
        if self.slowdown_s:
            time.sleep(self.slowdown_s)
        if self.leak_bytes:
            self._leaked.append(jnp.zeros(self.leak_bytes // 4, jnp.float32).block_until_ready())


def measure(name: str, step_fn: Callable, args: Tuple, donate: Tuple[int, ...] = (),
            *, runs: int = 10, warmup: int = 1,
            hook: Optional[RegressionHook] = None) -> Measurement:
    """Paper protocol: median-of-N timing of the jitted computation phase."""
    gc.collect()
    dev0 = _live_device_bytes()
    jitted = jax.jit(step_fn) if not donate else jax.jit(step_fn)
    # compile (excluded from the measured region, reported separately)
    t0 = time.perf_counter()
    out = jitted(*args)
    jax.block_until_ready(out)
    compile_us = (time.perf_counter() - t0) * 1e6

    # donation-aware steady state: thread state through when donated
    tracemalloc.start()
    times = []
    cur_args = args
    for i in range(warmup + runs):
        t0 = time.perf_counter()
        out = jitted(*cur_args)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) * 1e6
        if hook is not None:
            hook.fire()
            dt += (hook.slowdown_s * 1e6)
        if i >= warmup:
            times.append(dt)
        # thread outputs back in for stateful steps (train: state, serve: cache)
        if donate == (0,) and isinstance(out, tuple) and len(out) == 2:
            cur_args = (out[0],) + args[1:]
        elif donate == (2,) and isinstance(out, tuple) and len(out) == 2:
            cur_args = args[:2] + (out[1],)
    _, host_peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    dev1 = _live_device_bytes()
    arr = np.array(times)
    return Measurement(
        name=name,
        median_us=float(np.median(arr)),
        mean_us=float(arr.mean()),
        p10_us=float(np.percentile(arr, 10)),
        p90_us=float(np.percentile(arr, 90)),
        compile_us=compile_us,
        host_peak_bytes=int(host_peak),
        device_bytes_delta=int(dev1 - dev0),
        runs=runs,
    )
