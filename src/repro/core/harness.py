"""Measurement harness (paper §2.2 discipline).

* measures ONLY the computation phase: inputs are device-resident before
  timing starts, ``block_until_ready`` bounds the region;
* runs each benchmark N times and reports the run with the **median**
  execution time (exactly the paper's protocol), plus mean/p10/p90;
* collects host peak memory (tracemalloc of the run) and device buffer
  deltas (live device arrays before/after);
* a regression-injection hook lets the CI tests create known slowdowns
  (sleep) and memory bloat (retained buffers) to validate detection.
"""
from __future__ import annotations

import dataclasses
import gc
import time
import tracemalloc
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Measurement:
    name: str
    median_us: float
    mean_us: float
    p10_us: float
    p90_us: float
    compile_us: float
    host_peak_bytes: int
    device_bytes_delta: int
    runs: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _live_device_bytes() -> int:
    total = 0
    for d in jax.live_arrays():
        try:
            total += d.nbytes
        except Exception:   # noqa: BLE001
            pass
    return total


class RegressionHook:
    """Injected fault for CI validation: slows steps / leaks buffers."""

    def __init__(self, slowdown_s: float = 0.0, leak_bytes: int = 0):
        self.slowdown_s = slowdown_s
        self.leak_bytes = leak_bytes
        self._leaked = []

    def fire(self) -> None:
        if self.slowdown_s:
            time.sleep(self.slowdown_s)
        if self.leak_bytes:
            self._leaked.append(jnp.zeros(self.leak_bytes // 4, jnp.float32).block_until_ready())


def measure_eager(name: str, step_fn: Callable, args: Tuple, *,
                  runs: int = 3,
                  hook: Optional[RegressionHook] = None) -> Measurement:
    """Op-by-op dispatch timing (``jax.disable_jit``) — the eager analogue of
    ``measure`` for the compiler-mode comparison.  No compile, no donation."""
    with jax.disable_jit():
        jax.block_until_ready(step_fn(*args))   # warm
        tracemalloc.start()
        times = []
        for _ in range(max(2, runs)):
            t0 = time.perf_counter()
            jax.block_until_ready(step_fn(*args))
            dt = (time.perf_counter() - t0) * 1e6
            if hook is not None:
                hook.fire()
                dt += (hook.slowdown_s * 1e6)
            times.append(dt)
        _, host_peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
    arr = np.array(times)
    return Measurement(
        name=name, median_us=float(np.median(arr)), mean_us=float(arr.mean()),
        p10_us=float(arr.min()), p90_us=float(arr.max()), compile_us=0.0,
        host_peak_bytes=int(host_peak), device_bytes_delta=0, runs=len(times))


def prepare(step_fn: Callable, donate: Tuple[int, ...] = ()) -> Callable:
    """Jit a step with real buffer donation (suite convention: donated state
    comes back as an element of a 2-tuple output, see ``_thread``)."""
    if donate:
        return jax.jit(step_fn, donate_argnums=donate)
    return jax.jit(step_fn)


def _thread(out: Any, cur_args: Tuple, donate: Tuple[int, ...]) -> Tuple:
    """Thread a step's output state back into its (donated) argument slot.

    Suite convention: train steps are ``(state, batch) -> (state, metrics)``
    with ``donate == (0,)``; serving steps are ``(params, toks, cache) ->
    (logits, cache)`` with ``donate == (2,)``.  With donation active the old
    buffers are invalidated, so every subsequent call MUST use the threaded
    output — including the first call after compilation.
    """
    if donate == (0,) and isinstance(out, tuple) and len(out) == 2:
        return (out[0],) + cur_args[1:]
    if donate == (2,) and isinstance(out, tuple) and len(out) == 2:
        return cur_args[:2] + (out[1],)
    return cur_args


def measure(name: str, step_fn: Callable, args: Tuple, donate: Tuple[int, ...] = (),
            *, runs: int = 10, warmup: int = 1,
            hook: Optional[RegressionHook] = None,
            jitted: Optional[Callable] = None,
            final_args: Optional[list] = None,
            phase_log: Optional[list] = None,
            events: Optional[list] = None) -> Measurement:
    """Paper protocol: median-of-N timing of the jitted computation phase.

    ``jitted`` lets a caller (the BenchmarkRunner) reuse an already-compiled
    executable; ``final_args`` (a mutable list) receives the threaded
    steady-state arguments so the caller can keep them valid across calls
    when buffers are donated.

    ``phase_log`` (a mutable list) is the profiler hook: it receives one
    ``(dispatch_s, device_s)`` tuple per *measured* step — the time until
    the async jitted call returns vs the ``block_until_ready`` wait.  The
    split costs one extra ``perf_counter`` read per step and is taken only
    when a log is passed, so unprofiled measurements are byte-identical to
    the pre-profiler protocol.

    ``events`` (a mutable list) is the tracing hook: it receives one
    ``(phase, wall_t0, wall_t1)`` tuple per protocol phase — "compile"
    (first jitted call + ready wait), "warm" (the warmup prefix of the
    loop) and "measure" (the timed iterations).  Wall-clock boundaries
    are read only when a list is passed, so untraced measurements pay
    nothing.
    """
    gc.collect()
    dev0 = _live_device_bytes()
    if jitted is None:
        jitted = prepare(step_fn, donate)
    # compile (excluded from the measured region, reported separately)
    tw = time.time() if events is not None else 0.0
    t0 = time.perf_counter()
    out = jitted(*args)
    jax.block_until_ready(out)
    compile_us = (time.perf_counter() - t0) * 1e6
    # donation-aware steady state: thread state through when donated
    cur_args = _thread(out, args, donate)
    if events is not None:
        t_phase = time.time()
        events.append(("compile", tw, t_phase))

    tracemalloc.start()
    times = []
    for i in range(warmup + runs):
        if events is not None and i == warmup:
            now = time.time()
            events.append(("warm", t_phase, now))
            t_phase = now
        t0 = time.perf_counter()
        out = jitted(*cur_args)
        t_disp = time.perf_counter() if phase_log is not None else 0.0
        jax.block_until_ready(out)
        t_done = time.perf_counter()
        dt = (t_done - t0) * 1e6
        if hook is not None:
            hook.fire()
            dt += (hook.slowdown_s * 1e6)
        if i >= warmup:
            times.append(dt)
            if phase_log is not None:
                phase_log.append((t_disp - t0, t_done - t_disp))
        cur_args = _thread(out, cur_args, donate)
    if events is not None:
        events.append(("measure", t_phase, time.time()))
    _, host_peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    if final_args is not None:
        final_args.append(cur_args)
    dev1 = _live_device_bytes()
    arr = np.array(times)
    return Measurement(
        name=name,
        median_us=float(np.median(arr)),
        mean_us=float(arr.mean()),
        p10_us=float(np.percentile(arr, 10)),
        p90_us=float(np.percentile(arr, 90)),
        compile_us=compile_us,
        host_peak_bytes=int(host_peak),
        device_bytes_delta=int(dev1 - dev0),
        runs=runs,
    )
