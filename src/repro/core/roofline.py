"""Three-term roofline model from dry-run compiled artifacts.

    compute_s    = HLO_FLOPs_global    / (chips * peak_FLOP/s)
    memory_s     = HLO_bytes_global    / (chips * HBM_bw)
    collective_s = collective_bytes    / (chips * link_bw)

HLO quantities come from :mod:`repro.core.hloanalysis` (per-partition,
trip-count corrected) and are scaled to global by ``chips``.  The roofline
step-time estimate assumes perfect overlap (max of terms) and none (sum);
reality is in between — the perf loop drives the *dominant* term down.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.hardware import DEFAULT_HW, HardwareProfile
from repro.core.hloanalysis import HloCost


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_global: float
    bytes_global: float
    collective_bytes_global: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / HLO_FLOPs
    step_time_lower_s: float     # max(terms): perfect overlap
    step_time_upper_s: float     # sum(terms): no overlap
    roofline_fraction: float     # compute_s / step_time_upper (how compute-bound)
    hw: str = "tpu_v5e"
    collective_counts: Optional[Dict[str, int]] = None
    collective_bytes_by_op: Optional[Dict[str, float]] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_from_cost(
    cost: HloCost,
    *,
    arch: str,
    shape: str,
    mesh: str,
    chips: int,
    model_flops: float,
    hw: HardwareProfile = DEFAULT_HW,
) -> Roofline:
    fg = cost.flops * chips
    bg = cost.bytes_accessed * chips
    cg = cost.collective_bytes * chips
    compute_s = fg / (chips * hw.peak_flops_bf16)
    memory_s = bg / (chips * hw.hbm_bw)
    collective_s = cg / (chips * hw.link_bw)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    lo = max(terms.values())
    hi = sum(terms.values())
    return Roofline(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        flops_global=fg, bytes_global=bg, collective_bytes_global=cg,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=model_flops / fg if fg else 0.0,
        step_time_lower_s=lo, step_time_upper_s=hi,
        roofline_fraction=compute_s / hi if hi else 0.0,
        hw=hw.name,
        collective_counts=dict(cost.collective_counts),
        collective_bytes_by_op=dict(cost.collective_bytes_by_op),
    )


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (forward-only), N = *active* params.

    N counts routed-expert weights at top_k/n_experts utilization (MoE);
    D = tokens processed by the step (decode: one per sequence).
    """
    from repro.models import build_model
    import jax

    model = build_model(cfg)
    defs = model.param_defs()
    total = 0.0
    flat, _ = jax.tree_util.tree_flatten_with_path(defs, is_leaf=lambda d: hasattr(d, "shape"))
    for path, d in flat:
        n = 1.0
        for s in d.shape:
            n *= s
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        if cfg.n_experts and ("mlp/w_" in keys or "mlp/router" in keys) and "shared" not in keys:
            if "router" not in keys:
                n *= cfg.top_k / cfg.n_experts
        if "embed" in keys and cfg.tie_embeddings:
            pass  # embedding counted once; used as both table and head
        total += n
    if shape.kind == "train":
        mult = 6.0
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        mult = 2.0
        tokens = shape.global_batch * shape.seq_len
    else:  # decode: one token per sequence
        mult = 2.0
        tokens = shape.global_batch
    return mult * total * tokens
