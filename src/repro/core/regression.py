"""Performance-regression detection and bisection (paper §4.2).

Mirrors the PyTorch-CI integration TorchBench shipped:

* ``MetricStore`` — per-benchmark baseline metrics (execution time +
  host/device memory, in the paper's four configurations).  A thin view
  over ``repro.runner.results.ResultStore``: the baseline map keeps its
  historical single-JSON format (the store's latest pointer) and every
  ``update`` is also appended to the sibling ``*.jsonl`` run log, so
  baseline history is replayable.
* ``detect`` — flags any benchmark whose metric exceeds baseline by the
  paper's 7% threshold; emits a structured "GitHub issue" record.
* ``bisect_commits`` — the paper's nightly strategy: check only the nightly
  build; if it regressed, binary-search the day's commits by timestamp.
  Commits are modeled as objects with a ``run(benchmark) -> metrics``
  callable so tests can inject real measured regressions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

THRESHOLD = 0.07   # the paper's 7%

METRICS = ("median_us", "host_peak_bytes", "device_bytes_delta")


@dataclasses.dataclass
class Issue:
    benchmark: str
    metric: str
    baseline: float
    observed: float
    increase: float
    culprit: Optional[str] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


_NON_METRIC_KEYS = ("name", "ts", "schema")


class MetricStore:
    def __init__(self, path: str):
        from repro.runner.results import ResultStore
        self.path = path
        self._store = ResultStore(path)

    @property
    def data(self) -> Dict[str, Dict[str, float]]:
        return {name: self._metrics(rec)
                for name, rec in self._store.latest.items()}

    @staticmethod
    def _metrics(rec: Dict[str, Any]) -> Dict[str, float]:
        return {k: v for k, v in rec.items() if k not in _NON_METRIC_KEYS}

    def update(self, benchmark: str, metrics: Dict[str, float]) -> None:
        self._store.append({"name": benchmark,
                            **{k: float(v) for k, v in metrics.items()}})

    def baseline(self, benchmark: str) -> Optional[Dict[str, float]]:
        rec = self._store.latest.get(benchmark)
        return None if rec is None else self._metrics(rec)

    def history(self, benchmark: str):
        """Replay every baseline this benchmark ever recorded (JSONL log)."""
        return self._store.history(benchmark)

    def log_result(self, result) -> dict:
        """Append one full ``RunResult`` to the history log WITHOUT moving
        the latest pointer: a provenance-keyed time-series point
        (``repro.telemetry.history`` groups these into per-environment
        trajectories), not a new baseline — ``data``/``baseline`` views
        stay exactly what ``update`` last wrote."""
        return self._store.append(result, advance_latest=False)


def detect(store: MetricStore, benchmark: str, observed: Dict[str, float],
           *, threshold: float = THRESHOLD,
           metrics: Sequence[str] = METRICS) -> List[Issue]:
    base = store.baseline(benchmark)
    if base is None:
        return []
    issues = []
    for m in metrics:
        b = base.get(m)
        o = observed.get(m)
        if not b or o is None or b <= 0:
            continue
        inc = (o - b) / b
        if inc > threshold:
            issues.append(Issue(benchmark=benchmark, metric=m, baseline=b,
                                observed=o, increase=inc))
    return issues


@dataclasses.dataclass
class Commit:
    sha: str
    timestamp: int
    run: Callable[[str], Dict[str, float]]   # benchmark name -> metrics


def bisect_commits(commits: Sequence[Commit], benchmark: str, metric: str,
                   baseline: float, *, threshold: float = THRESHOLD,
                   trace: Optional[List[str]] = None) -> Optional[Commit]:
    """Binary-search the first commit whose metric regresses past threshold.

    Precondition (the nightly check): the last commit is known-regressed.
    Returns the culprit commit, measuring O(log n) commits.
    """
    commits = sorted(commits, key=lambda c: c.timestamp)
    lo, hi = 0, len(commits) - 1

    def bad(i: int) -> bool:
        obs = commits[i].run(benchmark)[metric]
        is_bad = (obs - baseline) / baseline > threshold
        if trace is not None:
            trace.append(f"measure {commits[i].sha}: {obs:.1f} ({'bad' if is_bad else 'good'})")
        return is_bad

    if not bad(hi):
        return None
    while lo < hi:
        mid = (lo + hi) // 2
        if bad(mid):
            hi = mid
        else:
            lo = mid + 1
    return commits[lo]
