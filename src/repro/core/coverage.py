"""API-surface coverage analysis (the paper's central claim, adapted).

TorchBench's key differentiator is covering 2.3x more of the PyTorch API
surface than MLPerf.  The JAX analogue has two layers:

* **primitive surface** — the set of jaxpr primitives a benchmark traces
  through (jax.lax-level API: what the model code exercises);
* **StableHLO op surface** — the set of ops in the lowered module (what the
  compiler stack must handle).

``coverage_report`` computes per-benchmark sets, the suite union, and the
coverage ratio of the suite vs. any single benchmark / sub-suite — the
quantitative form of the paper's "2.3x MLPerf" comparison (our MLPerf-proxy
is the single-arch {gemma-2b} sub-suite: one dense LM, which is what a small
cross-framework suite typically includes).
"""
from __future__ import annotations

import re
from typing import Any, Callable, Dict, Iterable, List, Set, Tuple

import jax


def jaxpr_primitives(fn: Callable, *args, **kwargs) -> Set[str]:
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    prims: Set[str] = set()

    def walk(jx) -> None:
        for eqn in jx.eqns:
            prims.add(eqn.primitive.name)
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    walk(sub)

    walk(jaxpr.jaxpr)
    return prims


def _sub_jaxprs(v: Any):
    from jax._src.core import ClosedJaxpr, Jaxpr
    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _sub_jaxprs(x)
    elif isinstance(v, dict):
        for x in v.values():
            yield from _sub_jaxprs(x)


_STABLEHLO_OP_RE = re.compile(r"(?:^|\s)(?:%[\w.#]+\s*(?::[\w,\s%]*)?=\s+)?\"?(stablehlo\.[\w.]+|mhlo\.[\w.]+)")


def stablehlo_ops(lowered_text: str) -> Set[str]:
    return {m.group(1).split(".", 1)[1] for m in _STABLEHLO_OP_RE.finditer(lowered_text)}


def benchmark_surfaces(bench, *, batch: int = 2, seq: int = 32,
                       built=None) -> Tuple[Set[str], Set[str]]:
    """-> (jaxpr primitive set, stablehlo op set) for a suite Benchmark.

    ``built`` takes a cached arch build (``suite.Built``) so a runner-driven
    report never re-initialises params just to trace the surface."""
    step, args, _donate = bench.make(batch=batch, seq=seq, built=built)
    prims = jaxpr_primitives(step, *args)
    lowered = jax.jit(step).lower(*args)
    ops = stablehlo_ops(lowered.as_text())
    return prims, ops


def coverage_report(benches: List, *, baseline_archs: Iterable[str] = ("gemma-2b",),
                    batch: int = 2, seq: int = 32, runner=None) -> Dict[str, Any]:
    per: Dict[str, Dict[str, Any]] = {}
    union_prims: Set[str] = set()
    union_ops: Set[str] = set()
    base_prims: Set[str] = set()
    base_ops: Set[str] = set()
    for b in benches:
        built = runner.built_for(b.arch) if runner is not None else None
        prims, ops = benchmark_surfaces(b, batch=batch, seq=seq, built=built)
        per[b.name] = {"n_primitives": len(prims), "n_stablehlo_ops": len(ops),
                       "primitives": sorted(prims), "stablehlo_ops": sorted(ops)}
        union_prims |= prims
        union_ops |= ops
        if b.arch in baseline_archs:
            base_prims |= prims
            base_ops |= ops
    return {
        "per_benchmark": per,
        "suite_primitives": len(union_prims),
        "suite_stablehlo_ops": len(union_ops),
        "baseline_primitives": len(base_prims),
        "baseline_stablehlo_ops": len(base_ops),
        "coverage_x_primitives": (len(union_prims) / len(base_prims)) if base_prims else 0.0,
        "coverage_x_stablehlo": (len(union_ops) / len(base_ops)) if base_ops else 0.0,
        "union_primitives": sorted(union_prims),
    }
