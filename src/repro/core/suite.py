"""The benchmark suite registry (paper Table 1 analogue).

Every entry is a *computation-phase* benchmark (paper §2.2): a pure jitted
step over device-resident inputs — no data loading, no checkpointing inside
the measured region.  Selection criteria metadata mirrors the paper's
(classic / popular / industrial / diverse).

Two tiers per architecture:
  * measured  — reduced config, real wall-clock on the host devices
                (regression CI, compiler comparison);
  * derived   — full assigned config, compile-only dry-run metrics
                (roofline, breakdown, hardware comparison).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, get_arch

CRITERIA = {
    "gemma-2b": "popular",
    "internlm2-20b": "popular",
    "nemotron-4-15b": "industrial",
    "gemma3-12b": "industrial",
    "deepseek-v2-236b": "popular",
    "mixtral-8x7b": "popular",
    "whisper-large-v3": "industrial",
    "paligemma-3b": "industrial",
    "mamba2-2.7b": "classic-successor",
    "recurrentgemma-9b": "diverse",
}

DOMAINS = {a: c.domain for a, c in ARCHS.items()}


@dataclasses.dataclass
class Built:
    """A reusable arch build: config + model + initialised params.

    This is the expensive, task-independent part of ``Benchmark.make`` —
    the BenchmarkRunner caches one per (arch, config-overrides) and shares
    it across every task/batch/seq scenario of that arch.
    """
    cfg: Any
    model: Any
    params: Any


def build_arch(arch: str, overrides: Optional[Dict[str, Any]] = None) -> Built:
    """Build the reduced config, model, and params for one arch."""
    cfg = get_arch(arch).reduced(**(overrides or {}))
    from repro.models import build_model
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return Built(cfg=cfg, model=model, params=params)


@dataclasses.dataclass
class Benchmark:
    name: str                 # e.g. "gemma-2b/train"
    arch: str
    task: str                 # train | infer_prefill | infer_decode
    domain: str
    criteria: str

    def make(self, *, batch: int = 2, seq: int = 64,
             built: Optional[Built] = None,
             overrides: Optional[Dict[str, Any]] = None):
        """-> (step_fn, args, donate_argnums) on the reduced config.

        ``built`` lets a caller supply a cached arch build; ``overrides``
        are reduced-config field overrides (compiler-mode / dtype variants).
        """
        if built is None:
            built = build_arch(self.arch, overrides)
        cfg, model, params = built.cfg, built.model, built.params
        toks = jax.random.randint(jax.random.key(1), (batch, seq), 0, cfg.vocab)
        extra: Dict[str, Any] = {}
        if cfg.family == "encdec":
            extra["frames"] = jax.random.normal(jax.random.key(2), (batch, cfg.enc_seq, cfg.d_model)) * 0.1
        if cfg.family == "vlm":
            extra["patch_embeds"] = jax.random.normal(jax.random.key(2), (batch, cfg.n_prefix, cfg.d_model)) * 0.02
        batch_dict = {"tokens": toks, **extra}

        if self.task == "train":
            from repro.launch.steps import make_train_step
            step, _ = make_train_step(cfg)
            from repro.optim.adamw import adamw_init
            # copy params into the train state: the state may be donated
            # (consumed in-place), and the cached Built must stay valid for
            # the other tasks of this arch.
            p0 = jax.tree_util.tree_map(jnp.copy, params)
            state = (p0, adamw_init(p0))
            return step, (state, batch_dict), (0,)
        if self.task == "infer_prefill":
            cache = model.init_cache(batch, seq + 8 + (cfg.n_prefix or 0))
            return (lambda p, b, c: model.prefill(p, b, c)), (params, batch_dict, cache), (2,)
        if self.task == "infer_decode":
            cache = model.init_cache(batch, seq + 8 + (cfg.n_prefix or 0))
            _, cache = jax.jit(model.prefill)(params, batch_dict, cache)
            tok1 = toks[:, :1]
            return (lambda p, t, c: model.decode_step(p, t, c)), (params, tok1, cache), (2,)
        raise ValueError(self.task)


def get_benchmark(arch: str, task: str) -> Benchmark:
    """Registry lookup: one suite entry by (arch, task)."""
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r} (known: {sorted(ARCHS)})")
    return Benchmark(name=f"{arch}/{task}", arch=arch, task=task,
                     domain=DOMAINS[arch], criteria=CRITERIA.get(arch, "diverse"))


def build_suite(tasks: Tuple[str, ...] = ("train", "infer_prefill", "infer_decode"),
                archs: Optional[List[str]] = None) -> List[Benchmark]:
    return [get_benchmark(arch, task)
            for arch in sorted(archs or ARCHS) for task in tasks]
