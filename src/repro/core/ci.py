"""Nightly CI driver (paper §4.2.1): run the measured suite in all four
configurations (train/inference x with/without donation as the CPU/GPU
proxy), compare against the baseline store, file issues, and bisect.

Execution goes through the unified ``BenchmarkRunner``: pass a shared
runner to reuse arch builds and compiled executables across nights (the
per-night wall time drops to pure measurement after night 0).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

from repro.configs import ARCHS
from repro.core.harness import RegressionHook
from repro.core.regression import Issue, MetricStore, detect
from repro.runner.runner import BenchmarkRunner
from repro.runner.scenario import ScenarioMatrix


@dataclasses.dataclass
class NightlyReport:
    ran: int
    issues: List[Issue]
    wall_s: float

    def to_dict(self) -> dict:
        return {"ran": self.ran, "wall_s": self.wall_s,
                "issues": [i.to_dict() for i in self.issues]}


def run_nightly(store: MetricStore, *, archs: Optional[List[str]] = None,
                tasks=("train", "infer_decode"), runs: int = 5,
                batches=(2,), seqs=(64,),
                update_baseline: bool = False,
                hooks: Optional[Dict[str, RegressionHook]] = None,
                runner: Optional[BenchmarkRunner] = None,
                jobs: Optional[int] = None) -> NightlyReport:
    """``jobs=N`` shards the night's matrix across N worker subprocesses
    (defaults to the runner's own ``jobs`` setting); the persistent pool
    keeps worker caches warm across repeated nights.  ``batches``/``seqs``
    pick the probe cells — noisy shared hosts want small ones, so an
    injected regression dwarfs host jitter.

    Every measured result (ok or error, baseline night or not) is also
    appended to the store's history log as a provenance-stamped
    time-series point (``MetricStore.log_result``) — the raw material
    ``repro.telemetry.history`` turns into per-environment nightly
    trajectories — without touching the baseline pointer."""
    t0 = time.perf_counter()
    issues: List[Issue] = []
    owned = runner is None      # close what we create (shard workers!)
    runner = runner or BenchmarkRunner(runs=runs)
    matrix = ScenarioMatrix(archs=sorted(archs or ARCHS), tasks=tasks,
                            batches=batches, seqs=seqs)
    ran = 0
    try:
        for rr in runner.run_matrix(matrix, hooks=hooks, runs=runs, jobs=jobs):
            ran += 1
            store.log_result(rr)
            if rr.status != "ok":
                issues.append(Issue(benchmark=rr.bench, metric="status",
                                    baseline=0.0, observed=0.0, increase=0.0,
                                    culprit=rr.error))
                continue
            obs = rr.metrics()
            if update_baseline:
                store.update(rr.bench, obs)
            else:
                issues.extend(detect(store, rr.bench, obs))
    finally:
        if owned:
            runner.close()
    return NightlyReport(ran=ran, issues=issues,
                         wall_s=time.perf_counter() - t0)
