"""Nightly CI driver (paper §4.2.1): run the measured suite in all four
configurations (train/inference x with/without donation as the CPU/GPU
proxy), compare against the baseline store, file issues, and bisect.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional

from repro.core.harness import RegressionHook, measure
from repro.core.regression import Issue, MetricStore, detect
from repro.core.suite import Benchmark, build_suite


@dataclasses.dataclass
class NightlyReport:
    ran: int
    issues: List[Issue]
    wall_s: float

    def to_dict(self) -> dict:
        return {"ran": self.ran, "wall_s": self.wall_s,
                "issues": [i.to_dict() for i in self.issues]}


def run_nightly(store: MetricStore, *, archs: Optional[List[str]] = None,
                tasks=("train", "infer_decode"), runs: int = 5,
                update_baseline: bool = False,
                hooks: Optional[Dict[str, RegressionHook]] = None) -> NightlyReport:
    t0 = time.perf_counter()
    issues: List[Issue] = []
    benches = build_suite(tasks=tasks, archs=archs)
    for b in benches:
        step, args, donate = b.make()
        m = measure(b.name, step, args, donate, runs=runs,
                    hook=(hooks or {}).get(b.name))
        obs = {"median_us": m.median_us, "host_peak_bytes": m.host_peak_bytes,
               "device_bytes_delta": m.device_bytes_delta}
        if update_baseline:
            store.update(b.name, obs)
        else:
            issues.extend(detect(store, b.name, obs))
    return NightlyReport(ran=len(benches), issues=issues,
                         wall_s=time.perf_counter() - t0)
