"""Declarative scenario matrix for the unified benchmark runner.

A ``Scenario`` is one fully-specified benchmark execution:

    arch x task x batch x seq x dtype x compiler-mode [x slots x trace]

The bracketed axes exist only under ``task="serve"`` (the
continuous-batching serving workload, ``repro.launch.serve``): ``slots``
is the decode batch width and ``trace`` the deterministic load profile
(``repro.runner.traces``) — a generative profile name or a recorded
spec file (``trace="file:PATH"``).  ``ScenarioMatrix`` expands the cartesian
product and applies the
torchbench-driver selection semantics (regex ``filter`` / ``exclude``
against the scenario name, plus an exact ``skip`` list — matching the
torchdynamo ``iter_models`` front door).
"""
from __future__ import annotations

import dataclasses
import itertools
import re
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

#: single-step tasks measured by the harness ``measure()`` protocol
STEP_TASKS = ("train", "infer_prefill", "infer_decode")

#: all tasks: the step tasks, the continuous-batching serving workload
#: (a whole engine run per cell, ``repro.launch.serve``), the
#: load-generation mode over that same engine (``task="loadgen"``: replay
#: a trace shard at a scaled offered load — N workers x M engines comes
#: free from ordinary matrix dispatch), and the kernel micro-bench cells
#: of the autotuner (``repro.tuning``), whose ``arch`` axis names a
#: tuning candidate instead of a registry arch
TASKS = STEP_TASKS + ("serve", "loadgen", "kernel")

#: the only execution mode for kernel micro-bench cells: a tuning
#: candidate is one jitted ops-layer call — eager dispatch and the
#: model-level reduced-config/donation modes don't apply
KERNEL_MODES = ("jit",)

#: execution modes valid for the serving task: the continuous-batching
#: engine is a jitted decode loop — op-by-op dispatch (eager) and the
#: train-only reduced-config modes don't apply.  "jit_donated" donates
#: the KV cache into each decode step (the production protocol).
SERVE_MODES = ("jit", "jit_donated")

#: compiler-execution modes (paper Figs. 3-4 comparison; see core/compilers.py)
#:   eager        op-by-op dispatch (jax.disable_jit)
#:   jit          whole-step XLA compilation, no buffer donation
#:   jit_donated  + donated state buffers (the standard steady-state protocol)
#:   jit_unrolled layer scan unrolled  (cfg: scan_layers=False)
#:   jit_noremat  no rematerialization (cfg: remat="none")
MODES = ("eager", "jit", "jit_donated", "jit_unrolled", "jit_noremat")

#: reduced-config overrides per mode (applied at arch-build time)
MODE_OVERRIDES: Dict[str, Dict[str, Any]] = {
    "jit_unrolled": {"scan_layers": False},
    "jit_noremat": {"remat": "none"},
}

DTYPES = ("fp32", "bf16")


def dtype_overrides(dtype: str) -> Dict[str, Any]:
    if dtype == "fp32":
        return {}
    if dtype == "bf16":
        import jax.numpy as jnp
        return {"param_dtype": jnp.bfloat16}
    raise ValueError(f"unknown dtype {dtype!r} (known: {DTYPES})")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One cell of the execution matrix (hashable: used as a cache key).

    The serving task carries three extra axes — ``slots`` (decode batch
    rows), ``trace`` (load-profile name, see ``runner/traces.py``) and
    ``admission`` (prefill policy: ``"batched"`` admits every waiting
    request of a wave in one jitted call, ``"single"`` keeps the
    one-prefill-per-request baseline) — which stay inert (0 / "") on
    every other task.  For ``task="serve"`` the shared axes are
    reinterpreted: ``batch`` is the trace's request count and ``seq``
    its prompt length.
    """
    arch: str
    task: str = "train"
    batch: int = 2
    seq: int = 64
    dtype: str = "fp32"
    mode: str = "jit_donated"
    slots: int = 0
    trace: str = ""
    load: float = 0.0
    split: str = ""
    admission: str = ""

    def __post_init__(self):
        if self.task not in TASKS:
            raise ValueError(f"unknown task {self.task!r} (known: {TASKS})")
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r} (known: {MODES})")
        if self.dtype not in DTYPES:
            raise ValueError(f"unknown dtype {self.dtype!r} (known: {DTYPES})")
        if self.task in ("serve", "loadgen"):
            if self.mode not in SERVE_MODES:
                raise ValueError(f"{self.task} supports modes {SERVE_MODES}, "
                                 f"not {self.mode!r}")
            if self.slots == "auto":
                raise ValueError(
                    "slots='auto' is a ScenarioMatrix axis value, resolved "
                    "to a measured slot count at matrix expansion "
                    "(repro.runner.loadgen.auto_slots); a bare Scenario "
                    "needs an int")
            # normalize the serve axes so Scenario(task="serve") works bare
            if self.slots == 0:
                object.__setattr__(self, "slots", 4)
            if not self.trace:
                object.__setattr__(self, "trace", "uniform")
            if not self.admission:
                object.__setattr__(self, "admission", "batched")
            from repro.launch.serve import ADMISSIONS
            if self.admission not in ADMISSIONS:
                raise ValueError(f"unknown admission {self.admission!r} "
                                 f"(known: {ADMISSIONS})")
            if self.slots < 1:
                raise ValueError(f"serve needs slots >= 1, got {self.slots}")
            from repro.runner.traces import (FILE_PREFIX, PROFILES,
                                             PROMPT_PROFILES, split_trace)
            if self.trace.startswith(FILE_PREFIX):
                # a recorded trace-spec file (traces.save_spec); resolved
                # lazily on the host that runs the cell — a missing file
                # becomes that cell's error record, not a matrix error
                if not self.trace[len(FILE_PREFIX):]:
                    raise ValueError("trace='file:' needs a path")
            else:
                arrival, plen = split_trace(self.trace)
                if arrival not in PROFILES:
                    raise ValueError(
                        f"unknown trace profile {arrival!r} (known: "
                        f"{PROFILES}, or 'file:PATH')")
                if plen not in PROMPT_PROFILES:
                    raise ValueError(
                        f"unknown prompt-length profile {plen!r} "
                        f"(known: {PROMPT_PROFILES})")
        if self.task == "loadgen":
            # offered-load multiplier over the trace's native arrival rate;
            # normalize 0 (the inert default) to 1.0 so bare loadgen works
            if self.load == 0.0:
                object.__setattr__(self, "load", 1.0)
            if not self.load > 0:
                raise ValueError(f"loadgen needs load > 0, got {self.load}")
            if self.split and not re.fullmatch(r"\d+/\d+", self.split):
                raise ValueError(
                    f"split must be 'i/n' (e.g. '0/2'), got {self.split!r}")
        elif self.task == "serve":
            if self.load or self.split:
                raise ValueError("load/split are loadgen-only axes "
                                 "(use task='loadgen')")
        elif self.slots or self.trace or self.load or self.split \
                or self.admission:
            raise ValueError(f"slots/trace/load/split/admission are "
                             f"serve/loadgen-only axes (task={self.task!r})")
        if self.task == "kernel":
            if self.mode not in KERNEL_MODES:
                raise ValueError(f"kernel cells support modes {KERNEL_MODES}, "
                                 f"not {self.mode!r}")
            # arch must be a tuning candidate id "kernel@DIMS@PARAMS"
            # (full decode happens lazily on the host that runs the cell,
            # like serve's trace files — an unknown kernel becomes that
            # cell's error record, not a matrix error)
            if self.arch.count("@") != 2:
                raise ValueError(
                    f"kernel cells need a candidate-id arch "
                    f"('kernel@DIMS@PARAMS', see repro.tuning.space), "
                    f"got {self.arch!r}")

    @property
    def bench(self) -> str:
        """The suite-registry benchmark name ("arch/task")."""
        return f"{self.arch}/{self.task}"

    @property
    def name(self) -> str:
        base = f"{self.arch}/{self.task}/b{self.batch}/s{self.seq}/{self.dtype}/{self.mode}"
        # batched admission is the default and stays out of the name, so
        # pre-existing serve/loadgen cell names (and skip lists) are stable
        adm = "/adm-single" if self.admission == "single" else ""
        if self.task == "serve":
            return f"{base}/x{self.slots}/{self.trace}{adm}"
        if self.task == "loadgen":
            name = f"{base}/x{self.slots}/{self.trace}/L{self.load:g}"
            if self.split:
                i, n = self.split.split("/")
                name += f"/{i}of{n}"
            return name + adm
        return base

    def build_overrides(self) -> Dict[str, Any]:
        """Reduced-config overrides implied by (mode, dtype)."""
        return {**dtype_overrides(self.dtype), **MODE_OVERRIDES.get(self.mode, {})}

    def build_key(self) -> Tuple:
        """Cache key for the arch build (model + params) this scenario needs.

        Serve cells extend the key with ("serve", slots): the compiled
        decode executable is shaped by the slot count, so sharding by
        build_key keeps each worker's serve-engine cache hot.  The trace
        profile is deliberately NOT in the key — it changes the replayed
        load, never what gets built or compiled, so traces of one
        (arch, slots) group should land on one worker and share engines.
        """
        base = (self.arch, self.dtype, self.mode in MODE_OVERRIDES and self.mode)
        if self.task in ("serve", "loadgen"):
            # loadgen shares the serve group: same slots -> same compiled
            # decode executable and cached engine on whichever worker runs it
            return base + ("serve", self.slots)
        if self.task == "kernel":
            # one group per candidate: kernel cells share no arch build,
            # so the scheduler is free to place (and steal) them singly —
            # the sweep is embarrassingly parallel
            return ("kernel", self.arch, self.dtype)
        return base

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls) if f.name in d})


def select_scenarios(scenarios: Iterable[Scenario],
                     filter: Sequence[str] = (),
                     exclude: Sequence[str] = ()) -> List[Scenario]:
    """The shared selection semantics: keep iff ANY ``filter`` regex matches
    the scenario name (empty keeps all); drop if ANY ``exclude`` matches."""
    flt = re.compile("|".join(filter)) if filter else None
    exc = re.compile("|".join(exclude)) if exclude else None
    return [s for s in scenarios
            if (flt is None or flt.search(s.name))
            and not (exc is not None and exc.search(s.name))]


@dataclasses.dataclass
class ScenarioMatrix:
    """Cartesian scenario expander with filter/exclude/skip selection.

    * ``filter``  — regex list; a scenario is kept iff ANY regex matches its
      name (empty list keeps everything);
    * ``exclude`` — regex list; a scenario is dropped if ANY regex matches;
    * ``skip``    — exact names: a full scenario name, a benchmark name
      ("arch/task"), or a bare arch (the torchbench SKIP-set idiom for
      known-broken models).

    ``slots`` / ``traces`` / ``admissions`` are the serve-only axes: they
    multiply out only under ``task="serve"`` / ``task="loadgen"`` (every
    other task gets exactly one scenario per (arch, batch, seq, dtype,
    mode) cell, with the serve axes inert); ``loads`` / ``splits``
    additionally multiply out under ``task="loadgen"`` only — an
    offered-load sweep over trace shards.  A slots entry may be the
    string ``"auto"``: it is resolved per arch at expansion time from the
    measured load curve (``repro.runner.loadgen.auto_slots``, reading
    ``results/loadgen_curve.json``), falling back to the default width 4
    when no usable curve exists.  Serve cells silently skip modes outside
    ``SERVE_MODES`` — a matrix mixing ``tasks=("train", "serve")`` with
    ``modes=("eager", ...)`` expands the eager cell for train only.
    ``task="kernel"`` (the autotuner's micro-bench cells, opt-in like
    serve; archs are tuning candidate ids) likewise expands only under
    ``mode="jit"``.

    Expansion (the cartesian product AND the regex selection) is memoized
    on the current field values — ``len(m)`` / ``for s in m`` / nested
    ``m.expand()`` calls pay for one expansion, and editing any field
    invalidates the cache.
    """
    archs: Sequence[str]
    tasks: Sequence[str] = STEP_TASKS     # serve is opt-in: tasks=("serve",)
    batches: Sequence[int] = (2,)
    seqs: Sequence[int] = (64,)
    dtypes: Sequence[str] = ("fp32",)
    modes: Sequence[str] = ("jit_donated",)
    slots: Sequence[int] = (4,)
    traces: Sequence[str] = ("uniform",)
    loads: Sequence[float] = (1.0,)       # loadgen-only: offered-load sweep
    splits: Sequence[str] = ("",)         # loadgen-only: trace shards "i/n"
    admissions: Sequence[str] = ("batched",)  # serve/loadgen admission policy
    filter: Sequence[str] = ()
    exclude: Sequence[str] = ()
    skip: Sequence[str] = ()

    def _fields_key(self) -> Tuple:
        return tuple(tuple(getattr(self, f.name))
                     for f in dataclasses.fields(self))

    def _expanded(self) -> List[Scenario]:
        key = self._fields_key()
        cached = getattr(self, "_expand_cache", None)
        if cached is not None and cached[0] == key:
            return cached[1]
        skip = set(self.skip)
        # per-arch (slots, fallback_reason) for slots="auto"; cells whose
        # resolution fell back carry the reason to the dispatch layer as
        # extra["slots_fallback"] (see BenchmarkRunner._matrix_extras)
        slot_cache: Dict[str, Tuple[int, str]] = {}
        fallbacks: Dict[str, str] = {}

        def resolve_slots(k, arch):
            if k != "auto":
                return k
            if arch not in slot_cache:
                from repro.runner.loadgen import auto_slots_info
                slot_cache[arch] = auto_slots_info(arch)
            return slot_cache[arch][0]

        def mark_auto(s: Scenario, k, arch) -> Scenario:
            if k == "auto" and slot_cache.get(arch, (0, ""))[1]:
                fallbacks[s.name] = slot_cache[arch][1]
            return s

        out: List[Scenario] = []
        for arch, task, batch, seq, dtype, mode in itertools.product(
                self.archs, self.tasks, self.batches, self.seqs,
                self.dtypes, self.modes):
            if task == "serve":
                if mode not in SERVE_MODES:
                    continue      # eager/reduced-config modes are train-only
                cells = [mark_auto(
                             Scenario(arch=arch, task=task, batch=batch,
                                      seq=seq, dtype=dtype, mode=mode,
                                      slots=resolve_slots(k, arch), trace=t,
                                      admission=adm), k, arch)
                         for k, t, adm in itertools.product(
                             self.slots, self.traces, self.admissions)]
            elif task == "loadgen":
                if mode not in SERVE_MODES:
                    continue      # loadgen drives the serve engine: same modes
                cells = [mark_auto(
                             Scenario(arch=arch, task=task, batch=batch,
                                      seq=seq, dtype=dtype, mode=mode,
                                      slots=resolve_slots(k, arch), trace=t,
                                      load=ld, split=sp, admission=adm),
                             k, arch)
                         for k, t, ld, sp, adm in itertools.product(
                             self.slots, self.traces, self.loads, self.splits,
                             self.admissions)]
            elif task == "kernel":
                if mode not in KERNEL_MODES:
                    continue      # kernel micro-bench cells are jit-only
                cells = [Scenario(arch=arch, task=task, batch=batch, seq=seq,
                                  dtype=dtype, mode=mode)]
            else:
                cells = [Scenario(arch=arch, task=task, batch=batch, seq=seq,
                                  dtype=dtype, mode=mode)]
            for s in cells:
                if {s.name, s.bench, s.arch} & skip:
                    continue
                out.append(s)
        out = select_scenarios(out, self.filter, self.exclude)
        names = {s.name for s in out}
        self._fallback_cache = {n: r for n, r in fallbacks.items()
                                if n in names}
        self._expand_cache = (key, out)
        return out

    def slots_fallback(self) -> Dict[str, str]:
        """Scenario name -> fallback reason for every expanded cell whose
        ``slots="auto"`` resolution fell back to the default width (see
        ``loadgen.auto_slots_info``).  Empty when every auto resolution
        used a real measured curve (or no cell asked for auto)."""
        self._expanded()
        return dict(getattr(self, "_fallback_cache", {}))

    def expand(self) -> List[Scenario]:
        return list(self._expanded())   # callers may mutate their copy

    def __iter__(self):
        return iter(self._expanded())

    def __len__(self) -> int:
        return len(self._expanded())
