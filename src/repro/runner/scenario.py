"""Declarative scenario matrix for the unified benchmark runner.

A ``Scenario`` is one fully-specified benchmark execution:

    arch x task x batch x seq x dtype x compiler-mode

``ScenarioMatrix`` expands the cartesian product and applies the
torchbench-driver selection semantics (regex ``filter`` / ``exclude``
against the scenario name, plus an exact ``skip`` list — matching the
torchdynamo ``iter_models`` front door).
"""
from __future__ import annotations

import dataclasses
import itertools
import re
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

TASKS = ("train", "infer_prefill", "infer_decode")

#: compiler-execution modes (paper Figs. 3-4 comparison; see core/compilers.py)
#:   eager        op-by-op dispatch (jax.disable_jit)
#:   jit          whole-step XLA compilation, no buffer donation
#:   jit_donated  + donated state buffers (the standard steady-state protocol)
#:   jit_unrolled layer scan unrolled  (cfg: scan_layers=False)
#:   jit_noremat  no rematerialization (cfg: remat="none")
MODES = ("eager", "jit", "jit_donated", "jit_unrolled", "jit_noremat")

#: reduced-config overrides per mode (applied at arch-build time)
MODE_OVERRIDES: Dict[str, Dict[str, Any]] = {
    "jit_unrolled": {"scan_layers": False},
    "jit_noremat": {"remat": "none"},
}

DTYPES = ("fp32", "bf16")


def dtype_overrides(dtype: str) -> Dict[str, Any]:
    if dtype == "fp32":
        return {}
    if dtype == "bf16":
        import jax.numpy as jnp
        return {"param_dtype": jnp.bfloat16}
    raise ValueError(f"unknown dtype {dtype!r} (known: {DTYPES})")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One cell of the execution matrix (hashable: used as a cache key)."""
    arch: str
    task: str = "train"
    batch: int = 2
    seq: int = 64
    dtype: str = "fp32"
    mode: str = "jit_donated"

    def __post_init__(self):
        if self.task not in TASKS:
            raise ValueError(f"unknown task {self.task!r} (known: {TASKS})")
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r} (known: {MODES})")
        if self.dtype not in DTYPES:
            raise ValueError(f"unknown dtype {self.dtype!r} (known: {DTYPES})")

    @property
    def bench(self) -> str:
        """The suite-registry benchmark name ("arch/task")."""
        return f"{self.arch}/{self.task}"

    @property
    def name(self) -> str:
        return f"{self.arch}/{self.task}/b{self.batch}/s{self.seq}/{self.dtype}/{self.mode}"

    def build_overrides(self) -> Dict[str, Any]:
        """Reduced-config overrides implied by (mode, dtype)."""
        return {**dtype_overrides(self.dtype), **MODE_OVERRIDES.get(self.mode, {})}

    def build_key(self) -> Tuple:
        """Cache key for the arch build (model + params) this scenario needs."""
        return (self.arch, self.dtype, self.mode in MODE_OVERRIDES and self.mode)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls) if f.name in d})


def select_scenarios(scenarios: Iterable[Scenario],
                     filter: Sequence[str] = (),
                     exclude: Sequence[str] = ()) -> List[Scenario]:
    """The shared selection semantics: keep iff ANY ``filter`` regex matches
    the scenario name (empty keeps all); drop if ANY ``exclude`` matches."""
    flt = re.compile("|".join(filter)) if filter else None
    exc = re.compile("|".join(exclude)) if exclude else None
    return [s for s in scenarios
            if (flt is None or flt.search(s.name))
            and not (exc is not None and exc.search(s.name))]


@dataclasses.dataclass
class ScenarioMatrix:
    """Cartesian scenario expander with filter/exclude/skip selection.

    * ``filter``  — regex list; a scenario is kept iff ANY regex matches its
      name (empty list keeps everything);
    * ``exclude`` — regex list; a scenario is dropped if ANY regex matches;
    * ``skip``    — exact names: a full scenario name, a benchmark name
      ("arch/task"), or a bare arch (the torchbench SKIP-set idiom for
      known-broken models).

    Expansion (the cartesian product AND the regex selection) is memoized
    on the current field values — ``len(m)`` / ``for s in m`` / nested
    ``m.expand()`` calls pay for one expansion, and editing any field
    invalidates the cache.
    """
    archs: Sequence[str]
    tasks: Sequence[str] = TASKS
    batches: Sequence[int] = (2,)
    seqs: Sequence[int] = (64,)
    dtypes: Sequence[str] = ("fp32",)
    modes: Sequence[str] = ("jit_donated",)
    filter: Sequence[str] = ()
    exclude: Sequence[str] = ()
    skip: Sequence[str] = ()

    def _fields_key(self) -> Tuple:
        return tuple(tuple(getattr(self, f.name))
                     for f in dataclasses.fields(self))

    def _expanded(self) -> List[Scenario]:
        key = self._fields_key()
        cached = getattr(self, "_expand_cache", None)
        if cached is not None and cached[0] == key:
            return cached[1]
        skip = set(self.skip)
        out: List[Scenario] = []
        for arch, task, batch, seq, dtype, mode in itertools.product(
                self.archs, self.tasks, self.batches, self.seqs,
                self.dtypes, self.modes):
            s = Scenario(arch=arch, task=task, batch=batch, seq=seq,
                         dtype=dtype, mode=mode)
            if {s.name, s.bench, s.arch} & skip:
                continue
            out.append(s)
        out = select_scenarios(out, self.filter, self.exclude)
        self._expand_cache = (key, out)
        return out

    def expand(self) -> List[Scenario]:
        return list(self._expanded())   # callers may mutate their copy

    def __iter__(self):
        return iter(self._expanded())

    def __len__(self) -> int:
        return len(self._expanded())
