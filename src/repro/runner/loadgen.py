"""Load-generation helpers for ``task="loadgen"`` cells.

A loadgen cell replays a (shard of a) deterministic trace against the
continuous-batching serve engine at a scaled *offered load*, so a matrix
sweeping ``loads=(0.5, 1.0, 2.0, 4.0)`` measures a TTFT/p99-vs-load
curve; sweeping ``splits=("0/2", "1/2")`` across cluster workers replays
trace shards against as many engines as the pool has workers — the
N-workers-x-M-engines fleet measurement, dispatched through the same
JSONL protocol as every other cell.

Both transforms act on the generated ``Request`` list, never on the
spec: the prompt tokens stay a pure function of (trace spec, params), so
shard digests are stable and a sharded run's union equals the unsharded
trace.

``find_knee`` post-processes a measured curve: offered load is swept up,
throughput saturates, and the knee is the last point whose marginal
throughput gain over the previous point still exceeds ~5% — past it the
engine only queues (TTFT and p99 climb with no tok/s to show for it).

``auto_slots`` closes the loop: it turns a persisted measured curve
(``benchmarks/loadgen_curve.py`` -> ``results/loadgen_curve.json``) into a
slot count, so ``ScenarioMatrix(slots=("auto",))`` picks the decode batch
width from the measured knee instead of by hand.
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.runner.traces import Request
from repro.telemetry.spans import warn

#: marginal-throughput-gain threshold that defines saturation
KNEE_GAIN = 0.05

#: schema tag of results/loadgen_curve.json consumed by auto_slots (bumped
#: whenever benchmarks/loadgen_curve.py changes the file layout — an old
#: file is then *stale* and auto_slots falls back to the default)
CURVE_SCHEMA = 2

#: environment override for the curve location (tests, ad-hoc curves)
CURVE_PATH_ENV = "REPRO_LOADGEN_CURVE"

#: fallback slot count when no usable curve exists (the Scenario default)
DEFAULT_SLOTS = 4

#: autoscaler bounds and headroom: the measured width is scaled by
#: HEADROOM/knee_load and clamped to [1, AUTO_SLOTS_MAX]
AUTO_SLOTS_MAX = 16
AUTO_SLOTS_HEADROOM = 1.25


def auto_slots_info(arch: str, curve_path: Optional[str] = None,
                    default: int = DEFAULT_SLOTS) -> Tuple[int, str]:
    """``(slots, fallback_reason)`` for ``arch`` from the measured curve.

    The reason is ``""`` when the knee policy actually ran, else one of
    ``"missing"`` (no curve file), ``"unreadable"`` (exists but not valid
    JSON), ``"stale-schema"`` (written by an older
    ``benchmarks/loadgen_curve.py`` layout), ``"foreign-arch"`` (curve
    measured for a different arch) or ``"degenerate-curve"`` (no usable
    knee/slot numbers).  Every fallback emits one structured
    ``telemetry.warn("slots_fallback", ...)`` line — a stale curve
    silently shaping a matrix is exactly the failure this surfaces.
    """
    path = (curve_path or os.environ.get(CURVE_PATH_ENV)
            or os.path.join("results", "loadgen_curve.json"))

    def fallback(reason: str) -> Tuple[int, str]:
        warn("slots_fallback", arch=arch, path=path, reason=reason,
             slots=default)
        return default, reason

    try:
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
    except OSError:
        return fallback("missing")
    except ValueError:
        return fallback("unreadable")
    if not isinstance(data, dict) or data.get("schema") != CURVE_SCHEMA:
        return fallback("stale-schema")
    if data.get("arch") != arch:
        return fallback("foreign-arch")
    knee = ((data.get("curves") or {}).get("batched") or {}).get("knee") or {}
    knee_load = knee.get("knee_load") or 0.0
    measured = data.get("slots") or 0
    if knee_load <= 0 or measured <= 0:
        return fallback("degenerate-curve")
    target = measured * AUTO_SLOTS_HEADROOM / knee_load
    return max(1, min(AUTO_SLOTS_MAX, int(math.ceil(target)))), ""


def auto_slots(arch: str, curve_path: Optional[str] = None,
               default: int = DEFAULT_SLOTS) -> int:
    """Knee-driven slot count for ``arch`` from the measured load curve.

    Reads ``results/loadgen_curve.json`` (or ``$REPRO_LOADGEN_CURVE`` /
    ``curve_path``), written by ``benchmarks/loadgen_curve.py`` with the
    slot count it measured at and the batched-admission saturation knee.
    The policy scales the measured width to the knee: a knee at offered
    load 1.0 means the width just keeps up with the native arrival rate —
    keep it (times ``AUTO_SLOTS_HEADROOM``); a knee below 1.0 means the
    engine saturates under native load — scale up proportionally; a knee
    well above 1.0 means the width is oversized — scale down.

    Falls back to ``default`` on a missing file, unreadable JSON, a stale
    schema tag, or a curve measured for a different arch — a wrong curve
    must never silently shape another arch's matrix.  The fallback is
    *not* silent: ``auto_slots_info`` (this function's implementation)
    names the reason in a structured warning, and ``ScenarioMatrix``
    forwards it to the affected cells as ``extra["slots_fallback"]``.
    """
    return auto_slots_info(arch, curve_path, default)[0]


def parse_split(split: str) -> Tuple[int, int]:
    """``"i/n"`` -> (i, n), validated (0 <= i < n, n >= 1)."""
    try:
        i_s, n_s = split.split("/")
        i, n = int(i_s), int(n_s)
    except ValueError:
        raise ValueError(f"split must be 'i/n', got {split!r}") from None
    if n < 1 or not (0 <= i < n):
        raise ValueError(f"split {split!r} needs 0 <= i < n")
    return i, n


def shard_requests(requests: List[Request], split: str) -> List[Request]:
    """Shard ``i/n``: keep every n-th request by rid order, starting at i.

    Deterministic in the request ids alone (not list order, not arrival
    times), so the same split expression names the same shard on every
    worker, and the n shards partition the trace exactly.
    """
    if not split:
        return requests
    i, n = parse_split(split)
    by_rid = sorted(requests, key=lambda r: r.rid)
    keep = {r.rid for j, r in enumerate(by_rid) if j % n == i}
    return [r for r in requests if r.rid in keep]


def scale_arrivals(requests: List[Request], load: float) -> List[Request]:
    """Offered load: compress (load > 1) or stretch (load < 1) the virtual
    arrival clock — ``arrival' = floor(arrival / load)``.  load=1.0 is the
    identity; the transform mutates arrival steps in place and returns the
    list for chaining.  Tokens are unaffected (arrivals only schedule slot
    admission; each request's output depends only on its own prompt)."""
    if load <= 0:
        raise ValueError(f"load must be > 0, got {load}")
    if load != 1.0:
        for r in requests:
            r.arrival_step = int(math.floor(r.arrival_step / load))
    return requests


def find_knee(points: Sequence[Dict[str, float]]) -> Dict[str, float]:
    """The saturation knee of a measured load curve.

    ``points`` are dicts with at least ``load`` and ``tok_per_s`` (one per
    swept offered load, any order).  Returns ``{"knee_load", "knee_tok_s"}``
    — the highest offered load whose step still bought a >= ``KNEE_GAIN``
    marginal throughput gain (scanning all steps, so one noisy mid-curve
    plateau doesn't end the search early).  With 0 or 1 points, or when
    no step ever bought throughput, the first point is the knee.
    """
    pts = sorted(points, key=lambda p: p["load"])
    if not pts:
        return {"knee_load": 0.0, "knee_tok_s": 0.0}
    knee = pts[0]
    for prev, cur in zip(pts, pts[1:]):
        base = prev["tok_per_s"]
        gain = (cur["tok_per_s"] - base) / base if base > 0 else 0.0
        if gain >= KNEE_GAIN:
            knee = cur
    return {"knee_load": float(knee["load"]),
            "knee_tok_s": float(knee["tok_per_s"])}
