"""Shared latency-distribution helpers for serving metrics.

Production users compare serving systems by latency *distributions* —
TTFT and per-token p50/p95/p99 — not mean step time (paper §2.2 framing;
the inference-framework comparisons in PAPERS.md all report tails).  This
module is the one place those percentiles are computed, so the serve
engine, the runner's RunResult extras, and the benchmark tables can never
disagree on interpolation semantics.

``percentile`` uses linear interpolation between closest ranks (the
numpy default), implemented in plain Python so it is trivially auditable
and exact for the small sample counts a serve cell produces.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence

#: the quantiles every latency summary reports (ISSUE: p50/p95/p99)
QUANTILES = (50.0, 95.0, 99.0)


def percentile(values: Sequence[float], q: float) -> float:
    """The ``q``-th percentile (0..100) of ``values``, linear interpolation.

    Handles any sample count >= 1: a single sample is every percentile of
    itself; even counts interpolate between the two middle ranks for p50.
    Raises ``ValueError`` on an empty sample or ``q`` outside [0, 100].
    """
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q} outside [0, 100]")
    vals = sorted(float(v) for v in values)
    if not vals:
        raise ValueError("percentile of an empty sample")
    if len(vals) == 1:
        return vals[0]
    rank = (q / 100.0) * (len(vals) - 1)
    lo = int(rank)
    frac = rank - lo
    if frac == 0.0 or lo + 1 >= len(vals):
        return vals[lo]
    return vals[lo] * (1.0 - frac) + vals[lo + 1] * frac


def latency_summary(values: Iterable[float], prefix: str,
                    scale: float = 1.0) -> Dict[str, float]:
    """p50/p95/p99 of ``values`` as ``{prefix}_p50`` ... keys.

    ``scale`` converts units on the way out (e.g. ``1e6`` seconds -> us).
    Empty samples produce an empty dict — callers treat the keys as
    optional, matching the RunResult extra-key contract.
    """
    vals: List[float] = [float(v) * scale for v in values]
    if not vals:
        return {}
    return {f"{prefix}_p{int(q)}": percentile(vals, q) for q in QUANTILES}
