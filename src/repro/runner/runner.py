"""The unified BenchmarkRunner: one execution path for the suite tables,
figures, and regression CI.

Responsibilities (previously hand-rolled per ``benchmarks/*`` script):

* resolve ``Scenario``s against the suite registry (``core.suite``);
* reuse expensive state across scenarios —
  - **arch builds** (config + model + initialised params) are cached per
    (arch, dtype, mode-overrides) and shared across every task/batch/seq
    of that arch;
  - **compiled executables** (jitted step + live threaded args) are cached
    per scenario, so re-measuring the same cell (regression CI, bisection)
    never re-jits or re-compiles;
* optional **subprocess isolation** per scenario (fault containment for
  crashy cells, the ``launch/dryrun`` idiom) via ``repro.runner.worker``;
* emit a versioned ``RunResult`` per execution into a ``ResultStore``;
* own the **derived** (compile-only dry-run) path with the same store-level
  caching, so figures that share a cell pay for one subprocess, not N.

``runner.stats`` counts builds/compiles/cache hits — the reuse speedup is
benchmarked by ``benchmarks/runner_bench.py``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.harness import (Measurement, RegressionHook, measure,
                                measure_eager, prepare)
from repro.core.suite import Benchmark, Built, build_arch, get_benchmark
from repro.fleet.metrics import registry as metrics_registry
from repro.profiler.attribution import attribute, cost_for_executable
from repro.profiler.timeline import Timeline, device_memory_stats
from repro.runner.latency import percentile
from repro.runner.pool import ShardScheduler, _subprocess_env
from repro.runner.traces import cache_len_bound, spec_for_scenario
from repro.runner.traces import generate as generate_trace
from repro.runner.results import ResultStore, RunResult
from repro.runner.scenario import Scenario, ScenarioMatrix, select_scenarios
from repro.telemetry.provenance import stamp as stamp_provenance
from repro.telemetry.spans import NULL_TRACER, Tracer, group_label


@dataclasses.dataclass
class RunnerStats:
    model_builds: int = 0
    model_cache_hits: int = 0
    executable_builds: int = 0
    executable_cache_hits: int = 0
    dryrun_runs: int = 0
    dryrun_cache_hits: int = 0
    scenarios_run: int = 0
    errors: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def merge(self, other) -> "RunnerStats":
        """Field-wise add another stats snapshot (RunnerStats or dict) —
        how worker-subprocess counts become visible in the parent."""
        d = other.to_dict() if isinstance(other, RunnerStats) else dict(other or {})
        for f in dataclasses.fields(self):
            if d.get(f.name):
                setattr(self, f.name, getattr(self, f.name) + int(d[f.name]))
        return self


@dataclasses.dataclass
class _ExecEntry:
    jitted: Optional[Callable]      # None for eager mode
    step: Callable
    args: Tuple                     # threaded, donation-valid arguments
    donate: Tuple[int, ...]


class BenchmarkRunner:
    def __init__(self, store: Optional[ResultStore] = None, *,
                 runs: int = 5, warmup: int = 1, compile_warmup: int = 3,
                 reuse: bool = True, isolate: bool = False, jobs: int = 0,
                 measure_fence: bool = True, profile: bool = False,
                 cluster: str = "", steal: bool = True,
                 tracer: Optional[Tracer] = None,
                 coverage: bool = False):
        self.store = store
        self.runs = runs
        self.warmup = warmup
        # extra warmup steps after a fresh compile: the first post-compile
        # iterations run well above steady state (thread-pool/allocator
        # churn), which would skew a fresh measurement vs a cache-hit
        # re-measure and break baseline comparability in regression CI
        self.compile_warmup = compile_warmup
        self.reuse = reuse
        self.isolate = isolate
        # default shard count for run_matrix (CLI --jobs); <=1 means the
        # serial in-process path.  measure_fence serializes the workers'
        # timed loops (comparable per-cell numbers, what regression CI
        # wants); throughput-only sweeps may turn it off
        self.jobs = jobs
        self.measure_fence = measure_fence
        # default cluster spec for run_matrix (CLI --cluster): "local:N"
        # spawns N localhost socket workers, "HOST:PORT" binds the
        # coordinator there for externally-launched workers (see
        # repro.runner.cluster); "" means no cluster dispatch.  steal
        # picks dynamic group stealing vs static LPT for the single-host
        # pool (the cluster is always dynamic)
        self.cluster = cluster
        self.steal = steal
        # measured profiling (src/repro/profiler/): per-step phase
        # timelines + op-class attribution under extra["prof_*"]; per-call
        # override via run(..., profile=...)
        self.profile = profile
        # span tracing (src/repro/telemetry/): an enabled Tracer records
        # matrix -> group -> cell -> phase spans and stitches worker-side
        # spans under their dispatch span via the job protocol; the
        # default NULL_TRACER makes every span site a cheap no-op
        self.tracer = tracer or NULL_TRACER
        # API-surface coverage annotations (opt-in, serial in-process step
        # cells only): trace each scenario's step once through
        # core.coverage.jaxpr_primitives and attach extra["cov_*"] counts;
        # the process-wide union feeds the metrics-snapshot gauge.  The
        # trace is cached per scenario, so re-measures pay nothing.
        self.coverage = coverage
        self._cov_cache: Dict[Scenario, frozenset] = {}
        self._cov_union: set = set()
        # session-level scenario selection (the CLI --filter/--exclude
        # regexes), applied on top of each matrix's own selection
        self.default_filter: Tuple[str, ...] = ()
        self.default_exclude: Tuple[str, ...] = ()
        # force recompilation of cached dry-run cells (CLI --refresh)
        self.dryrun_refresh = False
        self.stats = RunnerStats()
        self._built: Dict[Tuple, Built] = {}
        self._execs: Dict[Scenario, _ExecEntry] = {}
        # serve engines (compiled prefill/decode + slot state) cached per
        # (build_key, max_len) — the serving analogue of _execs
        self._serve_engines: Dict[Tuple, Any] = {}
        self._dryrun_mem: Dict[str, dict] = {}
        # profiled cells' HLO op-class costs, keyed like the executable
        # they describe (scenario for step cells, engine key for serve) —
        # the attribution AOT compile is paid once per executable, not per
        # profiled re-measure
        self._prof_costs: Dict[Any, Any] = {}
        self._pool: Optional[ShardScheduler] = None
        self._cluster: Optional[Any] = None   # ClusterScheduler, lazy

    def close(self) -> None:
        """Shut down the persistent shard workers and the cluster
        coordinator + its local workers (no-op when serial)."""
        if self._pool is not None:
            self._pool.close()
            self._pool = None
        if self._cluster is not None:
            self._cluster.close()
            self._cluster = None

    def cluster_worker_pids(self) -> List[int]:
        """PIDs of the locally-spawned cluster workers (``cluster=
        "local:N"``), empty when no cluster is active or it binds for
        external workers — the smoke gate's no-orphans check."""
        return [] if self._cluster is None else self._cluster.worker_pids()

    def worker_pids(self) -> List[int]:
        """PIDs of every worker subprocess this runner has live — the
        ``--jobs`` shard pool plus local cluster workers.  The no-orphans
        gate: after ``close()`` each of these must be dead."""
        pids: List[int] = []
        if self._pool is not None:
            pids.extend(self._pool.worker_pids())
        pids.extend(self.cluster_worker_pids())
        return pids

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    # ---- build / executable caches -------------------------------------

    def built_for(self, arch: str, *, dtype: str = "fp32",
                  mode: str = "jit_donated") -> Built:
        """The cached arch build for (arch, dtype, mode-overrides)."""
        sc = Scenario(arch=arch, dtype=dtype, mode=mode)
        key = sc.build_key()
        if key in self._built:
            self.stats.model_cache_hits += 1
            return self._built[key]
        built = build_arch(arch, sc.build_overrides())
        self.stats.model_builds += 1
        if self.reuse:
            self._built[key] = built
        return built

    def _resolve(self, scenario: Scenario) -> Tuple[_ExecEntry, Dict[str, bool]]:
        if self.reuse and scenario in self._execs:
            self.stats.executable_cache_hits += 1
            return self._execs[scenario], {"model_reused": True,
                                           "executable_reused": True}
        hits0 = self.stats.model_cache_hits
        built = self.built_for(scenario.arch, dtype=scenario.dtype,
                               mode=scenario.mode)
        bench = get_benchmark(scenario.arch, scenario.task)
        step, args, donate = bench.make(batch=scenario.batch, seq=scenario.seq,
                                        built=built)
        if scenario.mode == "eager":
            entry = _ExecEntry(jitted=None, step=step, args=args, donate=())
        else:
            d = donate if scenario.mode == "jit_donated" else ()
            entry = _ExecEntry(jitted=prepare(step, d), step=step,
                               args=args, donate=d)
            self.stats.executable_builds += 1
        if self.reuse:
            self._execs[scenario] = entry
        return entry, {"model_reused": self.stats.model_cache_hits > hits0,
                       "executable_reused": False}

    # ---- measured path --------------------------------------------------

    def run(self, scenario: Scenario, *, hook: Optional[RegressionHook] = None,
            runs: Optional[int] = None, warmup: Optional[int] = None,
            record: bool = True, profile: Optional[bool] = None,
            extra: Optional[Dict[str, Any]] = None) -> RunResult:
        """Execute one scenario and return its RunResult (never raises for
        benchmark failures — they come back as status="error" records).

        ``task="serve"`` cells run the continuous-batching engine over the
        scenario's trace instead of the ``measure()`` step protocol;
        ``runs``/``warmup`` don't apply there (the trace defines the work).

        ``profile`` (default: the runner's ``profile`` setting) captures a
        per-step phase timeline during the SAME timed loop and attributes
        it over HLO op classes (``repro.profiler``); the profile lands
        under ``extra["prof_*"]``.  Eager cells can't profile (no compiled
        module, synchronous dispatch) and record ``prof_skipped`` instead.

        ``extra`` is merged into the result's extras (ok or error) —
        the dispatch layers use it to attach matrix-expansion context
        (e.g. ``slots_fallback``) to the record before it is stored.
        """
        prof = self.profile if profile is None else profile
        if self.isolate:
            return self._run_isolated(scenario, hook=hook, runs=runs,
                                      warmup=warmup, record=record,
                                      profile=prof, extra=extra)
        if scenario.task in ("serve", "loadgen"):
            return self._run_serve(scenario, hook=hook, record=record,
                                   profile=prof, extra=extra)
        if scenario.task == "kernel":
            return self._run_kernel(scenario, hook=hook, runs=runs,
                                    warmup=warmup, record=record,
                                    profile=prof, extra=extra)
        t0 = time.perf_counter()
        self.stats.scenarios_run += 1
        tr = self.tracer
        phase_log: Optional[List[Tuple[float, float]]] = None
        with tr.span("cell:" + scenario.name, kind="cell",
                     cell=scenario.name) as cs:
            try:
                with tr.span("build", kind="phase"):
                    entry, cache = self._resolve(scenario)
                # trace coverage before the measure: donated buffers are
                # still live here (the jaxpr trace is abstract, but fresh
                # args keep it valid on every mode)
                cov = self._coverage_extra(scenario, entry) \
                    if self.coverage else None
                if scenario.mode == "eager":
                    with tr.span("measure", kind="phase"):
                        m = measure_eager(scenario.name, entry.step,
                                          entry.args,
                                          runs=max(2, (runs or self.runs) // 2),
                                          hook=hook)
                else:
                    if prof:
                        phase_log = []
                    events: Optional[list] = [] if tr.enabled else None
                    final_args: List[Tuple] = []
                    wu = self.warmup if warmup is None else warmup
                    if not cache.get("executable_reused"):
                        wu += self.compile_warmup
                    m = measure(scenario.name, entry.step, entry.args,
                                entry.donate,
                                runs=runs or self.runs, warmup=wu,
                                hook=hook, jitted=entry.jitted,
                                final_args=final_args, phase_log=phase_log,
                                events=events)
                    if self.reuse and final_args:
                        # donated buffers were consumed: keep the threaded
                        # args so the cached executable stays callable next
                        # time
                        entry.args = final_args[0]
                    if events:
                        for ph, tw0, tw1 in events:
                            tr.add(ph, ts=tw0, dur_s=tw1 - tw0, parent=cs)
                rr = RunResult.from_measurement(
                    scenario, m, wall_s=time.perf_counter() - t0, cache=cache)
                if cache.get("executable_reused"):
                    # nothing compiled on a cache hit; measure()'s first call
                    # timed an ordinary step, which is not a compile time
                    rr.compile_us = 0.0
                if cov:
                    rr.extra.update(cov)
                if prof:
                    if scenario.mode == "eager":
                        rr.extra["prof_skipped"] = "eager"
                    else:
                        with tr.span("attribute", kind="phase"):
                            rr.extra.update(self._profile_extra(
                                scenario, phase_log,
                                lambda: entry.jitted.lower(*entry.args)))
            except Exception as e:  # noqa: BLE001 — fault containment per cell
                self.stats.errors += 1
                # a failed measure may have consumed donated buffers
                # mid-loop: evict the cached executable so the next run
                # rebuilds cleanly
                self._execs.pop(scenario, None)
                rr = RunResult.from_error(scenario, f"{type(e).__name__}: {e}",
                                          wall_s=time.perf_counter() - t0)
                cs.set(error=rr.error)
            cs.set(status=rr.status)
        return self._finalize(rr, cs, extra, record)

    def _finalize(self, rr: RunResult, cell_span: Any,
                  extra: Optional[Dict[str, Any]], record: bool) -> RunResult:
        """Shared result epilogue: merge dispatch-provided extras, stamp
        span ids + provenance, record."""
        if extra:
            rr.extra.update(extra)
        tr = self.tracer
        if tr.enabled and getattr(cell_span, "span_id", ""):
            rr.extra["span_trace"] = tr.trace_id
            rr.extra["span_cell"] = cell_span.span_id
        stamp_provenance(rr)
        metrics_registry().record_result(rr)
        if record and self.store is not None:
            self.store.append(rr)
        return rr

    def _coverage_extra(self, scenario: Scenario,
                        entry: _ExecEntry) -> Dict[str, int]:
        """Per-scenario jaxpr-primitive counts (``extra["cov_*"]``) and the
        process-union gauge — the cheap seed for the coverage loop."""
        prims = self._cov_cache.get(scenario)
        if prims is None:
            from repro.core.coverage import jaxpr_primitives
            try:
                prims = frozenset(jaxpr_primitives(entry.step, *entry.args))
            except Exception:   # noqa: BLE001 — coverage is advisory
                prims = frozenset()
            self._cov_cache[scenario] = prims
        new = prims - self._cov_union
        self._cov_union |= prims
        metrics_registry().set_gauge("fleet_cov_union_primitives",
                                     len(self._cov_union))
        return {"cov_primitives": len(prims), "cov_new_primitives": len(new)}

    # ---- kernel micro-bench path (the autotuner's cells) -----------------

    def _run_kernel(self, scenario: Scenario, *,
                    hook: Optional[RegressionHook] = None,
                    runs: Optional[int] = None,
                    warmup: Optional[int] = None,
                    record: bool = True, profile: bool = False,
                    extra: Optional[Dict[str, Any]] = None) -> RunResult:
        """One tuning candidate (``task="kernel"``): decode the candidate
        id from the ``arch`` axis (``repro.tuning.space``), jit its
        ops-layer call, and measure it under the standard ``measure()``
        protocol — so a sweep's cells dispatch, shard, fence, and record
        exactly like model cells.  The candidate's identity lands under
        the well-known ``tuning_*`` extras (``runner/results.py``).

        The compiled candidate is cached in ``self._execs`` like a model
        executable: re-measuring a candidate (regression CI, the pool's
        fenced re-run) hits the cache, and the pool worker's ledger
        accounting stays correct."""
        from repro.tuning import space as tuning_space
        t0 = time.perf_counter()
        self.stats.scenarios_run += 1
        tr = self.tracer
        phase_log: Optional[List[Tuple[float, float]]] = None
        with tr.span("cell:" + scenario.name, kind="cell",
                     cell=scenario.name) as cs:
            try:
                with tr.span("build", kind="phase"):
                    case, params = tuning_space.parse_candidate(
                        scenario.arch, dtype=scenario.dtype)
                    if self.reuse and scenario in self._execs:
                        self.stats.executable_cache_hits += 1
                        entry = self._execs[scenario]
                        cache = {"model_reused": True,
                                 "executable_reused": True}
                    else:
                        step, args = tuning_space.bench_callable(case, params)
                        entry = _ExecEntry(jitted=prepare(step), step=step,
                                           args=args, donate=())
                        self.stats.executable_builds += 1
                        if self.reuse:
                            self._execs[scenario] = entry
                        cache = {"model_reused": False,
                                 "executable_reused": False}
                if profile:
                    phase_log = []
                events: Optional[list] = [] if tr.enabled else None
                wu = self.warmup if warmup is None else warmup
                if not cache["executable_reused"]:
                    wu += self.compile_warmup
                m = measure(scenario.name, entry.step, entry.args,
                            entry.donate,
                            runs=runs or self.runs, warmup=wu, hook=hook,
                            jitted=entry.jitted, phase_log=phase_log,
                            events=events)
                if events:
                    for ph, tw0, tw1 in events:
                        tr.add(ph, ts=tw0, dur_s=tw1 - tw0, parent=cs)
                rr = RunResult.from_measurement(
                    scenario, m, wall_s=time.perf_counter() - t0, cache=cache,
                    extra=tuning_space.result_extra(case, params))
                if cache["executable_reused"]:
                    rr.compile_us = 0.0
                if profile:
                    with tr.span("attribute", kind="phase"):
                        rr.extra.update(self._profile_extra(
                            scenario, phase_log,
                            lambda: entry.jitted.lower(*entry.args)))
            except Exception as e:  # noqa: BLE001 — fault containment per cell
                self.stats.errors += 1
                self._execs.pop(scenario, None)
                rr = RunResult.from_error(scenario, f"{type(e).__name__}: {e}",
                                          wall_s=time.perf_counter() - t0)
                cs.set(error=rr.error)
            cs.set(status=rr.status)
        return self._finalize(rr, cs, extra, record)

    # ---- measured profiling ---------------------------------------------

    def _profile_extra(self, cost_key: Any, phase_log, lower, *,
                       kind: str = "step", wall_s: float = 0.0) -> Dict[str, Any]:
        """The ``extra["prof_*"]`` payload for one profiled execution:
        timeline from the measured ``phase_log`` plus op-class attribution
        from the executable's (cached) HLO cost.  Attribution failures
        degrade to a timeline-only profile with ``prof_error`` — profiling
        must never turn a good measurement into an error record."""
        tl = Timeline.from_phase_log(phase_log or [], kind=kind,
                                     wall_s=wall_s,
                                     memory=device_memory_stats())
        extra = tl.to_extra()
        try:
            cost = self._prof_costs.get(cost_key)
            if cost is None:
                cost = cost_for_executable(lower)
                if self.reuse:
                    self._prof_costs[cost_key] = cost
        except Exception as e:  # noqa: BLE001 — profile degrades, cell stays ok
            from repro.core.hloanalysis import HloCost
            cost = HloCost()
            extra["prof_error"] = f"{type(e).__name__}: {e}"
        extra.update(attribute(tl, cost).to_extra())
        return extra

    # ---- serving path ----------------------------------------------------

    def _serve_engine_for(self, scenario: Scenario, built: Built,
                          max_len: int) -> Tuple[Any, bool]:
        """The cached continuous-batching engine for a serve cell; returns
        (engine, reused).  Keyed by (build_key, mode, max_len, admission):
        the compiled decode step is shaped by (slots, max_len), its
        donation by mode — build_key alone can't tell jit from jit_donated
        — and the admission policy picks the engine's prefill protocol
        (batched wave vs per-request), while trace profiles of one shape
        share the engine (the trace never affects compilation)."""
        from repro.launch.serve import ServeEngine
        key = (scenario.build_key(), scenario.mode, max_len,
               scenario.admission)
        if self.reuse and key in self._serve_engines:
            self.stats.executable_cache_hits += 1
            return self._serve_engines[key], True
        engine = ServeEngine(built, slots=scenario.slots, max_len=max_len,
                             donate=scenario.mode == "jit_donated",
                             admission=scenario.admission)
        self.stats.executable_builds += 1
        if self.reuse:
            self._serve_engines[key] = engine
        return engine, False

    def _run_serve(self, scenario: Scenario, *,
                   hook: Optional[RegressionHook] = None,
                   record: bool = True, profile: bool = False,
                   extra: Optional[Dict[str, Any]] = None) -> RunResult:
        """One serving or loadgen cell: regenerate the scenario's trace,
        replay it through the (cached) engine, and fold the latency
        distribution into a RunResult — ``median_us``/``mean_us``/
        ``p10_us``/``p90_us`` are per-token decode latencies, and the
        TTFT/per-token p50/p95/p99 + throughput land under the well-known
        ``extra`` keys documented in ``runner/results.py``.

        ``task="loadgen"`` is serve under transformed load: the trace is
        sharded (``scenario.split``) then its virtual arrival clock scaled
        by the offered load (``scenario.load``) before replay — the cell
        additionally records ``offered_load``/``split`` so a swept matrix
        yields a latency-vs-load curve.

        ``profile=True`` records a per-decode-step phase timeline during
        the measured replay and attributes it over the decode step's HLO
        op classes; replay wall time outside decode steps (admission,
        prefill, queue management) shows up as the profile's idle share."""
        from repro.launch.serve import summarize_metrics
        from repro.runner.loadgen import scale_arrivals, shard_requests
        from repro.runner.traces import capture_spec
        t0 = time.perf_counter()
        self.stats.scenarios_run += 1
        tr = self.tracer
        key = None
        with tr.span("cell:" + scenario.name, kind="cell",
                     cell=scenario.name) as cs:
            try:
                with tr.span("build", kind="phase"):
                    spec = spec_for_scenario(scenario)
                    hits0 = self.stats.model_cache_hits
                    built = self.built_for(scenario.arch,
                                           dtype=scenario.dtype,
                                           mode=scenario.mode)
                    model_reused = self.stats.model_cache_hits > hits0
                    reqs = generate_trace(spec, vocab=built.cfg.vocab)
                    if scenario.task == "loadgen":
                        reqs = scale_arrivals(
                            shard_requests(reqs, scenario.split),
                            scenario.load)
                        if not reqs:
                            raise ValueError(
                                f"split {scenario.split!r} leaves an empty "
                                f"shard of {spec.requests} requests")
                    # sized for the whole replay: per-slot positions mean a
                    # row never needs more than its own prompt + budget
                    # (+ vlm prefix)
                    prefix = (built.cfg.n_prefix
                              if built.cfg.family == "vlm" else 0)
                    max_len = cache_len_bound(reqs, prefix=prefix)
                    key = (scenario.build_key(), scenario.mode, max_len,
                           scenario.admission)
                    engine, engine_reused = self._serve_engine_for(
                        scenario, built, max_len)
                cache = {"model_reused": model_reused or engine_reused,
                         "executable_reused": engine_reused}
                compile_us = 0.0
                if not engine_reused:
                    # untimed warm replay on a fresh engine: pays the
                    # prefill/decode jit (recorded as compile_us, like a
                    # step cell's first measure call) so the measured
                    # replay's latency samples — and its TTFTs — are
                    # steady-state and stay comparable with cache-hit
                    # re-measures
                    with tr.span("compile", kind="phase"):
                        tc = time.perf_counter()
                        engine.run(reqs)
                        compile_us = (time.perf_counter() - tc) * 1e6
                phase_log: Optional[List[Tuple[float, float]]] = \
                    [] if profile else None
                span_log: Optional[list] = [] if tr.enabled else None
                with tr.span("measure", kind="phase") as ms:
                    out = engine.run(reqs, hook=hook, phase_log=phase_log,
                                     span_log=span_log)
                self._add_serve_spans(tr, ms, span_log)
                if out["admit_new_shapes"]:
                    # this replay's queue dynamics reached prefill bucket
                    # shapes no earlier replay on this engine had compiled
                    # (batched admission shapes are load-dependent), so it
                    # paid those jits inside the timed window: fold its
                    # wall into compile_us and re-measure steady-state —
                    # the rerun is shape-complete because the replay is
                    # deterministic
                    compile_us += out["wall_s"] * 1e6
                    phase_log = [] if profile else None
                    span_log = [] if tr.enabled else None
                    with tr.span("measure", kind="phase",
                                 remeasure=True) as ms:
                        out = engine.run(reqs, hook=hook,
                                         phase_log=phase_log,
                                         span_log=span_log)
                    self._add_serve_spans(tr, ms, span_log)
                sx = summarize_metrics(out)
                plens = sorted(len(r.prompt) for r in reqs)
                sx.update(trace=scenario.trace, slots=scenario.slots,
                          tokens=out["tokens_by_rid"],
                          prompt_len_p50=percentile(plens, 50),
                          prompt_len_p95=percentile(plens, 95))
                # capture provenance: the replayed trace as a
                # save_spec-schema payload, so any recorded serve/loadgen
                # run is replayable via trace="file:PATH" (load sharding/
                # scaling already applied)
                sx["capture"] = dataclasses.asdict(capture_spec(
                    reqs, seed=spec.seed, source=f"capture:{scenario.name}"))
                if scenario.task == "loadgen":
                    sx.update(offered_load=scenario.load,
                              split=scenario.split)
                if profile:
                    with tr.span("attribute", kind="phase"):
                        sx.update(self._profile_extra(
                            ("serve-cost",) + key, phase_log,
                            engine.lowered_decode, kind="decode_step",
                            wall_s=out["wall_s"]))
                lats = out["tok_lat_s"] or out["ttft_s"]
                rr = RunResult(
                    name=scenario.name, bench=scenario.bench,
                    arch=scenario.arch,
                    task=scenario.task, batch=scenario.batch,
                    seq=scenario.seq,
                    dtype=scenario.dtype, mode=scenario.mode, status="ok",
                    median_us=percentile(lats, 50) * 1e6,
                    mean_us=sum(lats) / len(lats) * 1e6,
                    p10_us=percentile(lats, 10) * 1e6,
                    p90_us=percentile(lats, 90) * 1e6,
                    compile_us=compile_us, runs=out["requests"],
                    wall_s=time.perf_counter() - t0, cache=cache,
                    ts=time.time(), extra=sx)
            except Exception as e:  # noqa: BLE001 — fault containment per cell
                self.stats.errors += 1
                # the engine's donated KV cache may be half-consumed:
                # evict it
                if key is not None:
                    self._serve_engines.pop(key, None)
                rr = RunResult.from_error(scenario, f"{type(e).__name__}: {e}",
                                          wall_s=time.perf_counter() - t0)
                cs.set(error=rr.error)
            cs.set(status=rr.status)
        return self._finalize(rr, cs, extra, record)

    @staticmethod
    def _add_serve_spans(tr: Tracer, parent: Any, span_log: Optional[list],
                         cap: int = 64) -> None:
        """Attach the engine's admit-wave / decode-step wall intervals as
        children of the serve cell's measure span.  Decode steps beyond
        *cap* are elided (count + total time noted on the parent) so a
        long replay doesn't bloat the trace."""
        if not span_log:
            return
        shown = dropped = 0
        dropped_s = 0.0
        for ev in span_log:
            name, tw0, tw1 = ev[0], ev[1], ev[2]
            attrs = ev[3] if len(ev) > 3 and isinstance(ev[3], dict) else {}
            if name == "decode_step":
                if shown >= cap:
                    dropped += 1
                    dropped_s += tw1 - tw0
                    continue
                shown += 1
            tr.add(name, ts=tw0, dur_s=tw1 - tw0, parent=parent,
                   kind="engine", **attrs)
        if dropped:
            parent.set(decode_steps_dropped=dropped,
                       decode_steps_dropped_s=round(dropped_s, 6))

    def select(self, matrix: ScenarioMatrix) -> List[Scenario]:
        """Matrix expansion with the runner's session-level filter/exclude
        applied after the matrix's own selection (both must pass)."""
        return select_scenarios(matrix.expand(),
                                self.default_filter, self.default_exclude)

    def run_matrix(self, matrix: ScenarioMatrix, *,
                   hooks: Optional[Dict[str, RegressionHook]] = None,
                   runs: Optional[int] = None,
                   warmup: Optional[int] = None,
                   jobs: Optional[int] = None,
                   cluster: Optional[str] = None,
                   profile: Optional[bool] = None) -> List[RunResult]:
        """Run every scenario of the matrix; hooks are keyed by benchmark
        name ("arch/task") or full scenario name.

        ``jobs=N`` (default: the runner's ``jobs`` setting) shards the
        selected scenarios across N persistent worker subprocesses, grouped
        by build_key so each worker keeps its caches hot (see
        ``repro.runner.pool``); results come back in matrix order with
        ``extra["shard"]`` set.  ``jobs<=1`` is the serial in-process path.
        ``cluster`` (default: the runner's setting; overrides ``jobs``)
        dispatches across socket-connected workers instead —
        ``"local:N"`` spins up N localhost worker subprocesses,
        ``"HOST:PORT"`` binds a coordinator for workers launched elsewhere
        with ``worker --connect`` (see ``repro.runner.cluster``); results
        carry ``extra["host"]``.  ``profile`` (default: the runner's
        setting) profiles every cell — under sharded/cluster dispatch the
        flag rides in each worker job, so profiled sweeps dispatch exactly
        like unprofiled ones.

        An enabled ``tracer`` records ONE trace per call regardless of
        transport: a matrix root span, a group span per build key, and a
        cell span per scenario with its phase children — worker-side
        spans ride back in the job protocol and stitch under their
        dispatch span.
        """
        scenarios = self.select(matrix)
        jobs = self.jobs if jobs is None else jobs
        cluster = self.cluster if cluster is None else cluster
        extras = self._matrix_extras(matrix, scenarios)
        tr = self.tracer
        if tr.enabled:
            tr.begin_trace()
        transport = ("cluster:" + cluster if cluster and scenarios else
                     f"jobs={jobs}" if jobs and jobs > 1 and scenarios else
                     "serial")
        with tr.span("matrix", kind="matrix", cells=len(scenarios),
                     transport=transport) as root:
            if cluster and scenarios:
                return self._run_clustered(scenarios, hooks=hooks, runs=runs,
                                           warmup=warmup, cluster=cluster,
                                           profile=profile,
                                           trace_parent=root, extras=extras)
            if jobs and jobs > 1 and scenarios:
                # even a single selected cell goes through the pool: the
                # caller opted into worker fault containment and shard
                # metadata
                return self._run_sharded(scenarios, hooks=hooks, runs=runs,
                                         warmup=warmup, jobs=jobs,
                                         profile=profile,
                                         trace_parent=root, extras=extras)
            out = []
            for sc in scenarios:
                hook = (hooks or {}).get(sc.name) or (hooks or {}).get(sc.bench)
                out.append(self.run(sc, hook=hook, runs=runs, warmup=warmup,
                                    profile=profile,
                                    extra=extras.get(sc.name)))
            if tr.enabled:
                self._stitch_serial_groups(tr, scenarios, out, root)
            return out

    @staticmethod
    def _matrix_extras(matrix: ScenarioMatrix,
                       scenarios: List[Scenario]) -> Dict[str, Dict[str, Any]]:
        """Per-cell extras derived from matrix expansion (currently the
        ``slots_fallback`` staleness marker from ``slots="auto"``
        resolution) — attached to each result before it is recorded,
        on every transport."""
        fb = getattr(matrix, "slots_fallback", None)
        fb = fb() if callable(fb) else {}
        if not fb:
            return {}
        return {sc.name: {"slots_fallback": fb[sc.name]}
                for sc in scenarios if sc.name in fb}

    @staticmethod
    def _stitch_serial_groups(tr: Tracer, scenarios: List[Scenario],
                              results: List[RunResult], root: Any) -> None:
        """Serial cells interleave across build keys in matrix order, so
        their group spans are synthesized after the loop from the
        recorded cell spans (pool/cluster dispatchers open group spans
        live instead)."""
        by_key: Dict[Tuple, List[str]] = {}
        for sc, rr in zip(scenarios, results):
            sid = rr.extra.get("span_cell")
            if sid and tr.find(sid) is not None:
                by_key.setdefault(sc.build_key(), []).append(sid)
        for bkey, ids in by_key.items():
            tr.group("group:" + group_label(bkey), ids, parent=root)

    def _run_sharded(self, scenarios: List[Scenario], *,
                     hooks: Optional[Dict[str, RegressionHook]],
                     runs: Optional[int], warmup: Optional[int],
                     jobs: int,
                     profile: Optional[bool] = None,
                     trace_parent: Any = None,
                     extras: Optional[Dict[str, Dict[str, Any]]] = None
                     ) -> List[RunResult]:
        """Dispatch a scenario batch to the persistent shard pool; the pool
        (and its workers' warm caches) lives until ``close()``."""
        if self._pool is not None and self._pool.jobs != jobs:
            self._pool.close()
            self._pool = None
        if self._pool is None:
            self._pool = ShardScheduler(jobs, runs=self.runs,
                                        warmup=self.warmup,
                                        compile_warmup=self.compile_warmup,
                                        reuse=self.reuse,
                                        measure_fence=self.measure_fence)
        record = self.store.append if self.store is not None else None
        prof = self.profile if profile is None else profile
        results, run_stats = self._pool.run(scenarios, hooks=hooks,
                                            runs=runs, warmup=warmup,
                                            profile=prof,
                                            on_result=record,
                                            steal=self.steal,
                                            tracer=self.tracer,
                                            trace_parent=trace_parent,
                                            extras=extras)
        self.stats.merge(run_stats)
        return results

    def _run_clustered(self, scenarios: List[Scenario], *,
                       hooks: Optional[Dict[str, RegressionHook]],
                       runs: Optional[int], warmup: Optional[int],
                       cluster: str,
                       profile: Optional[bool] = None,
                       trace_parent: Any = None,
                       extras: Optional[Dict[str, Dict[str, Any]]] = None
                       ) -> List[RunResult]:
        """Dispatch a scenario batch to the cluster coordinator; the
        coordinator — its worker connections, and for ``local:N`` the
        spawned worker subprocesses with their warm caches — lives until
        ``close()``, like the single-host pool."""
        from repro.runner.cluster import ClusterScheduler
        if self._cluster is not None and self._cluster.spec != cluster:
            self._cluster.close()
            self._cluster = None
        if self._cluster is None:
            self._cluster = ClusterScheduler(
                cluster, runs=self.runs, warmup=self.warmup,
                compile_warmup=self.compile_warmup, reuse=self.reuse,
                measure_fence=self.measure_fence)
        record = self.store.append if self.store is not None else None
        prof = self.profile if profile is None else profile
        results, run_stats = self._cluster.run(scenarios, hooks=hooks,
                                               runs=runs, warmup=warmup,
                                               profile=prof,
                                               on_result=record,
                                               tracer=self.tracer,
                                               trace_parent=trace_parent,
                                               extras=extras)
        self.stats.merge(run_stats)
        return results

    # ---- subprocess isolation -------------------------------------------

    def _run_isolated(self, scenario: Scenario, *,
                      hook: Optional[RegressionHook] = None,
                      runs: Optional[int] = None,
                      warmup: Optional[int] = None,
                      record: bool = True, timeout: int = 1200,
                      profile: bool = False,
                      extra: Optional[Dict[str, Any]] = None) -> RunResult:
        """One scenario in its own interpreter: a crash (OOM, segfault in a
        kernel, ...) becomes an error record instead of killing the sweep.

        The full measurement config (runs/warmup/compile-warmup/reuse) is
        forwarded so the isolated measurement follows the same protocol as
        the in-process path (comparable as a regression baseline), and the
        worker's ``RunnerStats`` come back in the payload and are merged —
        out-of-process builds/compiles count like in-process ones."""
        t0 = time.perf_counter()
        fd, out = tempfile.mkstemp(suffix=".json", prefix="repro_runner_")
        os.close(fd)
        cmd = [sys.executable, "-m", "repro.runner.worker",
               "--scenario", json.dumps(scenario.to_dict()),
               "--runs", str(runs or self.runs),
               "--warmup", str(self.warmup if warmup is None else warmup),
               "--compile-warmup", str(self.compile_warmup),
               "--json", out]
        if not self.reuse:
            cmd.append("--no-reuse")
        if profile:
            cmd.append("--profile")
        if hook is not None:
            cmd += ["--slowdown-s", str(hook.slowdown_s),
                    "--leak-bytes", str(hook.leak_bytes)]
        try:
            r = subprocess.run(cmd, env=_subprocess_env(), capture_output=True,
                               text=True, timeout=timeout)
            if r.returncode == 0 and os.path.getsize(out):
                with open(out) as f:
                    payload = json.load(f)
                rr = RunResult.from_dict(payload["result"])
                worker_stats = payload.get("stats") or {}
                rr.wall_s = time.perf_counter() - t0
                rr.extra["isolated"] = True
                rr.extra["worker_stats"] = worker_stats
                self.stats.merge(worker_stats)
            else:
                self.stats.scenarios_run += 1
                self.stats.errors += 1
                rr = RunResult.from_error(
                    scenario, f"worker exit {r.returncode}: {r.stderr[-500:]}",
                    wall_s=time.perf_counter() - t0)
        except subprocess.TimeoutExpired:
            self.stats.scenarios_run += 1
            self.stats.errors += 1
            rr = RunResult.from_error(scenario, f"worker timeout after {timeout}s",
                                      wall_s=time.perf_counter() - t0)
        finally:
            if os.path.exists(out):
                os.remove(out)
        if extra:
            rr.extra.update(extra)
        # the worker stamped its own provenance (correct host/backend);
        # setdefault only fills locally-created error records
        stamp_provenance(rr)
        # single-shot worker: its registry dies with it, so the parent
        # counts the execution (unlike the pool/cluster delta-merge)
        metrics_registry().record_result(rr)
        if record and self.store is not None:
            self.store.append(rr)
        return rr

    # ---- derived (compile-only dry-run) path -----------------------------

    def run_dryrun(self, arch: str, shape: str, *, multi_pod: bool = False,
                   rules: Optional[dict] = None, refresh: bool = False,
                   timeout: int = 1200) -> Dict[str, Any]:
        """One dry-run cell (compile-only, subprocess so THIS process keeps
        its single CPU device), cached in the ResultStore: figures sharing a
        cell pay for one compile across tables AND across invocations.

        The cache key is (arch, shape, mesh) only — after config/rule/model
        changes pass ``refresh=True`` (CLI: ``benchmarks.run --refresh``)
        to recompile.  Rule-overridden cells are never cached."""
        name = f"{arch}/{shape}/{'2x16x16' if multi_pod else '16x16'}/dryrun"
        if not (refresh or self.dryrun_refresh or rules):
            cached = self._dryrun_mem.get(name)
            if cached is None and self.store is not None:
                rec = self.store.latest.get(name)
                if rec and rec.get("status") == "ok" and rec.get("extra", {}).get("cell"):
                    cached = rec["extra"]["cell"]
            if cached is not None:
                self.stats.dryrun_cache_hits += 1
                self._dryrun_mem[name] = cached
                return cached
        self.stats.dryrun_runs += 1
        cell = dryrun_cell_subprocess(arch, shape, multi_pod=multi_pod,
                                      rules=rules, timeout=timeout)
        if rules:
            return cell   # rule-varied cells don't overwrite the canonical cache
        self._dryrun_mem[name] = cell
        if self.store is not None:
            status = "skipped" if "skipped" in cell else \
                     ("error" if "error" in cell else "ok")
            self.store.append(stamp_provenance(RunResult(
                name=name, bench=f"{arch}/{shape}", arch=arch, task="train",
                batch=0, seq=0, dtype="fp32", mode="jit_donated",
                status=status, error=cell.get("error"),
                ts=time.time(), extra={"cell": cell, "derived": True})))
        return cell

    def dryrun_cells(self, cells: Sequence[Tuple[str, str]], *,
                     multi_pod: bool = False) -> List[Dict[str, Any]]:
        return [self.run_dryrun(a, s, multi_pod=multi_pod) for a, s in cells]


def dryrun_cell_subprocess(arch: str, shape: str, *, multi_pod: bool = False,
                           rules: Optional[dict] = None,
                           timeout: int = 1200) -> Dict[str, Any]:
    """Compile one (arch x shape) cell in a subprocess and return its record
    (the dry-run forces 512 host devices, which must not leak into us)."""
    fd, out = tempfile.mkstemp(suffix=".json", prefix="repro_dryrun_")
    os.close(fd)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--json", out]
    if multi_pod:
        cmd.append("--multi-pod")
    if rules:
        cmd += ["--rules", json.dumps(rules)]
    try:
        r = subprocess.run(cmd, env=_subprocess_env(), capture_output=True,
                           text=True, timeout=timeout)
        if r.returncode != 0:
            raise RuntimeError(f"dryrun {arch}x{shape} failed:\n{r.stderr[-2000:]}")
        with open(out) as f:
            return json.load(f)[0]
    finally:
        if os.path.exists(out):
            os.remove(out)
