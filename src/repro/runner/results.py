"""Persistent, schema'd benchmark results.

``RunResult`` — one versioned record per scenario execution (schema v1):

    schema       int    record version (this file: SCHEMA_VERSION)
    name         str    scenario id "arch/task/bN/sN/dtype/mode"
    bench        str    suite benchmark name "arch/task"
    arch/task/batch/seq/dtype/mode   the scenario axes
    status       str    "ok" | "error" | "skipped"
    median_us, mean_us, p10_us, p90_us, compile_us   timing (us)
    host_peak_bytes, device_bytes_delta              memory
    runs         int    measured iterations (after warmup)
    wall_s       float  end-to-end wall time incl. build/compile
    cache        dict   {"model_reused": bool, "executable_reused": bool}
    ts           float  unix timestamp
    error        str?   exception text when status == "error"
    extra        dict   free-form payload (dry-run cells, hook params, ...)

Well-known ``extra`` keys written by the runner (still schema v1 — readers
must tolerate their absence):

    extra["isolated"]      bool   measured in a worker subprocess
                                  (``isolate=True``, sharded, or cluster
                                  dispatch)
    extra["shard"]         int    worker index that ran this scenario under
                                  sharded dispatch (``run_matrix(jobs=N)``)
    extra["host"]          str    registered host id of the cluster worker
                                  that ran this scenario under cluster
                                  dispatch (``run_matrix(cluster=...)``,
                                  see ``repro.runner.cluster``): the
                                  worker's ``--host`` flag, ``localK`` for
                                  ``cluster="local:N"`` workers, or
                                  ``<hostname>-<pid>`` by default.  Also
                                  set on the error record of a cell that
                                  was in flight on a worker that died.
                                  Cluster workers' build/compile counters
                                  are delta-merged into the parent
                                  ``RunnerStats`` exactly like pool
                                  workers' (no per-record snapshot).
    extra["worker_stats"]  dict   the isolated worker's ``RunnerStats``
                                  snapshot (model builds / compiles that
                                  happened out-of-process)

Provenance stamps (``repro.telemetry.provenance``, still schema v1): the
runner stamps EVERY record — ok and error, every transport — with the
environment that produced it, so a result history can be grouped into
comparable series (``repro.telemetry.history`` keys baselines and drift
detection on them).  Worker-side stamps win (correct host); dispatchers
only backstop records workers never produced (dead-worker errors):

    extra["prov_commit"]   str    git commit sha of the benchmarked tree
                                  (``$REPRO_COMMIT`` overrides when the
                                  deployed tree is not a git checkout)
    extra["prov_dirty"]    bool   the working tree had uncommitted changes
    extra["prov_backend"]  str    ``jax.default_backend()`` ("cpu"/"gpu"/
                                  "tpu") of the measuring process
    extra["prov_host"]     str    hostname of the measuring process
    extra["prov_jax"]      str    jax version
    extra["prov_python"]   str    python version

Span-tracing stamps (``repro.telemetry.spans``; present only when the
run was traced — a ``Tracer`` was passed to the runner or
``benchmarks.run --trace-out`` was used):

    extra["span_trace"]    str    trace id of the ``run_matrix`` call this
                                  record was measured in (one id per call,
                                  shared across coordinator and workers)
    extra["span_cell"]     str    span id of the cell span that timed this
                                  record (worker-side under pool/cluster
                                  dispatch)
    extra["span_dispatch"] str    span id of the dispatcher-side dispatch
                                  slot (pool/cluster transports only) —
                                  the worker's cell spans nest under it in
                                  the exported Chrome trace

Matrix-expansion annotations:

    extra["slots_fallback"] str   the cell's ``slots="auto"`` resolution
                                  fell back to the default width; the
                                  value names why ("missing" |
                                  "unreadable" | "stale-schema" |
                                  "foreign-arch" | "degenerate-curve",
                                  see ``runner/loadgen.auto_slots_info``).
                                  Absent when a real measured curve was
                                  used.

Fleet / coverage annotations (still schema v1; the fleet perf-CI
service ``src/repro/fleet/`` and the coverage-enabled runner):

    extra["fleet_tick"]    int    the fleet scheduler tick that measured
                                  this history point (``FleetScheduler``
                                  stamps every record it logs into the
                                  store, so a trajectory series can be
                                  re-cut by tick as well as by time)
    extra["cov_primitives"]     int   distinct jaxpr primitives the cell's
                                  step traces to (``core/coverage``,
                                  abstract trace — cached per scenario;
                                  only on step cells of a
                                  ``BenchmarkRunner(coverage=True)``)
    extra["cov_new_primitives"] int   of those, how many this cell added
                                  to the runner's suite-union frontier
                                  (first cell of a sweep pays the whole
                                  union; later cells count marginal
                                  coverage — the paper's breadth metric
                                  as a per-cell number).  The running
                                  union size is the
                                  ``fleet_cov_union_primitives`` gauge.

Every execution also feeds the process-wide metrics registry
(``repro.fleet.metrics``; counters/gauges/histograms, exported as the
``{"fleet_metrics": 1, "ts", "counters", "gauges", "histograms"}``
snapshot in ``results/fleet_status.json`` and as Prometheus text in
``results/fleet_metrics.prom``).  Registry counters are *execution*
counts, not record counts — the pool's measurement fence warms cells
with an unfenced pass, so ``fleet_cells_total`` can exceed the number
of records; histograms cross process boundaries as count/sum only
(percentiles are always measuring-process-local).

Serving cells (``task="serve"``, the continuous-batching engine in
``repro.launch.serve``) additionally carry the latency-distribution
metrics production users compare (all latencies in **microseconds**,
computed by ``repro.runner.latency``); for these records the core timing
fields ``median_us``/``mean_us``/``p10_us``/``p90_us`` hold *per-token
decode latencies* (not step times) and ``runs`` is the request count:

    extra["ttft_p50"|"ttft_p95"|"ttft_p99"]          time-to-first-token
                                  percentiles: request became admissible
                                  -> first (prefill) token emitted (a
                                  fresh engine's prefill/decode jit is
                                  paid by an untimed warm replay and
                                  recorded in ``compile_us``, so these
                                  are steady-state like step timings)
    extra["tok_lat_p50"|"tok_lat_p95"|"tok_lat_p99"] per-token decode
                                  latency percentiles across all tokens
    extra["tok_per_s"]     float  generated-token throughput (incl. first
                                  tokens) over the trace replay wall time
    extra["decode_steps"]  int    batched decode steps executed
    extra["queue_depth_mean"|"queue_depth_max"]      arrived-but-unadmitted
                                  requests sampled once per decode step
    extra["trace"]         str    load-profile name (runner/traces.py)
    extra["slots"]         int    decode batch width (continuous batching);
                                  always the resolved integer — a matrix
                                  ``slots=("auto",)`` axis entry is turned
                                  into a measured width at expansion time
                                  (``runner/loadgen.auto_slots``)
    extra["tokens"]        list   generated tokens per request, rid order —
                                  the serial-vs-sharded determinism witness
    extra["tokens_digest"] str    sha256 of extra["tokens"]
    extra["prompt_len_p50"|"prompt_len_p95"]         prompt-length
                                  percentiles of the replayed trace (mixed
                                  lengths per batch are first-class: the
                                  KV cache keeps per-slot position vectors)
    extra["capture"]       dict   capture provenance: the replayed trace
                                  as a ``traces.save_spec``-schema payload
                                  (per-request lengths/arrivals/budgets
                                  pinned, ``source="capture:<cell name>"``)
                                  — write it to a file and replay it with
                                  ``trace="file:PATH"`` for a byte-
                                  identical regression run
    extra["admission"]     str    prefill policy: "batched" (one jitted
                                  prefill per admission wave, bucketed
                                  padded shapes) or "single" (the
                                  one-prefill-per-request baseline)
    extra["admit_calls"]   int    jitted prefill calls this replay made —
                                  batched admission's headline saving over
                                  one-call-per-request
    extra["admit_batch_mean"|"admit_batch_max"]      requests admitted per
                                  prefill call (mean/max over the replay);
                                  mean 1.0 under admission="single"
    extra["admit_shapes"]  list   distinct compiled (rows, padded_len)
                                  prefill shapes over the ENGINE lifetime
                                  (cumulative across replays, mirroring
                                  the jit cache) — bounded by the bucket
                                  grid, not by distinct prompt lengths

Loadgen cells (``task="loadgen"``: a serve replay under transformed
load — trace sharded by ``scenario.split``, virtual arrival clock scaled
by ``scenario.load``; see ``repro.runner.loadgen``) carry all the serve
keys above plus:

    extra["offered_load"]  float  the arrival-clock multiplier this cell
                                  replayed at (>1 compresses arrivals)
    extra["split"]         str    trace shard "i/n" ("" = whole trace)

and a swept curve's summary record (``benchmarks/loadgen_curve.py``)
carries the post-processed saturation knee:

    extra["knee_load"|"knee_tok_s"]   highest offered load that still
                                  bought >= ~5% marginal throughput, and
                                  the throughput measured there

Kernel micro-bench cells (``task="kernel"``, the autotuner's candidate
timings — ``repro.tuning``; still schema v1): the scenario ``arch`` axis
holds a tuning *candidate id* (``kernel@DIMS@PARAMS``, e.g.
``flash_attention@B2,S128,H4,K2,D64@block_q=64,block_k=128``) instead of
a registry arch, ``mode`` is always ``"jit"``, ``batch``/``seq`` mirror
the case's B/S dims, and the timing fields follow the normal step-cell
``measure()`` protocol (median-of-N over the jitted ops-layer call,
compile excluded).  Their decoded identity rides in ``extra``:

    extra["tuning_kernel"]    str   kernel name ("flash_attention" |
                                  "rglru" | "ssd")
    extra["tuning_case"]      str   case id "kernel@DIMS" — the (kernel,
                                  shape) tuning problem this candidate
                                  belongs to
    extra["tuning_signature"] str   the tuning-DB shape signature (what
                                  ``kernels/*/ops.py`` recomputes at
                                  trace time, e.g. "Sq128,Sk128,D64")
    extra["tuning_params"]    dict  this candidate's launch parameters
                                  (e.g. {"block_q": 64, "block_k": 128})
    extra["tuning_default"]   bool  this candidate IS the ops-layer
                                  default (always swept, so a recorded
                                  winner is never slower than it)

Profiled cells (``run(..., profile=True)`` / ``benchmarks.run --profile``;
the measured profiling subsystem ``src/repro/profiler/``) additionally
carry the phase timeline + op-class attribution (still schema v1; eager
cells record only ``extra["prof_skipped"]="eager"`` — no compiled module):

    extra["prof_kind"]     str    "step" (train/infer cells: one sample per
                                  measured iteration) | "decode_step"
                                  (serve: one per batched decode step)
    extra["prof_steps"]    int    profiled samples
    extra["prof_timeline"] list   [dispatch_us, device_us] per sample,
                                  capped at profiler.TIMELINE_CAP (128)
    extra["prof_dispatch_us_mean"|"prof_device_us_mean"]   phase means:
                                  host dispatch (jitted call returning) vs
                                  device execution (block_until_ready wait)
    extra["prof_idle_us"]  float  serve only: measured replay wall outside
                                  decode steps (admission, prefill, queue)
    extra["prof_frac_compute"|"prof_frac_memory"|"prof_frac_collective"
         |"prof_frac_dispatch"|"prof_frac_idle"]
                           float  measured time decomposition; the five
                                  fractions sum to 1.0 per cell (device
                                  time is split over HLO op classes by
                                  their roofline weights, then into
                                  compute vs memory per class; device time
                                  the HLO costs can't explain lands in
                                  idle, never vanishes)
    extra["prof_class_us"|"prof_class_frac"]   dict   measured device time
                                  per op class (hloanalysis.OP_CLASSES:
                                  matmul/attention/collective/elementwise/
                                  other), us and fraction-of-device-time
    extra["prof_flops"|"prof_bytes"|"prof_collective_bytes"]   the
                                  trip-count-aware HLO costs backing the
                                  attribution
    extra["prof_bound_us"] float  the cell's analytic roofline device
                                  bound (modeled hardware)
    extra["prof_util"]     float  bound/measured device time — roofline-
                                  utilization proxy; compare across cells
                                  of one sweep, not across hosts
    extra["prof_device_peak_bytes"|"prof_device_bytes_in_use"]   backend
                                  memory stats, present only when the
                                  device exposes memory_stats() (TPU/GPU;
                                  absent on CPU)
    extra["prof_error"]    str    attribution failed (timeline-only
                                  profile); the cell's status stays "ok"
    extra["prof_skipped"]  str    why no profile was recorded ("eager")

``ResultStore`` — the persistence layer:

    * an append-only JSONL run log (full history, one record per line);
    * an atomically-rewritten latest-pointer JSON mapping name -> record.

Two layouts: a directory (``<root>/runs.jsonl`` + ``<root>/latest.json``,
the runner's layout) or a ``*.json`` file path (the latest pointer IS that
file, log beside it as ``*.jsonl`` — the layout ``core.regression.MetricStore``
sits on, keeping its historical single-file format readable).

Concurrency: one store file set may be appended to by several processes at
once (the sharded ``run_matrix`` path records from parent threads while CI
sweeps in other processes share the same store).  Log appends are a single
``O_APPEND`` write, the latest pointer is advanced under an exclusive lock
file with a read-merge-replace cycle, and ``history()`` skips (and counts)
torn lines left by a writer killed mid-append.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Any, Dict, Iterator, List, Optional

try:
    import fcntl
except ImportError:          # non-POSIX: fall back to best-effort updates
    fcntl = None  # type: ignore[assignment]

SCHEMA_VERSION = 1


@dataclasses.dataclass
class RunResult:
    name: str
    bench: str
    arch: str
    task: str
    batch: int
    seq: int
    dtype: str
    mode: str
    status: str = "ok"
    median_us: float = 0.0
    mean_us: float = 0.0
    p10_us: float = 0.0
    p90_us: float = 0.0
    compile_us: float = 0.0
    host_peak_bytes: int = 0
    device_bytes_delta: int = 0
    runs: int = 0
    wall_s: float = 0.0
    cache: Dict[str, bool] = dataclasses.field(default_factory=dict)
    ts: float = 0.0
    error: Optional[str] = None
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schema: int = SCHEMA_VERSION

    @classmethod
    def from_measurement(cls, scenario, m, *, wall_s: float = 0.0,
                         cache: Optional[Dict[str, bool]] = None,
                         extra: Optional[Dict[str, Any]] = None) -> "RunResult":
        return cls(name=scenario.name, bench=scenario.bench,
                   arch=scenario.arch, task=scenario.task,
                   batch=scenario.batch, seq=scenario.seq,
                   dtype=scenario.dtype, mode=scenario.mode,
                   status="ok", median_us=m.median_us, mean_us=m.mean_us,
                   p10_us=m.p10_us, p90_us=m.p90_us, compile_us=m.compile_us,
                   host_peak_bytes=m.host_peak_bytes,
                   device_bytes_delta=m.device_bytes_delta, runs=m.runs,
                   wall_s=wall_s, cache=dict(cache or {}),
                   ts=time.time(), extra=dict(extra or {}))

    @classmethod
    def from_error(cls, scenario, error: str, *, wall_s: float = 0.0) -> "RunResult":
        return cls(name=scenario.name, bench=scenario.bench,
                   arch=scenario.arch, task=scenario.task,
                   batch=scenario.batch, seq=scenario.seq,
                   dtype=scenario.dtype, mode=scenario.mode,
                   status="error", error=error, wall_s=wall_s, ts=time.time())

    def metrics(self) -> Dict[str, float]:
        """The regression-CI metric view of this record."""
        return {"median_us": self.median_us,
                "host_peak_bytes": float(self.host_peak_bytes),
                "device_bytes_delta": float(self.device_bytes_delta)}

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "RunResult":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


class ResultStore:
    """JSONL run log + latest-pointer map, atomic on update and safe for
    concurrent appenders (threads in one process AND separate processes)."""

    def __init__(self, path: str):
        if path.endswith(".json"):
            self.latest_path = path
            self.log_path = path[: -len(".json")] + ".jsonl"
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        else:
            os.makedirs(path, exist_ok=True)
            self.latest_path = os.path.join(path, "latest.json")
            self.log_path = os.path.join(path, "runs.jsonl")
        self.lock_path = self.latest_path + ".lock"
        #: torn/corrupt log lines skipped by the last ``history()`` replay
        self.corrupt_lines = 0
        self._tlock = threading.Lock()
        self.latest: Dict[str, dict] = {}
        if os.path.exists(self.latest_path):
            with open(self.latest_path) as f:
                self.latest = json.load(f)

    def append(self, record, *, advance_latest: bool = True) -> dict:
        """Append one record (RunResult or plain dict with a "name" key) to
        the log and move the latest pointer; returns the stored dict.

        ``advance_latest=False`` appends to the history log only — for
        time-series points (``MetricStore.log_result``) that must not
        shadow the latest-pointer view other readers key baselines on."""
        rec = record.to_dict() if hasattr(record, "to_dict") else dict(record)
        rec.setdefault("schema", SCHEMA_VERSION)
        rec.setdefault("ts", time.time())
        # one O_APPEND write syscall per record: concurrent appenders never
        # interleave bytes within a line
        line = (json.dumps(rec) + "\n").encode()
        fd = os.open(self.log_path, os.O_WRONLY | os.O_CREAT | os.O_APPEND,
                     0o644)
        try:
            os.write(fd, line)
        finally:
            os.close(fd)
        if advance_latest:
            self._advance_latest(rec)
        return rec

    def _advance_latest(self, rec: dict) -> None:
        """Move the latest pointer under an exclusive lock, merging with
        whatever other writers put on disk since we last read it."""
        with self._tlock:
            lock_fd = os.open(self.lock_path,
                              os.O_WRONLY | os.O_CREAT, 0o644)
            try:
                if fcntl is not None:
                    fcntl.flock(lock_fd, fcntl.LOCK_EX)
                disk: Dict[str, dict] = {}
                if os.path.exists(self.latest_path):
                    try:
                        with open(self.latest_path) as f:
                            disk = json.load(f)
                    except ValueError:
                        disk = {}
                merged = {**self.latest, **disk}
                merged[rec["name"]] = rec
                self.latest = merged
                tmp = f"{self.latest_path}.{os.getpid()}.tmp"
                with open(tmp, "w") as f:
                    json.dump(merged, f, indent=1)
                os.replace(tmp, self.latest_path)
            finally:
                os.close(lock_fd)

    def latest_result(self, name: str) -> Optional[RunResult]:
        rec = self.latest.get(name)
        return None if rec is None else RunResult.from_dict(rec)

    def history(self, name: Optional[str] = None) -> Iterator[dict]:
        """Replay the append log (optionally filtered to one scenario).

        Torn/truncated lines — a writer killed mid-append, a partial tail
        from a crash — are skipped, not fatal; ``self.corrupt_lines`` holds
        the count from the latest replay."""
        self.corrupt_lines = 0
        if not os.path.exists(self.log_path):
            return
        with open(self.log_path, errors="replace") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    self.corrupt_lines += 1
                    continue
                if not isinstance(rec, dict):
                    self.corrupt_lines += 1
                    continue
                if name is None or rec.get("name") == name:
                    yield rec

    def results(self) -> List[RunResult]:
        """All latest records that parse as RunResults, sorted by name."""
        return [RunResult.from_dict(r) for _, r in sorted(self.latest.items())
                if isinstance(r, dict) and "arch" in r]
