"""Deterministic request-trace generation for serving scenarios.

A *trace* is the load profile a serve cell (``Scenario(task="serve")``)
replays: a list of requests with prompts, output budgets, and arrival
times.  Arrival time is expressed in **decode steps** (virtual time), not
wall seconds: the continuous-batching engine admits a request once its
``arrival_step`` has passed, so which requests share slots — and therefore
the exact tokens generated — depends only on (profile, seed), never on
host speed.  That is what makes the acceptance invariant possible: the
same trace produces byte-identical token outputs whether the cell runs
serially in-process or sharded across worker subprocesses.

Profiles (``PROFILES``):

    uniform   every request available at step 0, fixed output budget —
              the closed-loop saturation workload;
    bursty    Poisson arrivals: exponential inter-arrival gaps in
              decode-step time, fixed output budget — the open-loop
              production shape where queues actually form;
    mixed     Poisson arrivals AND per-request output budgets drawn from
              a discrete distribution in [max(1, max_new//2), 2*max_new]
              — staggers slot completion, stressing continuous refill.

A spec is also the *recorded trace* format: ``save_spec``/``load_spec``
round-trip a TraceSpec through JSON, and a serve scenario can name one
with ``trace="file:PATH"`` — production-shaped load captured once (or
synthesized offline) becomes an ordinary scenario axis, replayed with
the same determinism guarantees as the generative profiles.

Prompt lengths are uniform within a trace: the engine's KV cache keeps a
single shared position counter per layer, so slots decode in lockstep
positions (see ``repro.launch.serve``).  Per-slot position tracking is
the serve-layer upgrade that unlocks mixed *prompt* lengths; until then
the spec varies output lengths only, which is what exercises continuous
batching.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import List, Optional, Sequence

import numpy as np

PROFILES = ("uniform", "bursty", "mixed")


@dataclasses.dataclass
class Request:
    """One serving request; field order is stable public API (positional
    construction ``Request(rid, prompt, max_new)`` predates traces)."""
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    arrival_step: int = 0         # decode step at which it becomes admissible
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # wall-clock timestamps stamped by the serve engine (0.0 = never)
    t_arrival: float = 0.0        # loop clock reached arrival_step
    t_first: float = 0.0          # first token emitted (prefill argmax)
    t_done: float = 0.0           # final token emitted


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Everything needed to regenerate a trace deterministically."""
    profile: str
    requests: int
    prompt_len: int
    max_new: int                  # base output budget (cap: 2x for "mixed")
    seed: int = 0

    def __post_init__(self):
        if self.profile not in PROFILES:
            raise ValueError(f"unknown trace profile {self.profile!r} "
                             f"(known: {PROFILES})")
        if self.requests < 1 or self.prompt_len < 1 or self.max_new < 1:
            raise ValueError(f"degenerate trace spec {self}")

    @property
    def max_new_cap(self) -> int:
        """Largest output budget any single request of this spec can carry
        (the "mixed" profile draws budgets up to 2x the base).  NOTE: this
        bounds one request, not the KV cache — size engines with
        ``cache_len_bound()``, which covers the whole replay."""
        return 2 * self.max_new if self.profile == "mixed" else self.max_new


def default_max_new(prompt_len: int) -> int:
    """The scenario-derived base output budget (seq axis -> prompt len)."""
    return max(4, prompt_len // 2)


def generate(spec: TraceSpec, vocab: int) -> List[Request]:
    """Expand a spec into concrete requests, sorted by (arrival, rid).

    All randomness flows from one ``default_rng(seed)`` in a fixed draw
    order, so a spec is a pure function of its fields — the worker
    subprocess regenerating the trace from the scenario gets the same
    requests the in-process path would.
    """
    rng = np.random.default_rng(spec.seed)
    prompts = rng.integers(0, vocab, (spec.requests, spec.prompt_len),
                           dtype=np.int64).astype(np.int32)
    arrivals = np.zeros(spec.requests, np.int64)
    if spec.profile in ("bursty", "mixed"):
        # Poisson process in decode-step time: the mean gap is half an
        # output budget, so bursts overlap in-flight requests and lulls
        # briefly drain the slots — both admission paths get exercised
        gaps = rng.exponential(scale=max(1.0, spec.max_new / 2.0),
                               size=spec.requests)
        arrivals = np.floor(np.cumsum(gaps) - gaps[0]).astype(np.int64)
    budgets = np.full(spec.requests, spec.max_new, np.int64)
    if spec.profile == "mixed":
        budgets = rng.integers(max(1, spec.max_new // 2),
                               spec.max_new_cap + 1, spec.requests)
    reqs = [Request(rid=i, prompt=prompts[i], max_new=int(budgets[i]),
                    arrival_step=int(arrivals[i]))
            for i in range(spec.requests)]
    reqs.sort(key=lambda r: (r.arrival_step, r.rid))
    return reqs


def cache_len_bound(requests: Sequence[Request], prompt_len: int) -> int:
    """KV-cache length the serve engine needs for a trace.

    The engine's per-layer position counter is shared across slots (see
    ``repro.launch.serve``) and advances once per batched decode step for
    the WHOLE trace replay — it never rewinds on slot refill.  Every
    decode step emits at least one token and each request emits
    ``max_new - 1`` decode tokens, so total steps are bounded by
    ``sum(max_new) - len(requests)``; the cache must cover the prompt
    plus that many positions.  (Per-slot position vectors — the DESIGN.md
    upgrade — would shrink this to prompt_len + max(max_new).)
    """
    steps = max(0, sum(r.max_new for r in requests) - len(requests))
    return prompt_len + steps + 8


def tokens_by_rid(requests: Sequence[Request]) -> List[List[int]]:
    """Generated tokens in rid order — the canonical output view used for
    the serial-vs-sharded determinism check."""
    return [list(r.out) for r in sorted(requests, key=lambda r: r.rid)]


def tokens_digest(tokens: Sequence[Sequence[int]]) -> str:
    """Stable digest of generated tokens (rid-ordered list of lists)."""
    payload = json.dumps([list(t) for t in tokens], separators=(",", ":"))
    return hashlib.sha256(payload.encode()).hexdigest()


#: scenario ``trace`` prefix selecting a recorded spec file over a
#: generative profile name
FILE_PREFIX = "file:"

#: schema tag written by save_spec / required by load_spec
SPEC_SCHEMA = 1


def save_spec(spec: TraceSpec, path: str) -> str:
    """Write a TraceSpec as JSON (``{"trace_spec": 1, ...fields}``) —
    the recorded-trace format ``trace="file:PATH"`` serve scenarios
    replay.  A spec IS the trace: ``generate()`` is a pure function of
    its fields, so persisting the spec persists the exact requests
    (prompts, budgets, arrivals) without storing token arrays."""
    with open(path, "w") as f:
        json.dump({"trace_spec": SPEC_SCHEMA,
                   **dataclasses.asdict(spec)}, f, indent=1)
    return path


def load_spec(path: str) -> TraceSpec:
    """Read a ``save_spec`` file back into a (validated) TraceSpec.

    Strict on shape: every spec field must be present and nothing else —
    a misspelled or renamed key in a hand-edited file must fail loudly
    here, not silently replay a default workload under the intended
    trace's name."""
    with open(path) as f:
        d = json.load(f)
    if not isinstance(d, dict) or d.get("trace_spec") != SPEC_SCHEMA:
        raise ValueError(f"{path}: not a trace-spec file "
                         f"(want trace_spec={SPEC_SCHEMA}, "
                         f"got {d.get('trace_spec') if isinstance(d, dict) else type(d).__name__})")
    fields = {f.name for f in dataclasses.fields(TraceSpec)}
    given = set(d) - {"trace_spec"}
    if given != fields:
        raise ValueError(f"{path}: trace-spec fields don't match "
                         f"(missing: {sorted(fields - given)}, "
                         f"unknown: {sorted(given - fields)})")
    return TraceSpec(**{k: d[k] for k in fields})


def spec_for_scenario(scenario, *, seed: Optional[int] = None) -> TraceSpec:
    """The TraceSpec a serve scenario denotes.

    ``trace="file:PATH"`` replays a recorded spec: the file defines the
    whole workload (request count, prompt length, budgets, seed) and the
    scenario's ``batch``/``seq`` axes are advisory labels only.  The file
    must exist on the host that RUNS the cell — under cluster dispatch
    that is the worker, so recorded traces need a shared or replicated
    path.  Otherwise ``trace`` names a generative profile: batch ->
    request count, seq -> prompt length, output budget derived from the
    prompt length."""
    if scenario.trace.startswith(FILE_PREFIX):
        return load_spec(scenario.trace[len(FILE_PREFIX):])
    return TraceSpec(profile=scenario.trace, requests=scenario.batch,
                     prompt_len=scenario.seq,
                     max_new=default_max_new(scenario.seq),
                     seed=0 if seed is None else seed)
