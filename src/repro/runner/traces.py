"""Deterministic request-trace generation for serving scenarios.

A *trace* is the load profile a serve cell (``Scenario(task="serve")``)
replays: a list of requests with prompts, output budgets, and arrival
times.  Arrival time is expressed in **decode steps** (virtual time), not
wall seconds: the continuous-batching engine admits a request once its
``arrival_step`` has passed, so which requests share slots — and therefore
the exact tokens generated — depends only on (profile, seed), never on
host speed.  That is what makes the acceptance invariant possible: the
same trace produces byte-identical token outputs whether the cell runs
serially in-process or sharded across worker subprocesses.

Arrival profiles (``PROFILES``):

    uniform   every request available at step 0, fixed output budget —
              the closed-loop saturation workload;
    bursty    Poisson arrivals: exponential inter-arrival gaps in
              decode-step time, fixed output budget — the open-loop
              production shape where queues actually form;
    mixed     Poisson arrivals AND per-request output budgets drawn from
              a discrete distribution in [max(1, max_new//2), 2*max_new]
              — staggers slot completion, stressing continuous refill.

Prompt-length profiles (``PROMPT_PROFILES``, the second half of a
``"arrival+length"`` trace axis, e.g. ``trace="bursty+bimodal"``):

    fixed     every prompt is exactly ``prompt_len`` tokens (default);
    uniform   lengths drawn uniformly in [max(1, P//2), 2P];
    bimodal   a chat-vs-document mix: half the requests at P//2, half
              at 2P;
    longtail  mostly short with a heavy tail: P//2 scaled by a Pareto
              draw, clipped to 4P — the production shape where one long
              prompt ties up a slot while short ones queue.

The engine tracks per-slot KV positions, so one trace can mix prompt
lengths freely — each admitted prompt is written at its own offset and
decoded against its own position vector (see ``repro.launch.serve``).

Determinism layout: every component draws from its OWN seeded stream
(lengths / arrivals / budgets / prompt content), so fixing one component
explicitly (a captured trace) never shifts another's draws.  Prompt
*content* is a pure function of (seed, lengths): a spec that records the
seed and the per-request lengths — what ``capture_spec`` emits from a
live run — regenerates byte-identical prompts without storing tokens.

A spec is also the *recorded trace* format: ``save_spec``/``load_spec``
round-trip a TraceSpec through JSON, and a serve scenario can name one
with ``trace="file:PATH"`` — production-shaped load captured once (via
``ServeEngine.capture`` / ``capture_spec``) or synthesized offline
becomes an ordinary scenario axis, replayed with the same determinism
guarantees as the generative profiles.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import List, Optional, Sequence, Tuple

import numpy as np

PROFILES = ("uniform", "bursty", "mixed")

PROMPT_PROFILES = ("fixed", "uniform", "bimodal", "longtail")

# per-component RNG stream keys: each draw category gets an independent
# default_rng([seed, KEY]) so explicit overrides (captured traces) never
# shift the other components' streams
_STREAM_LEN, _STREAM_ARRIVAL, _STREAM_BUDGET, _STREAM_CONTENT = 11, 13, 17, 19


def split_trace(trace: str) -> Tuple[str, str]:
    """Split a scenario trace-axis value ``"arrival[+length]"`` into its
    (arrival profile, prompt-length profile) halves; the length half
    defaults to ``"fixed"``."""
    arrival, _, plen = trace.partition("+")
    return arrival, (plen or "fixed")


@dataclasses.dataclass
class Request:
    """One serving request; field order is stable public API (positional
    construction ``Request(rid, prompt, max_new)`` predates traces)."""
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    arrival_step: int = 0         # decode step at which it becomes admissible
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # wall-clock timestamps stamped by the serve engine (0.0 = never)
    t_arrival: float = 0.0        # loop clock reached arrival_step
    t_first: float = 0.0          # first token emitted (prefill argmax)
    t_done: float = 0.0           # final token emitted


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Everything needed to regenerate a trace deterministically.

    The three optional tuples pin a component explicitly (one value per
    request, rid order); empty means "draw from the profile".  A captured
    trace pins all three, leaving only prompt *content* to the seeded
    content stream — which depends only on (seed, lengths), so the replay
    is byte-identical to the captured run.
    """
    profile: str
    requests: int
    prompt_len: int               # base prompt length (exact for "fixed")
    max_new: int                  # base output budget (cap: 2x for "mixed")
    seed: int = 0
    prompt_profile: str = "fixed"
    prompt_lens: Tuple[int, ...] = ()   # explicit per-request prompt lengths
    arrivals: Tuple[int, ...] = ()      # explicit per-request arrival steps
    budgets: Tuple[int, ...] = ()       # explicit per-request output budgets
    source: str = ""              # provenance (e.g. "capture:<cell name>")

    def __post_init__(self):
        if self.profile not in PROFILES:
            raise ValueError(f"unknown trace profile {self.profile!r} "
                             f"(known: {PROFILES})")
        if self.prompt_profile not in PROMPT_PROFILES:
            raise ValueError(
                f"unknown prompt-length profile {self.prompt_profile!r} "
                f"(known: {PROMPT_PROFILES})")
        if self.requests < 1 or self.prompt_len < 1 or self.max_new < 1:
            raise ValueError(f"degenerate trace spec {self}")
        # JSON round-trips tuples as lists; renormalize so specs stay
        # hashable and == across a save/load cycle
        for f in ("prompt_lens", "arrivals", "budgets"):
            v = getattr(self, f)
            if not isinstance(v, tuple):
                object.__setattr__(self, f, tuple(int(x) for x in v))
                v = getattr(self, f)
            if v and len(v) != self.requests:
                raise ValueError(f"{f} pins {len(v)} values for "
                                 f"{self.requests} requests")
            if any(x < (0 if f == "arrivals" else 1) for x in v):
                raise ValueError(f"degenerate {f} in {self}")

    @property
    def max_new_cap(self) -> int:
        """Largest output budget any single request of this spec can carry
        (the "mixed" profile draws budgets up to 2x the base).  NOTE: this
        bounds one request, not the KV cache — size engines with
        ``cache_len_bound()``, which covers the whole replay."""
        if self.budgets:
            return max(self.budgets)
        return 2 * self.max_new if self.profile == "mixed" else self.max_new


def default_max_new(prompt_len: int) -> int:
    """The scenario-derived base output budget (seq axis -> prompt len)."""
    return max(4, prompt_len // 2)


def _draw_lengths(spec: TraceSpec) -> np.ndarray:
    if spec.prompt_lens:
        return np.asarray(spec.prompt_lens, np.int64)
    P, n = spec.prompt_len, spec.requests
    if spec.prompt_profile == "fixed":
        return np.full(n, P, np.int64)
    rng = np.random.default_rng([spec.seed, _STREAM_LEN])
    if spec.prompt_profile == "uniform":
        return rng.integers(max(1, P // 2), 2 * P + 1, n)
    if spec.prompt_profile == "bimodal":
        return rng.choice([max(1, P // 2), 2 * P], n)
    # longtail: short head, Pareto-scaled tail clipped at 4P
    base = max(1, P // 2)
    lens = base * (1.0 + rng.pareto(2.0, n))
    return np.clip(lens.astype(np.int64), base, 4 * P)


def generate(spec: TraceSpec, vocab: int) -> List[Request]:
    """Expand a spec into concrete requests, sorted by (arrival, rid).

    Each component (lengths, arrivals, budgets, prompt content) draws
    from its own seeded stream in a fixed order, so a spec is a pure
    function of its fields — the worker subprocess regenerating the trace
    from the scenario gets the same requests the in-process path would,
    and a captured spec (explicit lengths/arrivals/budgets) regenerates
    the exact prompts of the run it was captured from.
    """
    n = spec.requests
    lens = _draw_lengths(spec)
    if spec.arrivals:
        arrivals = np.asarray(spec.arrivals, np.int64)
    else:
        arrivals = np.zeros(n, np.int64)
        if spec.profile in ("bursty", "mixed"):
            # Poisson process in decode-step time: the mean gap is half an
            # output budget, so bursts overlap in-flight requests and lulls
            # briefly drain the slots — both admission paths get exercised
            rng = np.random.default_rng([spec.seed, _STREAM_ARRIVAL])
            gaps = rng.exponential(scale=max(1.0, spec.max_new / 2.0), size=n)
            arrivals = np.floor(np.cumsum(gaps) - gaps[0]).astype(np.int64)
    if spec.budgets:
        budgets = np.asarray(spec.budgets, np.int64)
    else:
        budgets = np.full(n, spec.max_new, np.int64)
        if spec.profile == "mixed":
            rng = np.random.default_rng([spec.seed, _STREAM_BUDGET])
            budgets = rng.integers(max(1, spec.max_new // 2),
                                   spec.max_new_cap + 1, n)
    # prompt content: one stream, rid order — a function of (seed, lens)
    # only, which is the capture-fidelity invariant
    crng = np.random.default_rng([spec.seed, _STREAM_CONTENT])
    prompts = [crng.integers(0, vocab, (int(L),),
                             dtype=np.int64).astype(np.int32) for L in lens]
    reqs = [Request(rid=i, prompt=prompts[i], max_new=int(budgets[i]),
                    arrival_step=int(arrivals[i]))
            for i in range(n)]
    reqs.sort(key=lambda r: (r.arrival_step, r.rid))
    return reqs


def capture_spec(requests: Sequence[Request], *, seed: int = 0,
                 source: str = "") -> TraceSpec:
    """A replayable TraceSpec from a live run's requests — the serve
    engine's capture output.

    Pins lengths/arrivals/budgets explicitly; prompt *content* rides on
    the seed (pass the seed the requests were generated with — content is
    a pure function of (seed, lengths), see ``generate``), so the
    captured spec replays the original run byte-for-byte through the
    ordinary ``save_spec`` / ``trace="file:PATH"`` machinery."""
    reqs = sorted(requests, key=lambda r: r.rid)
    if not reqs:
        raise ValueError("cannot capture an empty request list")
    lens = [len(r.prompt) for r in reqs]
    return TraceSpec(
        profile="uniform", requests=len(reqs),
        prompt_len=int(np.median(lens)) or 1,
        max_new=max(r.max_new for r in reqs), seed=seed,
        prompt_lens=tuple(lens),
        arrivals=tuple(r.arrival_step for r in reqs),
        budgets=tuple(r.max_new for r in reqs),
        source=source)


def cache_len_bound(requests: Sequence[Request], *, prefix: int = 0) -> int:
    """KV-cache length the serve engine needs for a trace.

    Per-slot position tracking means a slot's positions rewind on refill:
    a request occupies positions ``[0, prefix + len(prompt) + max_new)``
    of its row regardless of how many replays/refills came before, so the
    bound is the largest single-request footprint — no lockstep slack.
    (The final emitted token is never written back, so this carries one
    position of slack by construction; the engine's exhaustion guard
    fires at exactly bound - 2.)  ``prefix`` covers non-token prefill
    rows (the vlm patch prefix).
    """
    return prefix + max(len(r.prompt) + r.max_new for r in requests)


def tokens_by_rid(requests: Sequence[Request]) -> List[List[int]]:
    """Generated tokens in rid order — the canonical output view used for
    the serial-vs-sharded determinism check."""
    return [list(r.out) for r in sorted(requests, key=lambda r: r.rid)]


def tokens_digest(tokens: Sequence[Sequence[int]]) -> str:
    """Stable digest of generated tokens (rid-ordered list of lists)."""
    payload = json.dumps([list(t) for t in tokens], separators=(",", ":"))
    return hashlib.sha256(payload.encode()).hexdigest()


#: scenario ``trace`` prefix selecting a recorded spec file over a
#: generative profile name
FILE_PREFIX = "file:"

#: schema tag written by save_spec / required by load_spec
SPEC_SCHEMA = 1

#: TraceSpec fields a spec file may omit (they default) — everything a
#: pre-capture save_spec file wouldn't have written
_OPTIONAL_FIELDS = ("prompt_profile", "prompt_lens", "arrivals", "budgets",
                    "source")


def save_spec(spec: TraceSpec, path: str) -> str:
    """Write a TraceSpec as JSON (``{"trace_spec": 1, ...fields}``) —
    the recorded-trace format ``trace="file:PATH"`` serve scenarios
    replay.  A spec IS the trace: ``generate()`` is a pure function of
    its fields, so persisting the spec persists the exact requests
    (prompts, budgets, arrivals) without storing token arrays.  Optional
    fields at their defaults are omitted, so synthetic specs keep the
    compact pre-capture file shape."""
    d = dataclasses.asdict(spec)
    for f in dataclasses.fields(TraceSpec):
        if f.name in _OPTIONAL_FIELDS and d[f.name] == f.default:
            del d[f.name]
    with open(path, "w") as f:
        json.dump({"trace_spec": SPEC_SCHEMA, **d}, f, indent=1)
    return path


def load_spec(path: str) -> TraceSpec:
    """Read a ``save_spec`` file back into a (validated) TraceSpec.

    Strict on shape: every required spec field must be present and no
    unknown keys — a misspelled or renamed key in a hand-edited file must
    fail loudly here, not silently replay a default workload under the
    intended trace's name.  The capture-era optional fields
    (``prompt_profile``/``prompt_lens``/``arrivals``/``budgets``/
    ``source``) may be absent (pre-capture files)."""
    with open(path) as f:
        d = json.load(f)
    if not isinstance(d, dict) or d.get("trace_spec") != SPEC_SCHEMA:
        raise ValueError(f"{path}: not a trace-spec file "
                         f"(want trace_spec={SPEC_SCHEMA}, "
                         f"got {d.get('trace_spec') if isinstance(d, dict) else type(d).__name__})")
    fields = {f.name for f in dataclasses.fields(TraceSpec)}
    required = fields - set(_OPTIONAL_FIELDS)
    given = set(d) - {"trace_spec"}
    if not required <= given or not given <= fields:
        raise ValueError(f"{path}: trace-spec fields don't match "
                         f"(missing: {sorted(required - given)}, "
                         f"unknown: {sorted(given - fields)})")
    return TraceSpec(**{k: d[k] for k in given})


def spec_for_scenario(scenario, *, seed: Optional[int] = None) -> TraceSpec:
    """The TraceSpec a serve/loadgen scenario denotes.

    ``trace="file:PATH"`` replays a recorded spec: the file defines the
    whole workload (request count, prompt lengths, budgets, seed) and the
    scenario's ``batch``/``seq`` axes are advisory labels only.  The file
    must exist on the host that RUNS the cell — under cluster dispatch
    that is the worker, so recorded traces need a shared or replicated
    path.  Otherwise ``trace`` names a generative profile
    (``"arrival[+length]"``, e.g. ``"bursty+bimodal"``): batch -> request
    count, seq -> base prompt length, output budget derived from the
    prompt length."""
    if scenario.trace.startswith(FILE_PREFIX):
        return load_spec(scenario.trace[len(FILE_PREFIX):])
    arrival, plen_profile = split_trace(scenario.trace)
    return TraceSpec(profile=arrival, requests=scenario.batch,
                     prompt_len=scenario.seq,
                     max_new=default_max_new(scenario.seq),
                     seed=0 if seed is None else seed,
                     prompt_profile=plen_profile)
