"""Cluster dispatch strategy glue for ``BenchmarkRunner.run_matrix``.

``ClusterScheduler`` mirrors the ``ShardScheduler`` interface (``run`` /
``close``) so the runner can treat cluster dispatch exactly like the
single-host pool, and owns the two deployment shapes behind one spec
string:

    "local:N"      bind a coordinator to an ephemeral localhost port and
                   spawn N ``worker --connect`` subprocesses against it —
                   the whole subsystem on one machine, used by tests,
                   ``scripts/smoke.sh`` and ``runner_bench``;
    "HOST:PORT"    bind the coordinator to that address and wait for
                   externally-launched workers (other hosts running
                   ``python -m repro.runner.worker --connect HOST:PORT``)
                   to register.

Local workers share the pool's measurement-fence flock (same host, same
semantics); remote workers fence only against themselves — cross-host
fencing is meaningless because the hosts don't share CPUs.  Local worker
stdout+stderr go to per-worker log files that are removed on ``close()``.
"""
from __future__ import annotations

import os
import subprocess
import sys
import tempfile
from typing import Callable, List, Optional, Sequence, Tuple

from repro.runner.cluster.coordinator import Coordinator
from repro.runner.pool import _subprocess_env
from repro.runner.results import RunResult
from repro.runner.scenario import Scenario


def parse_cluster_spec(spec: str) -> Tuple[str, str]:
    """``("local", "N")`` or ``("bind", "HOST:PORT")``; raises ValueError
    on anything else (including a bare hostname with no port)."""
    spec = (spec or "").strip()
    kind, _, rest = spec.partition(":")
    if kind == "local":
        if not rest.isdigit() or int(rest) < 1:
            raise ValueError(f"cluster spec {spec!r}: local:N needs N >= 1")
        return "local", rest
    if _ and rest.isdigit():
        return "bind", spec
    raise ValueError(f"cluster spec {spec!r}: expected 'local:N' or "
                     f"'HOST:PORT'")


class ClusterScheduler:
    """Dispatch scenario batches across socket-connected cluster workers."""

    def __init__(self, spec: str, *, runs: int = 5, warmup: int = 1,
                 compile_warmup: int = 3, reuse: bool = True,
                 measure_fence: bool = True, timeout: float = 1200.0,
                 heartbeat_timeout: float = 30.0,
                 connect_timeout: float = 120.0, capacity: int = 1):
        self.spec = spec
        kind, val = parse_cluster_spec(spec)
        bind = "127.0.0.1:0" if kind == "local" else val
        self.coordinator = Coordinator(bind=bind, timeout=timeout,
                                       heartbeat_timeout=heartbeat_timeout,
                                       connect_timeout=connect_timeout)
        self.procs: List[subprocess.Popen] = []
        self._log_paths: List[str] = []
        self._base_argv: List[str] = []
        self._env: dict = {}
        self.measure_lock_path = ""
        if kind == "local":
            argv = [sys.executable, "-m", "repro.runner.worker",
                    "--connect", self.coordinator.address,
                    "--runs", str(runs), "--warmup", str(warmup),
                    "--compile-warmup", str(compile_warmup)]
            if capacity > 1:
                # pipelined dispatch: the worker advertises capacity K at
                # register time, so the coordinator keeps K cells of its
                # group in flight (benchmarks/runner_bench.py part 8
                # measures what that pipelining buys)
                argv += ["--capacity", str(capacity)]
            if not reuse:
                argv.append("--no-reuse")
            if measure_fence and reuse:
                # same-host workers: same flock fence as the pipe pool
                fd, self.measure_lock_path = tempfile.mkstemp(
                    suffix=".lock", prefix="repro_measure_")
                os.close(fd)
                argv += ["--measure-lock", self.measure_lock_path]
            self._base_argv = argv
            self._env = _subprocess_env()
            for i in range(int(val)):
                proc, log = self._spawn(i)
                self.procs.append(proc)
                self._log_paths.append(log)

    def _spawn(self, i: int):
        fd, log = tempfile.mkstemp(suffix=".log", prefix=f"repro_cluster{i}_")
        proc = subprocess.Popen(self._base_argv + ["--host", f"local{i}"],
                                env=self._env, stdin=subprocess.DEVNULL,
                                stdout=fd, stderr=subprocess.STDOUT)
        os.close(fd)
        return proc, log

    def _respawn_dead(self) -> None:
        """Replace local workers that died (crashy cell took the process)
        before dispatching a new batch — the cluster analogue of the pipe
        pool's per-cell respawn, at run granularity.  A fleet that dies
        ENTIRELY mid-run still drains to error records after the
        coordinator's connect_timeout; the replacements catch the next
        ``run()`` call (nightly-CI persistence, not mid-run rescue)."""
        for i, proc in enumerate(self.procs):
            if proc.poll() is None:
                continue
            old_log = self._log_paths[i]
            if old_log and os.path.exists(old_log):
                try:
                    os.remove(old_log)
                except OSError:
                    pass
            self.procs[i], self._log_paths[i] = self._spawn(i)

    @property
    def address(self) -> str:
        return self.coordinator.address

    def worker_pids(self) -> List[int]:
        """PIDs of the locally-spawned workers (empty for bind mode) —
        the smoke gate's no-orphans check reads these before close()."""
        return [p.pid for p in self.procs]

    # ---- lifecycle -------------------------------------------------------

    def close(self) -> None:
        # shutdown messages first (clean worker exits), then reap hard
        self.coordinator.close()
        for proc in self.procs:
            try:
                proc.wait(5)
            except subprocess.TimeoutExpired:
                proc.terminate()
                try:
                    proc.wait(5)
                except subprocess.TimeoutExpired:
                    proc.kill()
                    proc.wait()
        self.procs = []
        for path in self._log_paths + [self.measure_lock_path]:
            if path and os.path.exists(path):
                try:
                    os.remove(path)
                except OSError:
                    pass
        self._log_paths = []
        self.measure_lock_path = ""

    def __enter__(self) -> "ClusterScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    # ---- dispatch --------------------------------------------------------

    def run(self, scenarios: Sequence[Scenario], *,
            hooks: Optional[dict] = None,
            runs: Optional[int] = None, warmup: Optional[int] = None,
            profile: bool = False,
            on_result: Optional[Callable[[RunResult], None]] = None,
            tracer=None, trace_parent=None, extras=None):
        """Dispatch one batch through the coordinator; returns
        ``(results_in_input_order, run_stats)`` — same contract as
        ``ShardScheduler.run`` (including the tracer/extras stitching
        knobs), with ``extra["host"]`` instead of ``extra["shard"]`` on
        every record."""
        if self.procs:
            self._respawn_dead()
        return self.coordinator.run(scenarios, hooks=hooks, runs=runs,
                                    warmup=warmup, profile=profile,
                                    on_result=on_result, tracer=tracer,
                                    trace_parent=trace_parent,
                                    extras=extras)
