"""The cluster coordinator: multi-host scenario dispatch over TCP.

``Coordinator`` listens on a socket and speaks the shared JSONL protocol
(``repro.runner.protocol``) with ``python -m repro.runner.worker
--connect HOST:PORT`` processes.  Workers register with a host id +
capacity; scenarios are scheduled as the same build-key groups the
single-host pool uses (``repro.runner.pool.rank_groups``), but placement
is **fully dynamic**: every group sits in a central deque and an idle
worker *steals* the next one — no static assignment at all, because
across heterogeneous hosts the task-weight guesses are even less
trustworthy than across local processes.  A worker owns its stolen group
until the group is drained (its arch-build/executable caches stay hot),
receiving up to ``capacity`` pipelined cells of that group at a time.

Failure detection is heartbeat-based: a worker thread pings every few
seconds even while a cell computes, so the coordinator can tell a long
XLA compile (pings flowing, cell deadline not yet reached) from a dead
host or partitioned network (silence).  On failure — EOF, heartbeat
silence past ``heartbeat_timeout``, or an in-flight cell past the
per-cell ``timeout`` — the worker's in-flight cells become error records
and the *unsent remainder of its group goes back on the deque*, to be
re-stolen by a surviving worker; the run completes as long as one worker
survives.  If every worker is gone and none (re)connects within
``connect_timeout``, the remaining cells become error records rather
than hanging the sweep — ``run()`` never raises for cluster faults.

The coordinator is persistent across ``run()`` calls (the cluster
analogue of the pool's warm workers): connections live until ``close()``,
which sends every worker a ``shutdown`` message.  Workers may connect at
any time, including mid-run — late joiners steal from whatever is left.
"""
from __future__ import annotations

import collections
import select
import socket
import time
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.fleet.metrics import registry as metrics_registry
from repro.runner.pool import rank_groups
from repro.runner.protocol import Channel, job_message, stats_delta
from repro.runner.results import RunResult
from repro.runner.scenario import Scenario
from repro.telemetry.provenance import stamp as stamp_provenance
from repro.telemetry.spans import NULL_TRACER, Tracer, group_label


class _WorkerConn:
    """One connected cluster worker: its channel + scheduling state."""

    def __init__(self, chan: Channel, addr: str):
        self.chan = chan
        self.addr = addr
        self.host = ""                 # set by the register message
        self.capacity = 1
        self.registered = False
        self.silence_bound = 0.0       # heartbeat-aware, set at register
        self.last_seen = time.monotonic()
        self.connected_at = self.last_seen
        self.stats_seen: Dict[str, int] = {}
        # same delta-merge protocol for the worker's metrics registry
        # (flat cumulative counters; see repro.fleet.metrics)
        self.metrics_seen: Dict[str, float] = {}
        # the group this worker currently owns (unsent cell indices) and
        # its in-flight cells (index -> dispatch time, for deadlines)
        self.group: List[int] = []
        self.inflight: Dict[int, float] = {}
        self.gspan = None              # open group span (traced runs)

    def ident(self) -> str:
        return self.host or self.addr


class Coordinator:
    """Listen for cluster workers and dispatch scenario batches to them."""

    def __init__(self, bind: str = "127.0.0.1:0", *,
                 heartbeat_timeout: float = 30.0, timeout: float = 1200.0,
                 connect_timeout: float = 120.0):
        host, _, port = bind.rpartition(":")
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host or "127.0.0.1", int(port or 0)))
        self._listener.listen(64)
        lhost, lport = self._listener.getsockname()[:2]
        #: what workers ``--connect`` to (the ephemeral port resolved)
        self.address = f"{lhost}:{lport}"
        self.heartbeat_timeout = heartbeat_timeout
        self.timeout = timeout
        self.connect_timeout = connect_timeout
        self._conns: List[_WorkerConn] = []
        self._closed = False
        # per-run tracing state (set by run(); defaults keep every path
        # trace-free when the caller passed no tracer)
        self._tr: Tracer = NULL_TRACER
        self._troot = None
        self._extras: Dict[str, dict] = {}
        self._dspans: Dict[int, object] = {}   # cell idx -> dispatch span

    # ---- lifecycle -------------------------------------------------------

    def workers(self) -> List[str]:
        """Host ids of the currently registered workers."""
        return [c.ident() for c in self._conns if c.registered]

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for conn in self._conns:
            try:
                conn.chan.send({"op": "shutdown"})
            except OSError:
                pass
            conn.chan.close()
        self._conns = []
        try:
            self._listener.close()
        except OSError:
            pass

    def __enter__(self) -> "Coordinator":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    # ---- dispatch --------------------------------------------------------

    def run(self, scenarios: Sequence[Scenario], *,
            hooks: Optional[dict] = None,
            runs: Optional[int] = None, warmup: Optional[int] = None,
            profile: bool = False,
            on_result: Optional[Callable[[RunResult], None]] = None,
            tracer: Optional[Tracer] = None, trace_parent=None,
            extras: Optional[Dict[str, dict]] = None):
        """Run every scenario across the connected workers; returns
        ``(results_in_input_order, run_stats)``.  Results carry
        ``extra["host"]`` (the worker's registered host id) and
        ``extra["isolated"]`` — see ``runner/results.py``.

        ``tracer``/``trace_parent`` stitch the dispatch into the caller's
        trace exactly like the pool: one ``group:`` span per stolen group
        (on the owning worker's connection), one ``dispatch:`` span per
        cell whose context rides the job so the worker's spans come back
        nested under it.  ``extras`` maps scenario name -> extra dict
        forwarded with each job."""
        from repro.runner.runner import RunnerStats
        self._tr = tracer or NULL_TRACER
        self._troot = trace_parent
        self._extras = extras or {}
        self._dspans = {}
        queue: Deque[List[int]] = collections.deque(
            list(idxs) for idxs, _ in rank_groups(scenarios))
        results: List[Optional[RunResult]] = [None] * len(scenarios)
        run_stats = RunnerStats()
        ctx = (scenarios, hooks or {}, runs, warmup, profile, on_result)
        now = time.monotonic()
        for conn in self._conns:
            conn.last_seen = now       # idle between runs is not a fault
        last_alive = now
        done = [0]
        # drain everything buffered while idle between runs — dead-peer
        # EOFs, pings, registrations of workers that connected in the
        # meantime — and reap the casualties BEFORE the first feed: a
        # worker that died idle must not be handed a cell that instantly
        # becomes a spurious error record
        while self._poll(0.0, queue, ctx, results, run_stats, done):
            pass
        self._reap_failures(queue, ctx, results, run_stats, done)
        # feed the (live) workers that stayed connected from previous runs
        for conn in list(self._conns):
            self._feed(conn, queue, ctx)
        while done[0] < len(scenarios):
            self._poll(0.5, queue, ctx, results, run_stats, done)
            self._reap_failures(queue, ctx, results, run_stats, done)
            if any(c.registered for c in self._conns):
                last_alive = time.monotonic()
            elif time.monotonic() - last_alive > self.connect_timeout:
                # every worker is gone and nobody reconnected: error out
                # the remaining cells instead of hanging the sweep
                self._drain_unrunnable(queue, ctx, results, run_stats, done)
        if self._tr.enabled:
            # seal whatever is still open (groups whose tail just finished,
            # dispatch slots orphaned by an off-protocol worker)
            for conn in self._conns:
                if conn.gspan is not None:
                    self._tr.finish(conn.gspan)
                    conn.gspan = None
            for ds in self._dspans.values():
                ds.set(error="unresolved at run end")
                self._tr.finish(ds)
            self._dspans = {}
        return [r for r in results if r is not None], run_stats

    def _poll(self, wait: float, queue, ctx, results, run_stats,
              done) -> bool:
        """One select pass: accept connections, pump readable channels,
        handle their messages.  Returns whether anything was ready (the
        pre-feed drain loops on this; eof channels are excluded so the
        loop terminates — _reap_failures retires them)."""
        channels = {c.chan.fileno(): c for c in self._conns
                    if not c.chan.eof}
        ready, _, _ = select.select(
            [self._listener] + list(channels), [], [], wait)
        for r in ready:
            if r is self._listener:
                self._accept()
                continue
            conn = channels.get(r)
            if conn is None:
                continue
            try:
                msgs = conn.chan.pump()
                if msgs:
                    now = time.monotonic()
                    if conn.registered:
                        # silence since the last message from this worker —
                        # the live heartbeat-gap distribution
                        metrics_registry().observe(
                            "cluster_heartbeat_gap_seconds",
                            now - conn.last_seen)
                    conn.last_seen = now
                for msg in msgs:
                    self._handle(conn, msg, queue, ctx, results,
                                 run_stats, done)
            except Exception as e:  # noqa: BLE001 — a stray client
                # (port scan, HTTP probe) or a buggy worker sending
                # non-protocol bytes costs ITS connection, never the
                # sweep: run() must not raise for cluster faults
                if conn in self._conns:
                    self._retire(conn,
                                 f"cluster worker {conn.ident()} "
                                 f"protocol error: {e!r}",
                                 queue, ctx, results, run_stats, done)
        return bool(ready)

    # ---- connection handling ---------------------------------------------

    def _accept(self) -> None:
        try:
            sock, addr = self._listener.accept()
        except OSError:
            return
        sock.setblocking(True)
        self._conns.append(
            _WorkerConn(Channel.over_socket(sock), f"{addr[0]}:{addr[1]}"))

    def _handle(self, conn: _WorkerConn, msg: dict, queue, ctx,
                results, run_stats, done) -> None:
        op = msg.get("op")
        if op == "register":
            conn.host = str(msg.get("host") or conn.addr)
            # clamp capacity: an absurd value from a buggy/hostile client
            # would absorb the whole queue into one dead-air connection
            # (and enough unread job bytes could even block sendall); 16
            # in-flight cells is far beyond any useful pipelining depth
            conn.capacity = min(16, max(1, int(msg.get("capacity") or 1)))
            # a worker pinging slower than our default silence bound is
            # healthy, not dead: honor its declared interval with margin
            beat = float(msg.get("heartbeat") or 0.0)
            conn.silence_bound = max(self.heartbeat_timeout, 3.0 * beat)
            conn.registered = True
            self._feed(conn, queue, ctx)
        elif op == "ping":
            pass                       # last_seen already advanced
        elif op == "result":
            self._on_result(conn, msg, queue, ctx, results, run_stats, done)

    def _on_result(self, conn: _WorkerConn, msg: dict, queue, ctx,
                   results, run_stats, done) -> None:
        scenarios, _, _, _, _, on_result = ctx
        idx = msg.get("cell")
        t0 = conn.inflight.pop(idx, None) if isinstance(idx, int) else None
        if t0 is None or not (0 <= idx < len(scenarios)) \
                or results[idx] is not None:
            # a result we can't match to an in-flight cell (missing/bogus
            # id, duplicate) means the worker is off-protocol: retire it
            # NOW — silently dropping the message would leave the real
            # in-flight entry ticking toward the 1200s cell timeout
            self._retire(conn,
                         f"cluster worker {conn.ident()} sent an "
                         f"unmatched result (cell {idx!r})",
                         queue, ctx, results, run_stats, done)
            return
        rr = RunResult.from_dict(msg["result"])
        rr.wall_s = time.monotonic() - t0 if t0 else rr.wall_s
        # cells pipelined behind this one (capacity > 1) were queued, not
        # executing: their per-cell deadline starts now, at the head
        now = time.monotonic()
        for pending in conn.inflight:
            conn.inflight[pending] = now
        delta = stats_delta(msg.get("stats"), conn.stats_seen)
        if delta:
            run_stats.merge(delta)
        if msg.get("metrics"):
            metrics_registry().merge_cumulative(
                stats_delta(msg["metrics"], conn.metrics_seen))
        metrics_registry().set_gauge(
            f"cluster_inflight_{conn.ident()}", len(conn.inflight))
        ds = self._dspans.pop(idx, None)
        if ds is not None:
            self._tr.ingest(msg.get("spans"), proc=conn.ident())
            ds.set(status=rr.status)
            self._tr.finish(ds)
            rr.extra.setdefault("span_trace", self._tr.trace_id)
            rr.extra["span_dispatch"] = ds.span_id
        self._finish(conn.ident(), idx, rr, results, done, on_result)
        self._feed(conn, queue, ctx)

    def _finish(self, host: str, idx: int, rr: RunResult,
                results, done, on_result) -> None:
        if host:
            rr.extra["host"] = host
        rr.extra["isolated"] = True
        # backstop for records the worker never produced (retire/drain
        # errors): dispatch-side extras + coordinator provenance.  Worker
        # results arrive already annotated/stamped; setdefault keeps the
        # worker's (correct-host) values
        ex = self._extras.get(rr.name)
        if ex:
            for k, v in ex.items():
                rr.extra.setdefault(k, v)
        stamp_provenance(rr)
        results[idx] = rr
        done[0] += 1
        try:
            if on_result is not None:
                on_result(rr)
        except Exception:  # noqa: BLE001 — a failing store append must not
            pass           # kill the dispatch loop; the result is returned

    def _feed(self, conn: _WorkerConn, queue, ctx) -> None:
        """Send the worker cells of its current group up to its capacity,
        stealing the next ranked group from the deque when it runs dry."""
        scenarios, hooks, runs, warmup, profile, _ = ctx
        if not conn.registered:
            return
        while len(conn.inflight) < conn.capacity:
            if not conn.group:
                if not queue:
                    return
                conn.group = queue.popleft()    # steal the next group
                reg = metrics_registry()
                reg.inc("cluster_steals_total")
                reg.set_gauge("cluster_queue_depth", len(queue))
                if self._tr.enabled:
                    if conn.gspan is not None:
                        self._tr.finish(conn.gspan)
                    key = scenarios[conn.group[0]].build_key()
                    conn.gspan = self._tr.start(
                        "group:" + group_label(key), parent=self._troot,
                        kind="group", host=conn.ident(),
                        cells=len(conn.group))
            idx = conn.group.pop(0)
            sc = scenarios[idx]
            hook = hooks.get(sc.name) or hooks.get(sc.bench)
            ds = None
            if self._tr.enabled:
                ds = self._tr.start("dispatch:" + sc.name, kind="dispatch",
                                    parent=conn.gspan, cell=sc.name,
                                    host=conn.ident())
            try:
                conn.chan.send(job_message(sc, runs=runs, warmup=warmup,
                                           profile=profile, hook=hook,
                                           cell=idx,
                                           trace=self._tr.context(ds),
                                           extra=self._extras.get(sc.name)))
            except OSError:
                # send failed: the cell was never dispatched — put it back
                # and let _reap_failures retire the connection (the unsent
                # dispatch span is simply dropped: never recorded)
                conn.group.insert(0, idx)
                conn.chan.eof = True
                return
            if ds is not None:
                self._dspans[idx] = ds
            conn.inflight[idx] = time.monotonic()

    # ---- failure handling ------------------------------------------------

    def _reap_failures(self, queue, ctx, results, run_stats, done) -> None:
        now = time.monotonic()
        for conn in list(self._conns):
            reason = None
            if conn.chan.eof:
                reason = f"cluster worker {conn.ident()} disconnected"
            elif (conn.registered
                  and now - conn.last_seen > (conn.silence_bound
                                              or self.heartbeat_timeout)):
                reason = (f"cluster worker {conn.ident()} heartbeat lost "
                          f"({conn.silence_bound:.0f}s silence)")
            elif (not conn.registered
                  and now - conn.connected_at > self.heartbeat_timeout):
                # registration deadline from ACCEPT time, not last_seen: a
                # stray client that keeps sending valid-but-unregistered
                # JSON (pings, unknown ops) must still be reaped, or its
                # fd leaks into every select() for the coordinator's
                # whole persistent lifetime
                reason = (f"cluster worker {conn.ident()} never registered "
                          f"({self.heartbeat_timeout:.0f}s since connect)")
            elif any(now - t0 > self.timeout
                     for t0 in conn.inflight.values()):
                reason = (f"cluster worker {conn.ident()} cell timed out "
                          f"after {self.timeout:.0f}s")
            if reason:
                self._retire(conn, reason, queue, ctx, results, run_stats,
                             done)

    def _retire(self, conn: _WorkerConn, reason: str, queue, ctx,
                results, run_stats, done) -> None:
        """Dead worker: error records for its in-flight cells, its group's
        unsent remainder back on the deque for a survivor to re-steal."""
        scenarios, _, _, _, _, on_result = ctx
        self._conns.remove(conn)
        conn.chan.close()
        metrics_registry().inc("cluster_retires_total")
        now = time.monotonic()
        for idx, t0 in sorted(conn.inflight.items()):
            if results[idx] is not None:
                continue
            rr = RunResult.from_error(scenarios[idx],
                                      f"{reason} (cell in flight)",
                                      wall_s=now - t0)
            run_stats.scenarios_run += 1
            run_stats.errors += 1
            ds = self._dspans.pop(idx, None)
            if ds is not None:
                ds.set(status="error", error=reason[:200])
                self._tr.finish(ds)
                rr.extra.setdefault("span_trace", self._tr.trace_id)
                rr.extra["span_dispatch"] = ds.span_id
            self._finish(conn.ident(), idx, rr, results, done, on_result)
        conn.inflight = {}
        if conn.gspan is not None:
            self._tr.finish(conn.gspan)
            conn.gspan = None
        if conn.group:
            queue.appendleft(conn.group)        # re-stolen next
            conn.group = []
        # the freed work may be stealable right now by an idle survivor
        for other in self._conns:
            self._feed(other, queue, ctx)

    def _drain_unrunnable(self, queue, ctx, results, run_stats,
                          done) -> None:
        scenarios, _, _, _, _, on_result = ctx
        reason = (f"no cluster workers connected within "
                  f"{self.connect_timeout:.0f}s")
        pending = [idx for group in queue for idx in group]
        queue.clear()
        for idx in pending:
            if results[idx] is not None:
                continue
            run_stats.scenarios_run += 1
            run_stats.errors += 1
            self._finish("", idx, RunResult.from_error(scenarios[idx], reason),
                         results, done, on_result)
