"""Multi-host cluster dispatch for the unified benchmark runner.

The socket transport of the worker protocol (``repro.runner.protocol``):
a ``Coordinator`` listens on TCP, ``worker --connect`` processes register
with a host id + capacity and steal build-key groups from a central
deque, with heartbeat-based failure detection and group reassignment.
``ClusterScheduler`` wraps it in the ``ShardScheduler`` interface and
owns the ``"local:N"`` self-contained deployment (N localhost worker
subprocesses), which is how ``run_matrix(..., cluster="local:N")``,
``benchmarks.run --cluster`` and the tests exercise the subsystem on one
machine.
"""
from repro.runner.cluster.coordinator import Coordinator
from repro.runner.cluster.scheduler import ClusterScheduler, parse_cluster_spec

__all__ = ["Coordinator", "ClusterScheduler", "parse_cluster_spec"]
