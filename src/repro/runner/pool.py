"""Sharded process-pool dispatch for ``BenchmarkRunner.run_matrix``.

Scenarios are scheduled as **build-key groups** (``rank_groups``): every
scenario of one (arch, dtype, mode-overrides) is dispatched to the same
worker back-to-back, so the per-worker arch-build and compiled-executable
caches keep paying off exactly as they do in-process.

Two placement strategies share those groups:

* **dynamic stealing** (default): the first ``jobs`` ranked groups seed
  one worker each (deterministic start — the common two-group/two-worker
  smoke stays exactly placed), and the remaining groups sit in a shared
  deque that idle workers *pull* from as they finish.  A worker stuck on
  a slow group simply stops pulling; the others drain the tail.  This
  replaces the static tail assignment, whose task-weight guesses misplace
  groups whenever guessed and actual cost diverge.
* **static LPT** (``steal=False``, and the ``assign_shards`` function):
  groups are placed largest-guessed-weight-first onto the least-loaded
  shard up front.  Fully deterministic placement, kept for comparison —
  ``benchmarks/runner_bench.py`` measures static vs stealing vs cluster
  on a skew-weighted matrix.

``ShardScheduler`` owns N *persistent* worker subprocesses
(``python -m repro.runner.worker --serve``) that live across ``run()``
calls — a regression-CI day's repeated nights keep their warm caches.
Jobs and results are JSONL messages (``repro.runner.protocol`` — the same
protocol the cluster speaks over TCP) over each worker's stdin/stdout
pipes, so the parent collects results as cells complete and a crash (OOM,
kernel segfault, ...) costs exactly the in-flight cell: the dead worker is
respawned and its group's remaining cells continue.  Worker ``RunnerStats``
are fetched after every cell and delta-merged into the per-run stats, so
model builds / compiles that happen out-of-process stay visible to the
parent (only the stats of a cell that crashes its worker are lost with the
process).

Concurrent workers overlap their expensive phases (interpreter startup,
model build, trace, XLA compile) but serialize the short timed loops on a
shared *measurement fence* lock, so two cells' timed steps never overlap —
the worst cross-worker distortion.  (Another worker's unfenced build or
compile can still share the CPU with a fenced loop, so on heavily
oversubscribed hosts prefer small probe cells where injected regressions
dwarf the jitter; ``measure_fence=False`` opts out entirely for
pure-throughput sweeps.)

Regression hooks are forwarded as their plain parameters
(``slowdown_s`` / ``leak_bytes``); custom ``RegressionHook`` subclasses
with parent-process behaviour cannot cross the process boundary.
"""
from __future__ import annotations

import collections
import os
import subprocess
import sys
import tempfile
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.fleet.metrics import registry as metrics_registry
from repro.runner.protocol import Channel, job_message, stats_delta
from repro.runner.results import RunResult
from repro.runner.scenario import Scenario
from repro.telemetry.provenance import stamp as stamp_provenance
from repro.telemetry.spans import NULL_TRACER, Tracer, group_label


def _src_dir() -> str:
    import repro
    pkg = (repro.__file__ and os.path.dirname(repro.__file__)) or \
        list(repro.__path__)[0]
    return os.path.dirname(os.path.abspath(pkg))


def _subprocess_env() -> Dict[str, str]:
    env = dict(os.environ)
    src = _src_dir()
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    return env


#: relative cost guess per task for shard balancing (a train step runs
#: fwd+bwd+update; decode is a single cached token; a serve cell replays a
#: whole continuous-batching trace — many decode steps plus per-request
#: prefills) — only the ratios matter, and only for load balance, never
#: for correctness
_TASK_WEIGHT = {"train": 4, "infer_prefill": 2, "infer_decode": 1,
                "serve": 8, "loadgen": 8, "kernel": 1}


def rank_groups(scenarios: Sequence[Scenario]) -> List[Tuple[List[int], int]]:
    """Build-key groups of scenario *indices*, ranked heaviest-guessed-
    weight first (group weight = sum of per-task cost weights, ties broken
    by first appearance — sorted() is stable).  Scenarios of one build_key
    stay together in input order.  This is the shared scheduling unit for
    the single-host pool AND the cluster coordinator: a group is the chunk
    a worker owns so its caches stay hot."""
    groups: Dict[Tuple, List[int]] = {}
    weight: Dict[Tuple, int] = {}
    order: List[Tuple] = []
    for i, sc in enumerate(scenarios):
        key = sc.build_key()
        if key not in groups:
            groups[key] = []
            weight[key] = 0
            order.append(key)
        groups[key].append(i)
        weight[key] += _TASK_WEIGHT.get(sc.task, 2)
    ranked = sorted(order, key=lambda k: -weight[k])
    return [(groups[k], weight[k]) for k in ranked]


def assign_shards(scenarios: Sequence[Scenario], jobs: int) -> List[List[int]]:
    """Static LPT: partition scenario indices into ``jobs`` shards by
    build_key, placing ranked groups onto the currently lightest shard
    (ties by shard index).  Fully deterministic for a given scenario list;
    shards may come back empty when there are fewer groups than jobs."""
    jobs = max(1, int(jobs))
    shards: List[List[int]] = [[] for _ in range(jobs)]
    load = [0] * jobs
    for idxs, w in rank_groups(scenarios):
        target = min(range(jobs), key=lambda j: (load[j], j))
        shards[target].extend(idxs)
        load[target] += w
    return shards


def steal_plan(ranked: Sequence[Tuple[List[int], int]], jobs: int
               ) -> Tuple[List[List[int]], Deque[List[int]]]:
    """Dynamic placement: the first ``jobs`` ranked groups seed one worker
    each (deterministic start), the tail goes into the shared steal deque
    idle workers pull from.  Returns ``(seeds, deque)``."""
    jobs = max(1, int(jobs))
    seeds: List[List[int]] = [[] for _ in range(jobs)]
    for j, (idxs, _) in enumerate(ranked[:jobs]):
        seeds[j] = list(idxs)
    return seeds, collections.deque(list(idxs) for idxs, _ in ranked[jobs:])


class _Worker:
    """One persistent ``worker --serve`` subprocess + its protocol state."""

    def __init__(self, idx: int, argv: List[str], env: Dict[str, str]):
        self.idx = idx
        self.argv = argv
        self.env = env
        self.proc: Optional[subprocess.Popen] = None
        self.chan: Optional[Channel] = None
        self.generation = 0          # bumped per spawn (stats-delta resets)
        # cumulative worker stats already delta-merged by the parent; lives
        # on the worker (NOT per run() call) because the process — and its
        # monotonically growing counters — persists across run() calls
        self.stats_seen: Dict[str, int] = {}
        # same delta-merge protocol for the worker's metrics registry
        # (flat cumulative counters; see repro.fleet.metrics)
        self.metrics_seen: Dict[str, float] = {}
        self.stats_gen = -1
        self.stderr_path = ""

    def ensure(self) -> subprocess.Popen:
        if self.proc is None or self.proc.poll() is not None:
            self._cleanup_stderr()
            fd, self.stderr_path = tempfile.mkstemp(
                suffix=".log", prefix=f"repro_shard{self.idx}_")
            self.proc = subprocess.Popen(
                self.argv, env=self.env, stdin=subprocess.PIPE,
                stdout=subprocess.PIPE, stderr=fd, bufsize=0)
            os.close(fd)
            self.chan = Channel.over_pipes(self.proc.stdout, self.proc.stdin)
            self.generation += 1
            if self.generation > 1:
                metrics_registry().inc("pool_respawns_total")
        return self.proc

    def send(self, msg: dict) -> None:
        self.ensure()
        self.chan.send(msg)

    def recv(self, timeout: float) -> Optional[dict]:
        """One protocol line, or None on EOF/timeout (worker dead/hung)."""
        if self.chan is None:
            return None
        return self.chan.recv(timeout)

    def stderr_tail(self, n: int = 500) -> str:
        try:
            with open(self.stderr_path, errors="replace") as f:
                return f.read()[-n:]
        except OSError:
            return ""

    def death_reason(self, timeout: float) -> str:
        if self.proc is not None:
            try:                 # give a just-died worker time to be reaped
                self.proc.wait(0.5)
            except subprocess.TimeoutExpired:
                return (f"shard worker {self.idx} timed out "
                        f"after {timeout:.0f}s")
        code = self.proc.poll() if self.proc is not None else None
        return (f"shard worker {self.idx} died (exit {code}): "
                f"{self.stderr_tail()}")

    def kill(self, grace: float = 0.0) -> None:
        proc, self.proc = self.proc, None
        self.chan = None
        if proc is not None:
            try:
                if proc.stdin:
                    proc.stdin.close()      # EOF => clean worker exit
            except OSError:
                pass
            if proc.poll() is None:
                try:
                    proc.wait(grace or 0.1)
                except subprocess.TimeoutExpired:
                    proc.terminate()
                    try:
                        proc.wait(5)
                    except subprocess.TimeoutExpired:
                        proc.kill()
                        proc.wait()
            if proc.stdout:
                proc.stdout.close()
        self._cleanup_stderr()

    def _cleanup_stderr(self) -> None:
        if self.stderr_path and os.path.exists(self.stderr_path):
            try:
                os.remove(self.stderr_path)
            except OSError:
                pass
        self.stderr_path = ""


class ShardScheduler:
    """Dispatch scenario batches across persistent worker subprocesses."""

    def __init__(self, jobs: int, *, runs: int = 5, warmup: int = 1,
                 compile_warmup: int = 3, reuse: bool = True,
                 measure_fence: bool = True, timeout: float = 1200.0,
                 steal: bool = True):
        if os.name != "posix":
            # the protocol needs select()able pipes + flock; fail loudly
            # instead of turning every cell into a "worker died" record
            raise RuntimeError("sharded dispatch (jobs>1) requires a POSIX "
                               "host; use the serial path (jobs=0)")
        self.jobs = max(1, int(jobs))
        self.timeout = timeout
        self.steal = steal
        argv = [sys.executable, "-m", "repro.runner.worker", "--serve",
                "--runs", str(runs), "--warmup", str(warmup),
                "--compile-warmup", str(compile_warmup)]
        if not reuse:
            argv.append("--no-reuse")
        # measurement fence: builds/compiles overlap across workers, but
        # the short timed loops serialize on a shared flock so no worker
        # times its steps against another's CPU load — sharded numbers
        # stay comparable with serial ones (see worker._run_cell)
        self.measure_lock_path = ""
        if measure_fence and reuse:
            fd, self.measure_lock_path = tempfile.mkstemp(
                suffix=".lock", prefix="repro_measure_")
            os.close(fd)
            argv += ["--measure-lock", self.measure_lock_path]
        env = _subprocess_env()
        self._workers = [_Worker(i, argv, env) for i in range(self.jobs)]
        self._lock = threading.Lock()

    # ---- lifecycle -------------------------------------------------------

    def worker_pids(self) -> List[int]:
        """PIDs of the currently-spawned shard workers (the no-orphans
        gate: after ``close()`` each must be dead)."""
        return [w.proc.pid for w in self._workers if w.proc is not None]

    def close(self) -> None:
        for worker in self._workers:
            worker.kill(grace=2.0)
        if self.measure_lock_path and os.path.exists(self.measure_lock_path):
            try:
                os.remove(self.measure_lock_path)
            except OSError:
                pass

    def __enter__(self) -> "ShardScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    # ---- dispatch --------------------------------------------------------

    def run(self, scenarios: Sequence[Scenario], *,
            hooks: Optional[dict] = None,
            runs: Optional[int] = None, warmup: Optional[int] = None,
            profile: bool = False,
            on_result: Optional[Callable[[RunResult], None]] = None,
            steal: Optional[bool] = None,
            tracer: Optional[Tracer] = None, trace_parent=None,
            extras: Optional[Dict[str, dict]] = None):
        """Run every scenario, grouped by build_key; returns
        ``(results_in_input_order, run_stats)`` where ``run_stats`` is a
        ``RunnerStats`` of everything the workers did *during this call*.

        ``steal`` (default: the scheduler's setting) picks dynamic
        group stealing vs static LPT placement.  ``profile`` rides in
        every job message, so workers record the measured
        ``extra["prof_*"]`` payload exactly like the serial path.
        ``on_result`` fires from worker-reader threads as cells complete
        (the ResultStore append path is thread-safe for exactly this).

        ``tracer``/``trace_parent`` stitch the dispatch into the caller's
        trace: each stolen group gets a ``group:`` span, each cell a
        ``dispatch:`` span whose context rides the job message so the
        worker's own spans come back parented under it (matched by cell).
        ``extras`` maps scenario name -> extra dict forwarded with the
        job and merged into that cell's result.
        """
        from repro.runner.runner import RunnerStats
        tracer = tracer or NULL_TRACER
        extras = extras or {}
        steal = self.steal if steal is None else steal
        ranked = rank_groups(scenarios)
        if steal:
            seeds, queue = steal_plan(ranked, self.jobs)
        else:
            # static LPT: every group pre-placed, nothing left to steal
            shards = assign_shards(scenarios, self.jobs)
            seeds, queue = [list(s) for s in shards], collections.deque()
        results: List[Optional[RunResult]] = [None] * len(scenarios)
        run_stats = RunnerStats()
        threads = []
        for worker, seed in zip(self._workers, seeds):
            if not seed and not queue:
                continue
            t = threading.Thread(
                target=self._drive,
                args=(worker, seed, queue, scenarios, hooks or {}, runs,
                      warmup, profile, results, run_stats, on_result,
                      tracer, trace_parent, extras),
                name=f"shard-{worker.idx}", daemon=True)
            threads.append(t)
            t.start()
        for t in threads:
            t.join()
        return [r for r in results if r is not None], run_stats

    def _drive(self, worker: _Worker, seed: List[int],
               queue: Deque[List[int]], scenarios: Sequence[Scenario],
               hooks: dict, runs: Optional[int], warmup: Optional[int],
               profile: bool, results: List[Optional[RunResult]], run_stats,
               on_result: Optional[Callable[[RunResult], None]],
               tracer: Tracer = NULL_TRACER, trace_parent=None,
               extras: Optional[Dict[str, dict]] = None) -> None:
        """One worker's job stream: its seed group first, then whatever
        groups it can steal from the shared deque.  Crashes cost one cell
        each (the worker is respawned for its group's remaining cells)."""
        group = seed
        while True:
            if not group:
                with self._lock:
                    if not queue:
                        return
                    group = queue.popleft()   # steal the next ranked group
                    depth = len(queue)
                reg = metrics_registry()
                reg.inc("pool_steals_total")
                reg.set_gauge("pool_queue_depth", depth)
                continue
            gspan = None
            if tracer.enabled and group:
                key = scenarios[group[0]].build_key()
                gspan = tracer.start(
                    "group:" + group_label(key), parent=trace_parent,
                    kind="group", shard=worker.idx, cells=len(group))
            try:
                for idx in group:
                    self._run_one(worker, idx, scenarios, hooks, runs,
                                  warmup, profile, results, run_stats,
                                  on_result, tracer, gspan, extras or {})
            finally:
                if gspan is not None:
                    tracer.finish(gspan)
            group = []

    def _run_one(self, worker: _Worker, idx: int,
                 scenarios: Sequence[Scenario], hooks: dict,
                 runs: Optional[int], warmup: Optional[int], profile: bool,
                 results: List[Optional[RunResult]], run_stats,
                 on_result: Optional[Callable[[RunResult], None]],
                 tracer: Tracer = NULL_TRACER, group_span=None,
                 extras: Optional[Dict[str, dict]] = None) -> None:
        sc = scenarios[idx]
        extra = (extras or {}).get(sc.name)
        ds = tracer.start("dispatch:" + sc.name, kind="dispatch",
                          parent=group_span, cell=sc.name,
                          shard=worker.idx) if tracer.enabled else None
        t0 = time.perf_counter()
        try:
            worker.ensure()
            if worker.generation != worker.stats_gen:
                worker.stats_gen = worker.generation
                worker.stats_seen = {}   # fresh interpreter: from zero
                worker.metrics_seen = {}
            hook = hooks.get(sc.name) or hooks.get(sc.bench)
            job = job_message(sc, runs=runs, warmup=warmup,
                              profile=profile, hook=hook,
                              trace=tracer.context(ds), extra=extra)
            rr, stats, metrics, spans = self._round_trip(worker, job)
        except Exception as e:  # noqa: BLE001 — e.g. spawn ENOMEM: the
            rr, stats, metrics, spans = None, None, None, None  # keep emitting
            reason = f"shard worker {worker.idx} dispatch failed: {e!r}"
        else:
            reason = None if rr is not None else \
                worker.death_reason(self.timeout)
        if rr is None:
            worker.kill()
            metrics_registry().inc("pool_worker_deaths_total")
            rr = RunResult.from_error(sc, reason,
                                      wall_s=time.perf_counter() - t0)
            if extra:
                rr.extra.update(extra)
            stamp_provenance(rr)   # worker never saw it: stamp here
            with self._lock:
                run_stats.scenarios_run += 1
                run_stats.errors += 1
        else:
            rr.wall_s = time.perf_counter() - t0   # incl. dispatch
            delta = stats_delta(stats, worker.stats_seen)
            if delta:
                with self._lock:
                    run_stats.merge(delta)
            if metrics:
                metrics_registry().merge_cumulative(
                    stats_delta(metrics, worker.metrics_seen))
        if ds is not None:
            tracer.ingest(spans, proc=f"shard{worker.idx}")
            ds.set(status=rr.status)
            tracer.finish(ds)
            rr.extra.setdefault("span_trace", tracer.trace_id)
            rr.extra["span_dispatch"] = ds.span_id
        rr.extra["shard"] = worker.idx
        rr.extra["isolated"] = True
        results[idx] = rr
        try:
            if on_result is not None:
                on_result(rr)
        except Exception:  # noqa: BLE001 — a failing store append must
            pass           # not kill the shard; the result is returned

    def _round_trip(self, worker: _Worker, job: dict):
        """Send one job, read its result (which carries the worker's
        cumulative stats, metrics-registry counters, and traced spans);
        all-None when the worker dies or hangs."""
        try:
            worker.send(job)
            msg = worker.recv(self.timeout)
        except (OSError, ValueError):
            return None, None, None, None
        if not msg or msg.get("op") != "result":
            return None, None, None, None
        return (RunResult.from_dict(msg["result"]), msg.get("stats"),
                msg.get("metrics"), msg.get("spans"))
