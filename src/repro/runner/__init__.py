"""Unified benchmark execution subsystem: scenario matrix -> runner -> store.

Public surface:

    Scenario, ScenarioMatrix     declarative execution matrix
    BenchmarkRunner, RunnerStats execution + build/executable reuse + isolation
    ShardScheduler, assign_shards sharded process-pool dispatch (jobs=N)
    RunResult, ResultStore       versioned records, JSONL log + latest pointer
"""
from repro.runner.pool import ShardScheduler, assign_shards
from repro.runner.results import SCHEMA_VERSION, ResultStore, RunResult
from repro.runner.runner import (BenchmarkRunner, RunnerStats,
                                 dryrun_cell_subprocess)
from repro.runner.scenario import MODES, Scenario, ScenarioMatrix

__all__ = ["Scenario", "ScenarioMatrix", "MODES", "BenchmarkRunner",
           "RunnerStats", "ShardScheduler", "assign_shards", "RunResult",
           "ResultStore", "SCHEMA_VERSION", "dryrun_cell_subprocess"]
