"""Unified benchmark execution subsystem: scenario matrix -> runner -> store.

Public surface:

    Scenario, ScenarioMatrix     declarative execution matrix
    BenchmarkRunner, RunnerStats execution + build/executable reuse + isolation
    ShardScheduler, assign_shards sharded process-pool dispatch (jobs=N)
    RunResult, ResultStore       versioned records, JSONL log + latest pointer
    TraceSpec, generate_trace    deterministic serving load profiles
    percentile, latency_summary  shared latency-distribution helpers
"""
from repro.runner.latency import latency_summary, percentile
from repro.runner.pool import ShardScheduler, assign_shards
from repro.runner.results import SCHEMA_VERSION, ResultStore, RunResult
from repro.runner.runner import (BenchmarkRunner, RunnerStats,
                                 dryrun_cell_subprocess)
from repro.runner.scenario import (MODES, SERVE_MODES, STEP_TASKS, TASKS,
                                   Scenario, ScenarioMatrix)
from repro.runner.traces import PROFILES, Request, TraceSpec
from repro.runner.traces import generate as generate_trace

__all__ = ["Scenario", "ScenarioMatrix", "MODES", "SERVE_MODES", "TASKS",
           "STEP_TASKS", "BenchmarkRunner", "RunnerStats", "ShardScheduler",
           "assign_shards", "RunResult", "ResultStore", "SCHEMA_VERSION",
           "dryrun_cell_subprocess", "PROFILES", "Request", "TraceSpec",
           "generate_trace", "percentile", "latency_summary"]
