"""Unified benchmark execution subsystem: scenario matrix -> runner -> store.

Public surface:

    Scenario, ScenarioMatrix     declarative execution matrix
    BenchmarkRunner, RunnerStats execution + build/executable reuse + isolation
    ShardScheduler, assign_shards sharded process-pool dispatch (jobs=N)
    Coordinator, ClusterScheduler multi-host cluster dispatch (cluster=...)
    RunResult, ResultStore       versioned records, JSONL log + latest pointer
    TraceSpec, generate_trace    deterministic serving load profiles
    save_spec, load_spec         recorded traces (trace="file:PATH")
    percentile, latency_summary  shared latency-distribution helpers
"""
from repro.runner.cluster import (ClusterScheduler, Coordinator,
                                  parse_cluster_spec)
from repro.runner.latency import latency_summary, percentile
from repro.runner.pool import ShardScheduler, assign_shards, rank_groups
from repro.runner.results import SCHEMA_VERSION, ResultStore, RunResult
from repro.runner.runner import (BenchmarkRunner, RunnerStats,
                                 dryrun_cell_subprocess)
from repro.runner.scenario import (MODES, SERVE_MODES, STEP_TASKS, TASKS,
                                   Scenario, ScenarioMatrix)
from repro.runner.traces import (PROFILES, Request, TraceSpec, load_spec,
                                 save_spec)
from repro.runner.traces import generate as generate_trace

__all__ = ["Scenario", "ScenarioMatrix", "MODES", "SERVE_MODES", "TASKS",
           "STEP_TASKS", "BenchmarkRunner", "RunnerStats", "ShardScheduler",
           "assign_shards", "rank_groups", "Coordinator", "ClusterScheduler",
           "parse_cluster_spec", "RunResult", "ResultStore", "SCHEMA_VERSION",
           "dryrun_cell_subprocess", "PROFILES", "Request", "TraceSpec",
           "generate_trace", "save_spec", "load_spec", "percentile",
           "latency_summary"]
