"""Unified benchmark execution subsystem: scenario matrix -> runner -> store.

Public surface:

    Scenario, ScenarioMatrix     declarative execution matrix
    BenchmarkRunner, RunnerStats execution + build/executable reuse + isolation
    RunResult, ResultStore       versioned records, JSONL log + latest pointer
"""
from repro.runner.results import SCHEMA_VERSION, ResultStore, RunResult
from repro.runner.runner import (BenchmarkRunner, RunnerStats,
                                 dryrun_cell_subprocess)
from repro.runner.scenario import MODES, Scenario, ScenarioMatrix

__all__ = ["Scenario", "ScenarioMatrix", "MODES", "BenchmarkRunner",
           "RunnerStats", "RunResult", "ResultStore", "SCHEMA_VERSION",
           "dryrun_cell_subprocess"]
