"""The JSONL job/result protocol shared by every worker transport.

One wire format, two transports:

* **pipe** — ``repro.runner.pool`` talks to ``worker --serve`` subprocesses
  over their stdin/stdout pipes (single-host sharded dispatch);
* **socket** — ``repro.runner.cluster`` talks to ``worker --connect``
  processes over TCP (multi-host dispatch), same messages plus a
  registration/heartbeat layer.

Every message is one JSON object per ``\\n``-terminated line.  Kinds
(``msg["op"]``):

    run       dispatcher -> worker   {"op": "run", "scenario": {...},
                                      "runs": R?, "warmup": W?,
                                      "profile": bool, "hook": {...}?,
                                      "cell": i?, "trace": {...}?,
                                      "extra": {...}?}
                                     ``trace`` is a span context
                                     ({"trace_id", "parent"}) — when
                                     present the worker traces the cell
                                     under that parent span and ships
                                     its spans back with the result;
                                     ``extra`` is merged into the
                                     result's extras by the worker
                                     (dispatch-side annotations, e.g.
                                     ``slots_fallback``).
    result    worker -> dispatcher   {"op": "result", "result": <RunResult>,
                                      "stats": <RunnerStats>,
                                      "metrics": {...}?, "cell": i?,
                                      "spans": [...]?}
                                     ``stats`` is the worker's CUMULATIVE
                                     counter snapshot (the dispatcher
                                     delta-merges, see ``stats_delta``);
                                     ``metrics`` is the worker's metrics
                                     registry as flat cumulative counters
                                     (``repro.fleet.metrics
                                     .counters_cumulative``), delta-merged
                                     by the dispatcher with the same
                                     ``stats_delta`` arithmetic into its
                                     own registry; ``cell`` echoes the
                                     job's id so a pipelined dispatcher
                                     can match results to cells; ``spans``
                                     (only when the job carried ``trace``)
                                     is the worker-side span export for
                                     the dispatcher to stitch into its
                                     trace.
    register  worker -> dispatcher   {"op": "register", "host": str,
                                      "capacity": int}   (socket only:
                                     first message after connecting)
    ping      worker -> dispatcher   {"op": "ping"}      (socket only:
                                     heartbeat while a cell runs, so the
                                     coordinator can tell a long compile
                                     from a dead host)
    shutdown  dispatcher -> worker   {"op": "shutdown"}  (socket only;
                                     pipe workers exit on stdin EOF)

``Channel`` is the shared endpoint: line-buffered JSONL over either a
(read fd, write callable) pipe pair or a connected socket, with blocking
``recv`` (timeout-aware) for the sequential pool/worker loops and
non-blocking ``pump`` for the coordinator's select loop.  Sends are
locked, so a worker's heartbeat thread can share the channel with its
job loop.
"""
from __future__ import annotations

import json
import os
import select
import socket
import threading
import time
from typing import Callable, Dict, List, Optional

#: bytes read per syscall when draining a channel
_CHUNK = 1 << 16


def encode(msg: dict) -> bytes:
    """One protocol line (the only framing: ``\\n``-terminated JSON)."""
    return (json.dumps(msg) + "\n").encode()


class LineBuffer:
    """Accumulate raw bytes, yield complete JSON messages."""

    def __init__(self):
        self._buf = b""

    def feed(self, chunk: bytes) -> List[dict]:
        """Parsed messages completed by ``chunk`` (in arrival order).
        Raises ``ValueError`` on a line that is not a JSON object — a
        corrupt transport, not a protocol message."""
        self._buf += chunk
        out: List[dict] = []
        while b"\n" in self._buf:
            line, self._buf = self._buf.split(b"\n", 1)
            if not line.strip():
                continue
            msg = json.loads(line)
            if not isinstance(msg, dict):
                raise ValueError(f"protocol line is not an object: {msg!r}")
            out.append(msg)
        return out


class Channel:
    """One protocol endpoint over a pipe pair or a socket.

    ``eof`` turns True once the peer closes its write side; ``recv``
    returns ``None`` on both timeout and EOF (check ``eof`` to tell them
    apart — the pool treats both as a dead worker, the coordinator
    requeues work only on real EOF/heartbeat loss)."""

    def __init__(self, read_fd: int, write: Callable[[bytes], None], *,
                 sock: Optional[socket.socket] = None):
        self._read_fd = read_fd
        self._write = write
        self._sock = sock
        self._lines = LineBuffer()
        self._pending: List[dict] = []
        self._send_lock = threading.Lock()
        self.eof = False

    @classmethod
    def over_pipes(cls, stdout, stdin) -> "Channel":
        """A subprocess endpoint: read its stdout pipe, write its stdin."""
        def write(data: bytes) -> None:
            stdin.write(data)
            stdin.flush()
        return cls(stdout.fileno(), write)

    @classmethod
    def over_socket(cls, sock: socket.socket) -> "Channel":
        return cls(sock.fileno(), sock.sendall, sock=sock)

    def fileno(self) -> int:
        return self._read_fd

    def send(self, msg: dict) -> None:
        with self._send_lock:
            self._write(encode(msg))

    def pump(self) -> List[dict]:
        """Non-blocking drain: one read syscall, return the messages it
        completed (possibly none).  Call when select() reports the fd
        readable; sets ``eof`` instead of raising when the peer closed."""
        if self.eof:
            return []
        try:
            chunk = os.read(self._read_fd, _CHUNK)
        except OSError:
            chunk = b""
        if not chunk:
            self.eof = True
            return []
        return self._lines.feed(chunk)

    def recv(self, timeout: float) -> Optional[dict]:
        """Blocking: the next message, or None on timeout/EOF."""
        deadline = time.monotonic() + timeout
        while not self._pending:
            if self.eof:
                return None
            left = deadline - time.monotonic()
            if left <= 0:
                return None
            ready, _, _ = select.select([self._read_fd], [], [],
                                        min(left, 1.0))
            if ready:
                self._pending.extend(self.pump())
        return self._pending.pop(0)

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
        self.eof = True


# ---- message construction shared by the dispatchers -----------------------

def job_message(scenario, *, runs: Optional[int], warmup: Optional[int],
                profile: bool, hook=None,
                cell: Optional[int] = None,
                trace: Optional[dict] = None,
                extra: Optional[dict] = None) -> dict:
    """One ``run`` job.  Regression hooks cross the process/host boundary
    as their plain parameters (``slowdown_s``/``leak_bytes``); custom
    ``RegressionHook`` subclasses with dispatcher-process behaviour
    cannot.  ``trace`` is the dispatcher's span context (see module
    docstring); ``extra`` rides to the worker and is merged into the
    result's extras before it is measured/recorded."""
    msg: Dict = {"op": "run", "scenario": scenario.to_dict(),
                 "runs": runs, "warmup": warmup, "profile": profile}
    if hook is not None:
        msg["hook"] = {"slowdown_s": getattr(hook, "slowdown_s", 0.0),
                       "leak_bytes": getattr(hook, "leak_bytes", 0)}
    if cell is not None:
        msg["cell"] = cell
    if trace is not None:
        msg["trace"] = trace
    if extra:
        msg["extra"] = extra
    return msg


def stats_delta(cumulative: Optional[dict],
                seen: Dict[str, int]) -> Dict[str, int]:
    """The new work since the last result from this worker.  Workers ship
    their CUMULATIVE ``RunnerStats`` with every result (no window where a
    completed cell's builds are lost to a dying worker); the dispatcher
    keeps the last snapshot per worker *process* and merges only the
    difference.  Mutates ``seen`` to the new snapshot."""
    if not cumulative:
        return {}
    delta = {k: max(0, v - seen.get(k, 0)) for k, v in cumulative.items()}
    seen.clear()
    seen.update(cumulative)
    return delta
