"""Subprocess worker for isolated scenario execution.

    python -m repro.runner.worker --scenario '{"arch": "gemma-2b", ...}' \
        --runs 3 --json out.json [--slowdown-s S --leak-bytes N]

Runs ONE scenario in this interpreter via an in-process BenchmarkRunner and
writes its RunResult JSON to ``--json``.  The parent (``BenchmarkRunner``
with ``isolate=True``) treats a crash/timeout of this process as an error
record — fault containment per cell, the ``launch/dryrun`` subprocess idiom.
The regression-hook parameters are plain numbers so injected-fault CI runs
can be isolated too.
"""
from __future__ import annotations

import argparse
import json


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", required=True, help="Scenario JSON dict")
    ap.add_argument("--runs", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--slowdown-s", type=float, default=0.0)
    ap.add_argument("--leak-bytes", type=int, default=0)
    ap.add_argument("--json", required=True)
    args = ap.parse_args(argv)

    from repro.core.harness import RegressionHook
    from repro.runner.runner import BenchmarkRunner
    from repro.runner.scenario import Scenario

    scenario = Scenario.from_dict(json.loads(args.scenario))
    hook = None
    if args.slowdown_s or args.leak_bytes:
        hook = RegressionHook(slowdown_s=args.slowdown_s,
                              leak_bytes=args.leak_bytes)
    runner = BenchmarkRunner(runs=args.runs, warmup=args.warmup)
    rr = runner.run(scenario, hook=hook, record=False)
    with open(args.json, "w") as f:
        json.dump(rr.to_dict(), f)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
