"""Subprocess worker for isolated scenario execution.

Three modes, one cell-execution path (``_run_cell``):

Single-shot mode (``BenchmarkRunner(isolate=True)``):

    python -m repro.runner.worker --scenario '{"arch": "gemma-2b", ...}' \
        --runs 3 --warmup 1 --compile-warmup 3 --json out.json \
        [--no-reuse] [--slowdown-s S --leak-bytes N]

Runs ONE scenario in this interpreter via an in-process BenchmarkRunner and
writes ``{"result": <RunResult>, "stats": <RunnerStats>}`` JSON to
``--json``.  The parent treats a crash/timeout of this process as an error
record — fault containment per cell, the ``launch/dryrun`` subprocess idiom.
The full runner measurement config (runs/warmup/compile-warmup/reuse) is
forwarded on the command line so isolated measurements stay comparable with
in-process ones as regression baselines, and the worker's ``RunnerStats``
ride back in the payload so out-of-process builds/compiles stay visible.

Pool mode (``--serve``; the ``run_matrix(..., jobs=N)`` sharded dispatch,
see ``repro.runner.pool``):

    python -m repro.runner.worker --serve --runs 3 --warmup 1 ...

Cluster mode (``--connect``; the ``run_matrix(..., cluster=...)``
multi-host dispatch, see ``repro.runner.cluster``):

    python -m repro.runner.worker --connect HOST:PORT \
        [--host ID] [--capacity N] --runs 3 --warmup 1 ...

NAMING: three different "serve"/"connect" notions meet in this file —
keep them apart:

* ``--serve`` means "serve the *pool protocol*": a persistent worker
  interpreter fed JSONL jobs over stdin/stdout pipes by a same-host
  ``ShardScheduler``.
* ``--connect HOST:PORT`` speaks the SAME job/result protocol
  (``repro.runner.protocol``) over a TCP socket to a cluster
  ``Coordinator`` — possibly on another host.  It registers first
  (``--host`` id, ``--capacity`` max in-flight cells) and heartbeats
  from a side thread so the coordinator can tell a long compile from a
  dead host.
* ``Scenario(task="serve")`` is the serving *workload* — the
  continuous-batching engine in ``repro.launch.serve``.  It is unrelated
  to either flag: both pool and cluster workers can be handed scenarios
  of any task, including ``task="serve"`` cells.

Pool mode processes a *batch* of scenarios: one JSONL request per line on
stdin —

    {"op": "run", "scenario": {...}, "runs": R?, "warmup": W?,
     "hook": {"slowdown_s": S, "leak_bytes": N}?}

— one JSONL reply per request on stdout (``{"op": "result", "result": ...,
"stats": ...}``, the cumulative RunnerStats riding along with every
result), exiting 0 on stdin EOF.  The protocol
stream is the *original* stdout fd, dup'd away before any benchmark code
runs; fd 1 is then pointed at stderr so stray prints from model/measure
code can never corrupt the protocol.  One BenchmarkRunner serves the whole
batch, so the arch-build and compiled-executable caches keep paying off
across the shard's scenarios exactly as they do in-process.  Cluster mode
is the same loop over the socket (jobs additionally carry a ``cell`` id,
echoed back so the coordinator can pipeline), exiting 0 on a ``shutdown``
message or socket EOF.

``--measure-lock PATH`` enables the *measurement fence*: each cell first
does an unfenced warm pass (build + compile + donation threading — the
expensive, contention-tolerant work, free to overlap with other workers),
then takes an exclusive flock on PATH for the short timed loop only.
Two cells' timed loops therefore never overlap — the worst cross-worker
distortion — keeping sharded measurements usable as regression baselines
(see ``runner/pool.py`` for what the fence can and cannot isolate; the
flock only fences workers of ONE host, which is exactly the set sharing
CPUs).  The fenced re-measure reports the warm pass's
compile_us/cache provenance and counts as ONE logical execution in
``RunnerStats``.  Requires the cache (ignored under ``--no-reuse``).

The regression-hook parameters are plain numbers so injected-fault CI runs
can be isolated/sharded/clustered too.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys
import threading

try:
    import fcntl
except ImportError:          # non-POSIX: fence degrades to unfenced runs
    fcntl = None  # type: ignore[assignment]


@contextlib.contextmanager
def _file_lock(path):
    if not path or fcntl is None:
        yield
        return
    fd = os.open(path, os.O_WRONLY | os.O_CREAT, 0o644)
    try:
        fcntl.flock(fd, fcntl.LOCK_EX)
        yield
    finally:
        os.close(fd)


def _build_runner(args):
    from repro.runner.runner import BenchmarkRunner
    return BenchmarkRunner(runs=args.runs, warmup=args.warmup,
                           compile_warmup=args.compile_warmup,
                           reuse=args.reuse)


def _hook_from(slowdown_s: float, leak_bytes: int):
    if not (slowdown_s or leak_bytes):
        return None
    from repro.core.harness import RegressionHook
    return RegressionHook(slowdown_s=slowdown_s, leak_bytes=leak_bytes)


def _run_cell(runner, scenario, hook, runs, warmup, lock_path,
              profile=False, extra=None):
    """One cell, with the measurement fence when a lock path is given:
    warm pass unfenced (build/compile/threading overlap across workers),
    timed loop under the exclusive lock (contention-free measurement)."""
    # serve cells follow the same protocol: the warm pass replays the trace
    # once on a fresh engine (building + compiling unfenced, overlapping
    # other workers), and the fenced re-run replays it on the warm engine
    if not (lock_path and runner.reuse):
        return runner.run(scenario, hook=hook, runs=runs, warmup=warmup,
                          record=False, profile=profile, extra=extra)
    # a profiled warm pass pays the attribution AOT compile here, unfenced
    # (it caches per executable), so the fenced profiled re-measure below
    # never holds the lock through an XLA compile
    warm = runner.run(scenario, runs=1, warmup=0, record=False,
                      profile=profile, extra=extra)
    if warm.status != "ok":
        return warm
    with _file_lock(lock_path):
        rr = runner.run(scenario, hook=hook, runs=runs, warmup=warmup,
                        record=False, profile=profile, extra=extra)
    if rr.status == "ok":
        # the fenced re-measure hit the warm pass's cache: report the
        # cell's true build/compile provenance instead
        rr.compile_us = warm.compile_us
        rr.cache = warm.cache
    # keep the ledger at one logical execution per cell — the warm pass
    # is protocol, not workload
    runner.stats.scenarios_run -= 1
    runner.stats.executable_cache_hits -= 1
    return rr


def _handle_job(runner, msg: dict, args) -> dict:
    """One ``run`` request -> its ``result`` reply (shared by the pool and
    cluster loops).  The cumulative stats ride along with every result:
    one round trip per cell, and no window where a completed cell's
    builds/compiles can be lost to a dying worker.  A job's ``cell`` id is
    echoed back so a pipelining dispatcher can match results to cells."""
    from repro.runner.scenario import Scenario
    scenario = Scenario.from_dict(msg["scenario"])
    hook_params = msg.get("hook") or {}
    hook = _hook_from(hook_params.get("slowdown_s", 0.0),
                      hook_params.get("leak_bytes", 0))
    tctx = msg.get("trace")
    tracer = None
    if tctx:
        # a per-job tracer seeded with the dispatcher's span context: this
        # cell's spans parent to the coordinator-side dispatch span and
        # ship back in the reply (the dispatcher relabels the lane)
        from repro.telemetry.spans import Tracer
        tracer = Tracer(trace_id=tctx.get("trace_id"),
                        proc=f"worker-{os.getpid()}",
                        root_parent=tctx.get("parent") or None)
        runner.tracer = tracer
    try:
        rr = _run_cell(runner, scenario, hook, msg.get("runs"),
                       msg.get("warmup"), args.measure_lock,
                       profile=bool(msg.get("profile") or args.profile),
                       extra=msg.get("extra"))
    finally:
        if tracer is not None:
            from repro.telemetry.spans import NULL_TRACER
            runner.tracer = NULL_TRACER
    from repro.fleet.metrics import registry as metrics_registry
    reply = {"op": "result", "result": rr.to_dict(),
             "stats": runner.stats.to_dict(),
             # this process's metrics registry as flat cumulative counters,
             # delta-merged by the dispatcher exactly like the stats
             "metrics": metrics_registry().counters_cumulative()}
    if tracer is not None:
        reply["spans"] = tracer.export()
    if "cell" in msg:
        reply["cell"] = msg["cell"]
    return reply


def _serve_pool(args) -> int:
    """Pool mode: persistent batch loop — JSONL requests on stdin, replies
    on the original stdout; workload output is rerouted to stderr.  (This
    "serves" the pool protocol; the inference-serving workload is
    ``repro.launch.serve``, dispatched through here like any other task.)"""
    proto = os.fdopen(os.dup(sys.stdout.fileno()), "w", buffering=1)
    os.dup2(sys.stderr.fileno(), sys.stdout.fileno())

    runner = _build_runner(args)
    for line in sys.stdin:
        line = line.strip()
        if not line:
            continue
        msg = json.loads(line)
        reply = _handle_job(runner, msg, args)
        proto.write(json.dumps(reply) + "\n")
        proto.flush()
    return 0


def _serve_cluster(args) -> int:
    """Cluster mode: connect to the coordinator, register (host id +
    capacity), heartbeat from a side thread, and run jobs until a
    ``shutdown`` message or socket EOF.  The protocol lives on the socket,
    so stray workload prints on stdout are harmless here."""
    import socket

    from repro.runner.protocol import Channel

    host, _, port = args.connect.rpartition(":")
    sock = socket.create_connection((host or "127.0.0.1", int(port)),
                                    timeout=30)
    sock.settimeout(None)
    chan = Channel.over_socket(sock)
    host_id = args.host or f"{socket.gethostname()}-{os.getpid()}"
    # floor the ping interval: --heartbeat 0 would busy-loop the side
    # thread into flooding the coordinator
    args.heartbeat = max(0.5, args.heartbeat)
    # register BEFORE the heavy imports (_build_runner pulls in jax), so
    # the coordinator sees this worker — and can plan around it — while
    # the interpreter is still warming up
    # heartbeat rides in the registration so the coordinator can scale its
    # silence bound to THIS worker's ping interval instead of reaping a
    # slow-pinging healthy host mid-compile
    chan.send({"op": "register", "host": host_id,
               "capacity": max(1, args.capacity),
               "heartbeat": args.heartbeat})

    stop = threading.Event()

    def _heartbeat() -> None:
        while not stop.wait(args.heartbeat):
            try:
                chan.send({"op": "ping"})
            except OSError:
                return             # coordinator gone: main loop sees EOF

    beat = threading.Thread(target=_heartbeat, name="heartbeat", daemon=True)
    beat.start()
    runner = _build_runner(args)
    try:
        while True:
            msg = chan.recv(timeout=60.0)
            if msg is None:
                if chan.eof:
                    return 0       # coordinator closed: clean exit
                continue           # idle between batches
            op = msg.get("op")
            if op == "shutdown":
                return 0
            if op != "run":
                continue
            try:
                chan.send(_handle_job(runner, msg, args))
            except OSError:
                return 0           # coordinator gone mid-reply
    finally:
        stop.set()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", help="Scenario JSON dict (single-shot mode)")
    ap.add_argument("--serve", action="store_true",
                    help="pool mode: persistent worker, JSONL requests on "
                         "stdin, replies on stdout (unrelated to the "
                         "task=\"serve\" workload)")
    ap.add_argument("--connect", default="",
                    help="cluster mode: HOST:PORT of a coordinator "
                         "(repro.runner.cluster) to register with and pull "
                         "jobs from over TCP")
    ap.add_argument("--host", default="",
                    help="cluster host id reported at registration and in "
                         "extra['host'] (default: <hostname>-<pid>)")
    ap.add_argument("--capacity", type=int, default=1,
                    help="cluster mode: max in-flight cells the "
                         "coordinator may pipeline to this worker")
    ap.add_argument("--heartbeat", type=float, default=5.0,
                    help="cluster mode: seconds between liveness pings")
    ap.add_argument("--runs", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--compile-warmup", type=int, default=3,
                    help="extra warmup after a fresh compile (parent's setting)")
    ap.add_argument("--no-reuse", dest="reuse", action="store_false",
                    default=True, help="disable build/executable caching")
    ap.add_argument("--profile", action="store_true",
                    help="measured profiling: record extra['prof_*'] "
                         "(timeline + op-class attribution) per cell")
    ap.add_argument("--measure-lock", default="",
                    help="flock path fencing the timed loop (pool/cluster "
                         "modes; fences same-host workers only)")
    ap.add_argument("--slowdown-s", type=float, default=0.0)
    ap.add_argument("--leak-bytes", type=int, default=0)
    ap.add_argument("--json", help="output path (single-shot mode)")
    args = ap.parse_args(argv)

    if args.serve and args.connect:
        ap.error("--serve (pipe pool) and --connect (cluster socket) are "
                 "mutually exclusive transports")
    if args.serve:
        return _serve_pool(args)
    if args.connect:
        return _serve_cluster(args)
    if not (args.scenario and args.json):
        ap.error("single-shot mode needs --scenario and --json "
                 "(or use --serve / --connect)")

    from repro.runner.scenario import Scenario

    scenario = Scenario.from_dict(json.loads(args.scenario))
    runner = _build_runner(args)
    rr = runner.run(scenario, hook=_hook_from(args.slowdown_s, args.leak_bytes),
                    record=False, profile=args.profile)
    with open(args.json, "w") as f:
        json.dump({"result": rr.to_dict(), "stats": runner.stats.to_dict()}, f)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
