"""Subprocess worker for isolated scenario execution.

Single-shot mode (``BenchmarkRunner(isolate=True)``):

    python -m repro.runner.worker --scenario '{"arch": "gemma-2b", ...}' \
        --runs 3 --warmup 1 --compile-warmup 3 --json out.json \
        [--no-reuse] [--slowdown-s S --leak-bytes N]

Runs ONE scenario in this interpreter via an in-process BenchmarkRunner and
writes ``{"result": <RunResult>, "stats": <RunnerStats>}`` JSON to
``--json``.  The parent treats a crash/timeout of this process as an error
record — fault containment per cell, the ``launch/dryrun`` subprocess idiom.
The full runner measurement config (runs/warmup/compile-warmup/reuse) is
forwarded on the command line so isolated measurements stay comparable with
in-process ones as regression baselines, and the worker's ``RunnerStats``
ride back in the payload so out-of-process builds/compiles stay visible.

Pool mode (``--serve``; the ``run_matrix(..., jobs=N)`` sharded dispatch,
see ``repro.runner.pool``):

    python -m repro.runner.worker --serve --runs 3 --warmup 1 ...

NAMING: the ``--serve`` flag means "serve the pool protocol" — a
persistent worker interpreter — and predates the serving *workload*
(``Scenario(task="serve")``, the continuous-batching engine in
``repro.launch.serve``).  The two are unrelated: a pool-mode worker can
be handed scenarios of any task, including ``task="serve"`` cells.

A persistent interpreter processing a *batch* of scenarios: one JSONL
request per line on stdin —

    {"op": "run", "scenario": {...}, "runs": R?, "warmup": W?,
     "hook": {"slowdown_s": S, "leak_bytes": N}?}

— one JSONL reply per request on stdout (``{"op": "result", "result": ...,
"stats": ...}``, the cumulative RunnerStats riding along with every
result), exiting 0 on stdin EOF.  The protocol
stream is the *original* stdout fd, dup'd away before any benchmark code
runs; fd 1 is then pointed at stderr so stray prints from model/measure
code can never corrupt the protocol.  One BenchmarkRunner serves the whole
batch, so the arch-build and compiled-executable caches keep paying off
across the shard's scenarios exactly as they do in-process.

``--measure-lock PATH`` enables the *measurement fence*: each cell first
does an unfenced warm pass (build + compile + donation threading — the
expensive, contention-tolerant work, free to overlap with other workers),
then takes an exclusive flock on PATH for the short timed loop only.
Two cells' timed loops therefore never overlap — the worst cross-worker
distortion — keeping sharded measurements usable as regression baselines
(see ``runner/pool.py`` for what the fence can and cannot isolate).
The fenced re-measure reports the warm pass's
compile_us/cache provenance and counts as ONE logical execution in
``RunnerStats``.  Requires the cache (ignored under ``--no-reuse``).

The regression-hook parameters are plain numbers so injected-fault CI runs
can be isolated/sharded too.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys

try:
    import fcntl
except ImportError:          # non-POSIX: fence degrades to unfenced runs
    fcntl = None  # type: ignore[assignment]


@contextlib.contextmanager
def _file_lock(path):
    if not path or fcntl is None:
        yield
        return
    fd = os.open(path, os.O_WRONLY | os.O_CREAT, 0o644)
    try:
        fcntl.flock(fd, fcntl.LOCK_EX)
        yield
    finally:
        os.close(fd)


def _build_runner(args):
    from repro.runner.runner import BenchmarkRunner
    return BenchmarkRunner(runs=args.runs, warmup=args.warmup,
                           compile_warmup=args.compile_warmup,
                           reuse=args.reuse)


def _hook_from(slowdown_s: float, leak_bytes: int):
    if not (slowdown_s or leak_bytes):
        return None
    from repro.core.harness import RegressionHook
    return RegressionHook(slowdown_s=slowdown_s, leak_bytes=leak_bytes)


def _run_cell(runner, scenario, hook, runs, warmup, lock_path,
              profile=False):
    """One cell, with the measurement fence when a lock path is given:
    warm pass unfenced (build/compile/threading overlap across workers),
    timed loop under the exclusive lock (contention-free measurement)."""
    # serve cells follow the same protocol: the warm pass replays the trace
    # once on a fresh engine (building + compiling unfenced, overlapping
    # other workers), and the fenced re-run replays it on the warm engine
    if not (lock_path and runner.reuse):
        return runner.run(scenario, hook=hook, runs=runs, warmup=warmup,
                          record=False, profile=profile)
    # a profiled warm pass pays the attribution AOT compile here, unfenced
    # (it caches per executable), so the fenced profiled re-measure below
    # never holds the lock through an XLA compile
    warm = runner.run(scenario, runs=1, warmup=0, record=False,
                      profile=profile)
    if warm.status != "ok":
        return warm
    with _file_lock(lock_path):
        rr = runner.run(scenario, hook=hook, runs=runs, warmup=warmup,
                        record=False, profile=profile)
    if rr.status == "ok":
        # the fenced re-measure hit the warm pass's cache: report the
        # cell's true build/compile provenance instead
        rr.compile_us = warm.compile_us
        rr.cache = warm.cache
    # keep the ledger at one logical execution per cell — the warm pass
    # is protocol, not workload
    runner.stats.scenarios_run -= 1
    runner.stats.executable_cache_hits -= 1
    return rr


def _serve_pool(args) -> int:
    """Pool mode: persistent batch loop — JSONL requests on stdin, replies
    on the original stdout; workload output is rerouted to stderr.  (This
    "serves" the pool protocol; the inference-serving workload is
    ``repro.launch.serve``, dispatched through here like any other task.)"""
    proto = os.fdopen(os.dup(sys.stdout.fileno()), "w", buffering=1)
    os.dup2(sys.stderr.fileno(), sys.stdout.fileno())

    from repro.runner.scenario import Scenario

    runner = _build_runner(args)
    for line in sys.stdin:
        line = line.strip()
        if not line:
            continue
        msg = json.loads(line)
        scenario = Scenario.from_dict(msg["scenario"])
        hook_params = msg.get("hook") or {}
        hook = _hook_from(hook_params.get("slowdown_s", 0.0),
                          hook_params.get("leak_bytes", 0))
        rr = _run_cell(runner, scenario, hook, msg.get("runs"),
                       msg.get("warmup"), args.measure_lock,
                       profile=bool(msg.get("profile") or args.profile))
        # cumulative stats ride along with every result: one round trip
        # per cell, and no window where a completed cell's builds/compiles
        # can be lost to a dying worker
        reply = {"op": "result", "result": rr.to_dict(),
                 "stats": runner.stats.to_dict()}
        proto.write(json.dumps(reply) + "\n")
        proto.flush()
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", help="Scenario JSON dict (single-shot mode)")
    ap.add_argument("--serve", action="store_true",
                    help="pool mode: persistent worker, JSONL requests on "
                         "stdin, replies on stdout (unrelated to the "
                         "task=\"serve\" workload)")
    ap.add_argument("--runs", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--compile-warmup", type=int, default=3,
                    help="extra warmup after a fresh compile (parent's setting)")
    ap.add_argument("--no-reuse", dest="reuse", action="store_false",
                    default=True, help="disable build/executable caching")
    ap.add_argument("--profile", action="store_true",
                    help="measured profiling: record extra['prof_*'] "
                         "(timeline + op-class attribution) per cell")
    ap.add_argument("--measure-lock", default="",
                    help="flock path fencing the timed loop (serve mode)")
    ap.add_argument("--slowdown-s", type=float, default=0.0)
    ap.add_argument("--leak-bytes", type=int, default=0)
    ap.add_argument("--json", help="output path (single-shot mode)")
    args = ap.parse_args(argv)

    if args.serve:
        return _serve_pool(args)
    if not (args.scenario and args.json):
        ap.error("single-shot mode needs --scenario and --json (or use --serve)")

    from repro.runner.scenario import Scenario

    scenario = Scenario.from_dict(json.loads(args.scenario))
    runner = _build_runner(args)
    rr = runner.run(scenario, hook=_hook_from(args.slowdown_s, args.leak_bytes),
                    record=False, profile=args.profile)
    with open(args.json, "w") as f:
        json.dump({"result": rr.to_dict(), "stats": runner.stats.to_dict()}, f)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
