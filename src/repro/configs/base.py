"""Model / shape configuration registry.

One ``ModelConfig`` per assigned architecture (exact sizes from the
assignment table) plus a ``reduced()`` variant per family used by CPU smoke
tests and the measured (wall-clock) benchmark paths.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shapes (assigned): every LM arch is paired with these four cells.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    activation: str = "silu"
    glu: bool = True
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    pos_embed: str = "rope"      # rope | learned | none
    qk_norm: bool = False
    softmax_scale: Optional[float] = None
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    tie_embeddings: bool = True
    embed_scale: bool = False    # gemma-style sqrt(d_model) embedding scale

    # attention pattern
    local_window: int = 0        # >0: local (sliding window) attention layers
    global_every: int = 0        # 0: all global; N: every Nth layer is global
    max_position: int = 1 << 20

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 0          # dispatch groups; 0 -> one per data shard

    # MLA
    use_mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2)
    d_state: int = 0
    ssm_headdim: int = 64
    expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256
    ssm_ngroups: int = 1

    # hybrid (recurrentgemma)
    lru_width: int = 0
    pattern_rec: int = 0         # recurrent layers per attention layer
    gate_blocks: int = 0         # RG-LRU block-diagonal gates (Griffin); 0=dense

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500

    # vlm (paligemma)
    n_prefix: int = 0            # image patch tokens prepended

    # numerics / execution
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    attn_chunk: int = 1024
    remat: str = "full"          # none | full | dots
    scan_layers: bool = True
    use_pallas: bool = False     # Pallas kernels on TPU; XLA ref path on CPU

    # beyond-paper optimization knobs (§Perf; False/0 = paper-faithful baseline)
    opt_bf16_probs: bool = False   # bf16 attention score/prob traffic (fp32 accum)
    opt_ce_chunk: int = 0          # chunked cross-entropy: seq-chunk size (0=off)
    opt_gate_bf16: bool = False    # RG-LRU gate einsums in bf16, output-sharded

    # metadata
    source: str = ""
    domain: str = "NLP"

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def layer_kind(self, i: int) -> str:
        """'global' | 'local' attention for layer i (LM archs)."""
        if self.local_window <= 0:
            return "global"
        if self.global_every <= 0:
            return "local"
        return "global" if (i % self.global_every == self.global_every - 1) else "local"

    def reduced(self, **kw) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests and measured benches."""
        small = dict(
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 6),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=32,
            d_ff=256,
            vocab=512,
            attn_chunk=64,
            max_position=4096,
        )
        if self.n_experts:
            small.update(n_experts=4, top_k=min(self.top_k, 2), d_ff_expert=64,
                         n_shared_experts=min(self.n_shared_experts, 1),
                         first_dense_layers=min(self.first_dense_layers, 1))
        if self.use_mla:
            small.update(kv_lora=32, q_lora=48, qk_nope_dim=16, qk_rope_dim=16,
                         v_head_dim=16, head_dim=32)
        if self.d_state:
            small.update(d_state=16, ssm_headdim=16, ssm_chunk=32)
        if self.lru_width:
            small.update(lru_width=128)
        if self.local_window:
            small.update(local_window=64)
        if self.global_every:
            small.update(global_every=2)  # 1 local : 1 global, 2 groups
        if self.n_enc_layers:
            small.update(n_enc_layers=2, enc_seq=32)
        if self.n_prefix:
            small.update(n_prefix=8)
        small.update(kw)
        return dataclasses.replace(self, name=self.name + "-reduced", **small)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCHS: Dict[str, ModelConfig] = {}

# Which archs run the long_500k cell (sub-quadratic / bounded-cache only,
# per the assignment; see DESIGN.md §Arch-applicability).
LONG_CONTEXT_ARCHS = {"mamba2-2.7b", "recurrentgemma-9b", "gemma3-12b", "mixtral-8x7b"}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> Tuple[str, ...]:
    return tuple(sorted(ARCHS))


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(applicable, reason-if-not) for an (arch, shape) cell."""
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_ARCHS:
        return False, "pure full-attention arch: 512k dense KV decode skipped (DESIGN.md)"
    return True, ""
