from repro.configs.base import (  # noqa: F401
    ARCHS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_arch,
    get_shape,
    list_archs,
    register_arch,
    shape_applicable,
)
# Importing the per-arch modules registers them.
from repro.configs import (  # noqa: F401
    gemma_2b,
    internlm2_20b,
    nemotron_4_15b,
    gemma3_12b,
    deepseek_v2_236b,
    mixtral_8x7b,
    whisper_large_v3,
    paligemma_3b,
    mamba2_2p7b,
    recurrentgemma_9b,
)
