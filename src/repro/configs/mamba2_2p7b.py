"""mamba2-2.7b [arXiv:2405.21060; unverified] — attention-free SSD."""
from repro.configs.base import ModelConfig, register_arch

MAMBA2_2P7B = register_arch(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,               # attention-free
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    activation="silu",
    glu=False,
    rope_theta=0.0,
    pos_embed="none",
    norm_eps=1e-5,
    tie_embeddings=True,
    d_state=128,
    ssm_headdim=64,          # d_inner = 2*2560 = 5120 -> 80 SSD heads
    expand=2,
    conv_width=4,
    ssm_chunk=256,
    source="arXiv:2405.21060; unverified",
    domain="NLP",
))
