"""gemma-2b [arXiv:2403.08295; hf] — dense, GeGLU, head_dim=256, MQA."""
from repro.configs.base import ModelConfig, register_arch

GEMMA_2B = register_arch(ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,           # MQA
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    activation="gelu_tanh",
    glu=True,               # GeGLU
    rope_theta=10000.0,
    tie_embeddings=True,
    embed_scale=True,
    source="arXiv:2403.08295; hf",
    domain="NLP",
))
