"""deepseek-v2-236b [arXiv:2405.04434; hf] — MLA kv_lora=512, 2 shared + 160 routed top-6."""
from repro.configs.base import ModelConfig, register_arch

DEEPSEEK_V2_236B = register_arch(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: logical heads; cache is the 512-dim latent
    head_dim=192,            # qk_nope(128) + qk_rope(64)
    d_ff=12288,              # first dense layer FFN
    vocab=102400,
    activation="silu",
    glu=True,
    rope_theta=10000.0,
    tie_embeddings=False,
    # MoE
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    d_ff_expert=1536,
    first_dense_layers=1,
    # MLA
    use_mla=True,
    kv_lora=512,
    q_lora=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    source="arXiv:2405.04434; hf",
    domain="NLP",
))
