"""whisper-large-v3 [arXiv:2212.04356; unverified] — enc-dec; conv frontend stubbed.

``input_specs()`` provides precomputed (B, 1500, d_model) frame embeddings
per the assignment; the benchmark exercises the transformer backbone only.
"""
from repro.configs.base import ModelConfig, register_arch

WHISPER_LARGE_V3 = register_arch(ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,             # decoder layers
    n_enc_layers=32,
    enc_seq=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,           # MHA
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    activation="gelu",
    glu=False,
    rope_theta=0.0,
    pos_embed="learned",
    norm_eps=1e-5,
    tie_embeddings=True,
    max_position=32768,      # assigned decode shapes exceed Whisper's 448
    source="arXiv:2212.04356; unverified",
    domain="Speech",
))
