"""mixtral-8x7b [arXiv:2401.04088; hf] — 8 experts top-2, sliding-window attention."""
from repro.configs.base import ModelConfig, register_arch

MIXTRAL_8X7B = register_arch(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    activation="silu",
    glu=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
    local_window=4096,       # SWA on every layer
    global_every=0,
    # MoE
    n_experts=8,
    top_k=2,
    d_ff_expert=14336,
    source="arXiv:2401.04088; hf",
    domain="NLP",
))
