"""nemotron-4-15b [arXiv:2402.16819; unverified] — dense GQA, squared-ReLU MLP."""
from repro.configs.base import ModelConfig, register_arch

NEMOTRON_4_15B = register_arch(ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=256000,
    activation="sq_relu",
    glu=False,              # squared-ReLU, no gate
    rope_theta=10000.0,
    tie_embeddings=False,
    source="arXiv:2402.16819; unverified",
    domain="NLP",
))
