"""recurrentgemma-9b [arXiv:2402.19427; unverified] — RG-LRU + local attention, 2:1."""
from repro.configs.base import ModelConfig, register_arch

RECURRENTGEMMA_9B = register_arch(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,             # pattern (rec, rec, attn) x 12 + 2 rec
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,            # MQA on attention layers
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    activation="gelu_tanh",
    glu=True,
    rope_theta=10000.0,
    tie_embeddings=True,
    embed_scale=True,
    local_window=2048,       # attention layers use a 2k local window
    lru_width=4096,
    pattern_rec=2,           # 2 recurrent : 1 attention
    gate_blocks=16,          # Griffin block-diagonal RG-LRU gates
    conv_width=4,
    source="arXiv:2402.19427; unverified",
    domain="NLP",
))
