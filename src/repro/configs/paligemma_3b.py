"""paligemma-3b [arXiv:2407.07726; hf] — SigLIP (stub) + gemma-2b backbone.

The SigLIP tower is stubbed per the assignment: ``input_specs()`` provides
256 precomputed patch embeddings which the model prepends (prefix-LM mask).
"""
from repro.configs.base import ModelConfig, register_arch

PALIGEMMA_3B = register_arch(ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,            # MQA (gemma backbone)
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    activation="gelu_tanh",
    glu=True,
    rope_theta=10000.0,
    tie_embeddings=True,
    embed_scale=True,
    n_prefix=256,
    source="arXiv:2407.07726; hf",
    domain="Multimodal",
))
