"""gemma3-12b [hf:google/gemma-3-1b-pt; unverified] — 5:1 local:global, 128k."""
from repro.configs.base import ModelConfig, register_arch

GEMMA3_12B = register_arch(ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    activation="gelu_tanh",
    glu=True,
    rope_theta=1000000.0,
    qk_norm=True,
    tie_embeddings=True,
    embed_scale=True,
    local_window=1024,
    global_every=6,         # 5 local : 1 global
    max_position=1 << 20,   # 128k trained; lowered structurally to 512k decode
    source="hf:google/gemma-3-1b-pt; unverified",
    domain="NLP",
))
