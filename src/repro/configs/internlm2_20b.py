"""internlm2-20b [arXiv:2403.17297; hf] — dense, GQA kv=8, SwiGLU."""
from repro.configs.base import ModelConfig, register_arch

INTERNLM2_20B = register_arch(ModelConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92544,
    activation="silu",
    glu=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
    source="arXiv:2403.17297; hf",
    domain="NLP",
))
