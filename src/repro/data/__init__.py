from repro.data.pipeline import (  # noqa: F401
    DataConfig, SyntheticTokenDataset, make_batch_specs, prefetch_iterator,
)
