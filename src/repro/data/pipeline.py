"""Deterministic synthetic token pipeline with resume and host sharding.

TorchBench's discipline is that the *measured region excludes data loading*
(paper Listing 1): batches are device-resident before the step.  This module
provides exactly that substrate: a deterministic, seekable token stream
(Zipf-distributed over the vocab, per-step keyed, so step N's batch is
identical across restarts — required for exact fault-tolerant resume), a
multi-host shard reader, and a double-buffered device prefetcher.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    n_hosts: int = 1
    host_id: int = 0


class SyntheticTokenDataset:
    """Deterministic, seekable synthetic corpus.

    ``batch_at(step)`` is a pure function of (seed, step, host shard): exact
    restart/resume follows for free, and straggler re-dispatch (the runtime
    may re-issue a step on a different host) never changes the data.
    """

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0, (cfg.global_batch, cfg.n_hosts)
        self.cfg = cfg
        self.host_batch = cfg.global_batch // cfg.n_hosts
        # precompute the Zipf CDF once (vocab can be 256k: keep it np)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        w = ranks ** (-cfg.zipf_a)
        self._cdf = np.cumsum(w) / w.sum()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        u = rng.random((self.host_batch, cfg.seq_len + 1))
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        toks = np.clip(toks, 0, cfg.vocab - 1)
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_specs(cfg, shape, *, include_labels: bool = False) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for a model-input batch (see launch.dryrun.input_specs)."""
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        specs["patch_embeds"] = jax.ShapeDtypeStruct((B, cfg.n_prefix, cfg.d_model), jnp.float32)
    return specs


def prefetch_iterator(it: Iterator, shardings: Optional[Any] = None, depth: int = 2):
    """Double-buffered host->device prefetch (device_put ahead of consumption)."""
    import collections
    buf = collections.deque()

    def put(batch):
        if shardings is None:
            return jax.tree.map(jnp.asarray, batch)
        return jax.tree.map(lambda x, s: jax.device_put(x, s), batch, shardings)

    for batch in it:
        buf.append(put(batch))
        if len(buf) >= depth:
            yield buf.popleft()
    while buf:
        yield buf.popleft()
