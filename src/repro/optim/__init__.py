from repro.optim.adamw import (  # noqa: F401
    OptState, adamw_init, adamw_update, opt_state_defs,
)
from repro.optim.schedule import cosine_schedule, linear_warmup  # noqa: F401
from repro.optim.compression import compress_grads, decompress_grads  # noqa: F401
