"""AdamW with fused (single-fusion) update, fp32 state, global-norm clipping.

The update body is a single ``jax.tree.map`` over the parameter pytree so XLA
emits one fused elementwise kernel per leaf — the JAX analogue of the
``torch._foreach_*`` fix the paper upstreamed (TorchBench §4.1.1: zero_grad's
per-tensor kernel storm).  Optimizer state is declared via ParamDefs so it
inherits each parameter's sharding (ZeRO-style: state is sharded exactly like
the FSDP-sharded weights — never replicated).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef, _is_def


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def opt_state_defs(param_defs) -> OptState:
    """ParamDef tree for the optimizer state (fp32 moments, param sharding)."""
    def f(d: ParamDef) -> ParamDef:
        return ParamDef(d.shape, d.axes, jnp.float32, "zeros")

    mu = jax.tree.map(f, param_defs, is_leaf=_is_def)
    nu = jax.tree.map(f, param_defs, is_leaf=_is_def)
    return OptState(step=ParamDef((), (), jnp.int32, "zeros"), mu=mu, nu=nu)


def adamw_init(params) -> OptState:
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(z, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state: OptState, cfg: AdamWConfig,
                 lr: Optional[jax.Array] = None):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr_t = (lr if lr is not None else cfg.lr)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm}
