"""Gradient compression for the cross-pod all-reduce.

Two composable schemes, both applied *before* the data-parallel reduction so
the wire format (not the math) shrinks:

* bf16 compression: cast fp32 grads to bf16 for the all-reduce and
  re-promote (2x fewer collective bytes; the roofline's collective term).
* int8 blockwise quantization with error feedback: per-block absmax scaling;
  the residual is carried to the next step so the scheme is unbiased in the
  long run (1-bit-Adam-style EF).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def compress_grads(grads, scheme: str, error: Optional[Any] = None):
    """-> (wire_tree, new_error).  wire_tree is what crosses the network."""
    if scheme == "none":
        return grads, error
    if scheme == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), error

    if scheme == "int8_ef":
        if error is None:
            error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

        def q(g, e):
            g = g.astype(jnp.float32) + e
            flat = g.reshape(-1)
            pad = (-flat.size) % BLOCK
            fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
            scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
            qv = jnp.clip(jnp.round(fp / jnp.maximum(scale, 1e-12)), -127, 127).astype(jnp.int8)
            deq = (qv.astype(jnp.float32) * scale).reshape(-1)[: flat.size].reshape(g.shape)
            return (qv, scale.astype(jnp.float32)), g - deq

        leaves, treedef = jax.tree.flatten(grads)
        errs = jax.tree.leaves(error)
        out = [q(g, e) for g, e in zip(leaves, errs)]
        wire = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_err = jax.tree.unflatten(treedef, [o[1] for o in out])
        return wire, new_err
    raise ValueError(scheme)


def decompress_grads(wire, scheme: str, like=None):
    if scheme == "none":
        return wire
    if scheme == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.float32), wire)
    if scheme == "int8_ef":
        def dq(pair, ref):
            qv, scale = pair
            deq = (qv.astype(jnp.float32) * scale).reshape(-1)[: ref.size]
            return deq.reshape(ref.shape)
        leaves_like = jax.tree.leaves(like)
        flat, treedef = jax.tree.flatten(wire, is_leaf=lambda x: isinstance(x, tuple))
        return jax.tree.unflatten(treedef, [dq(p, r) for p, r in zip(flat, leaves_like)])
    raise ValueError(scheme)
