"""Pallas TPU kernels for the compute hot spots.

Each kernel package has:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (interpret=True off-TPU)
  ref.py    — pure-jnp oracle used by the model code's XLA path and tests
"""
from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
from repro.kernels.ssd.ops import ssd  # noqa: F401
from repro.kernels.rglru.ops import rglru  # noqa: F401
