"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

The SSD duality splits the linear recurrence into an intra-chunk quadratic
part (chunk x chunk matmuls — MXU work) and an inter-chunk state recurrence
(rank-1 updates carried in VMEM scratch).  The CUDA reference keeps state in
registers across a persistent CTA; the TPU adaptation instead exploits the
sequential innermost grid dimension: state (P x N per head) lives in VMEM
scratch and carries across chunk iterations.

Grid: (B*H, n_chunks) — chunks execute sequentially per (batch, head).
Block shapes: x (chunk, P), dt (chunk, 1), B/C (chunk, N); chunk is a
multiple of 8 sublanes, P/N multiples of 128 lanes on real hardware (the
assigned mamba2-2.7b has P=64, N=128 — P=64 packs two heads per lane tile in
a production variant; kept simple here).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.validate import resolve_interpret, validate_block


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0].astype(jnp.float32)        # (L, 1)
    a = a_ref[0, 0]                           # scalar A (negative)
    bm = b_ref[0].astype(jnp.float32)         # (L, N)
    cm = c_ref[0].astype(jnp.float32)         # (L, N)

    da = dt * a                                # (L, 1) log-decay
    cum = jnp.cumsum(da, axis=0)               # (L, 1)
    # intra-chunk: w[i,j] = exp(cum_i - cum_j) * (C_i . B_j), j <= i
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (L, L)
    seg = cum - cum.T                          # (L, L) cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(jj <= ii, jnp.exp(seg) * scores, 0.0)
    xdt = x * dt                               # (L, P)
    y_intra = jax.lax.dot_general(w, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # inter-chunk: y_i += exp(cum_i) * C_i . state
    state = state_scr[...]                     # (N, P)
    y_inter = jnp.exp(cum) * jax.lax.dot_general(
        cm, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)
    # state' = exp(cum_L) * state + sum_j exp(cum_L - cum_j) B_j (x_j dt_j)
    decay_end = jnp.exp(cum[-1:] - cum)        # (L, 1)
    upd = jax.lax.dot_general(bm * decay_end, xdt, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)   # (N, P)
    state_scr[...] = jnp.exp(cum[-1, 0]) * state + upd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_bh(x, dt, a, bm, cm, *, chunk: int = 128,
           interpret: Optional[bool] = None):
    """x (BH, S, P), dt (BH, S, 1), a (BH, 1), bm/cm (BH, S, N) -> y (BH, S, P).

    The carried state scratch makes the chunk grid sequential, so S must
    be a multiple of chunk — validated with a clear error (``ops.ssd``
    pads with identity steps first).  ``interpret=None`` auto-detects,
    uniformly with the flash/rglru kernels.
    """
    BH, S, P = x.shape
    N = bm.shape[-1]
    validate_block("ssd", "S", S, "chunk", chunk, divides=True)
    interpret = resolve_interpret(interpret)
    nc = S // chunk
    kern = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, bm, cm)
