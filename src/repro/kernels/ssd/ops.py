"""Public SSD op: (B, S, H, P) model layout -> kernel layout + padding."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_bh


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: Optional[bool] = None):
    """Model-layout SSD: x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N).

    Matches repro.models.ssm.ssd_chunked / ssd_sequential (zero init state).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    L = min(chunk, S)
    pad = (L - S % L) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, Sp, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, Sp, 1)
    af = jnp.broadcast_to(A[None, :], (B, H)).reshape(B * H, 1)
    bf = jnp.repeat(Bm[:, None], H, axis=1).reshape(B * H, Sp, N)
    cf = jnp.repeat(Cm[:, None], H, axis=1).reshape(B * H, Sp, N)
    y = ssd_bh(xf, dtf, af, bf, cf, chunk=L, interpret=interpret)
    return y.reshape(B, H, Sp, P).transpose(0, 2, 1, 3)[:, :S]
