"""Public SSD op: (B, S, H, P) model layout -> kernel layout + padding."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_bh
from repro.kernels.validate import dtype_name, validate_block


def _tuned_chunk(S: int, P: int, N: int, dtype):
    """Tuning-DB lookup keyed on the *unpadded* (S, P, N) signature (None
    on miss or if a stale entry no longer validates as a bound)."""
    from repro.tuning.db import tuned_params

    t = tuned_params("ssd", f"S{S},P{P},N{N}", dtype_name(dtype))
    if not t:
        return None
    try:
        return validate_block("ssd", "S", S, "chunk", t["chunk"])
    except (KeyError, ValueError):
        return None


def ssd(x, dt, A, Bm, Cm, *, chunk: Optional[int] = None,
        interpret: Optional[bool] = None):
    """Model-layout SSD: x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N).

    Matches repro.models.ssm.ssd_chunked / ssd_sequential (zero init state).

    ``chunk`` defaults to ``None``: the tuning DB is consulted for this
    (shape, dtype) at trace time, falling back to ``min(128, S)``.  An
    explicit chunk is validated as a bound (``1 <= chunk <= S``) and S is
    padded up to a multiple (identity steps), so the kernel's
    divisibility requirement always holds; an invalid chunk raises,
    never clamps.  ``interpret=None`` resolves in the kernel layer.
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if chunk is None:
        chunk = _tuned_chunk(S, P, N, x.dtype)
    if chunk is None:
        L = min(128, S)
    else:
        L = validate_block("ssd", "S", S, "chunk", chunk)
    pad = (L - S % L) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, Sp, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, Sp, 1)
    af = jnp.broadcast_to(A[None, :], (B, H)).reshape(B * H, 1)
    bf = jnp.repeat(Bm[:, None], H, axis=1).reshape(B * H, Sp, N)
    cf = jnp.repeat(Cm[:, None], H, axis=1).reshape(B * H, Sp, N)
    y = ssd_bh(xf, dtf, af, bf, cf, chunk=L, interpret=interpret)
    return y.reshape(B, H, Sp, P).transpose(0, 2, 1, 3)[:, :S]
