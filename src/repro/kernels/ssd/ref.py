"""Oracle for the SSD kernel: the sequential per-token recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, bm, cm):
    """x (BH,S,P), dt (BH,S,1), a (BH,1), bm/cm (BH,S,N) -> (BH,S,P) fp32-exact."""
    BH, S, P = x.shape
    N = bm.shape[-1]

    def per_bh(xb, dtb, ab, bb, cb):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            decay = jnp.exp(dtt * ab[0])               # scalar
            h = decay * h + jnp.outer(bt, xt * dtt)    # (N, P)
            y = ct @ h                                 # (P,)
            return h, y
        h0 = jnp.zeros((N, P), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xb.astype(jnp.float32), dtb[:, 0].astype(jnp.float32),
                                        bb.astype(jnp.float32), cb.astype(jnp.float32)))
        return ys

    return jax.vmap(per_bh)(x, dt, a, bm, cm).astype(x.dtype)
