from repro.kernels.rglru.ops import rglru  # noqa: F401
