"""RG-LRU blocked-scan Pallas TPU kernel.

A diagonal gated linear recurrence h_t = a_t h_{t-1} + b_t.  The TPU
formulation avoids a per-token sequential loop: within a time block of
length L the solution is

    h_i = exp(cum_i) * h_prev + sum_{j<=i} exp(cum_i - cum_j) * b_j

computed as an (L x L x lane-tile) masked decay-weighted reduction (VPU
work, vectorized over the feature lanes); the carried state h_prev lives in
VMEM scratch across the sequential block grid dimension.  L is kept small
(16-32) so the L^2 term stays in VMEM and the exp(cum_i - cum_j) differences
stay in fp32 range.

Grid: (B, n_feature_tiles, n_time_blocks) — time innermost (sequential).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.validate import resolve_interpret, validate_block


def _rglru_kernel(a_ref, b_ref, h_ref, state_scr, *, block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    a = a_ref[0].astype(jnp.float32)          # (L, D)
    b = b_ref[0].astype(jnp.float32)          # (L, D)
    log_a = jnp.log(jnp.maximum(a, 1e-37))
    cum = jnp.cumsum(log_a, axis=0)           # (L, D)
    # decay(i, j) = exp(cum_i - cum_j) for j <= i  (per feature lane)
    seg = cum[:, None, :] - cum[None, :, :]   # (L, L, D)
    ii = jax.lax.broadcasted_iota(jnp.int32, (block_t, block_t, 1), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (block_t, block_t, 1), 1)
    w = jnp.where(jj <= ii, jnp.exp(seg), 0.0)
    h = jnp.sum(w * b[None, :, :], axis=1)    # (L, D)
    h = h + jnp.exp(cum) * state_scr[...]
    h_ref[0] = h.astype(h_ref.dtype)
    state_scr[...] = h[-1]


@functools.partial(jax.jit, static_argnames=("block_t", "block_d", "interpret"))
def rglru_scan_kernel(a, b, *, block_t: int = 16, block_d: int = 128,
                      interpret: Optional[bool] = None):
    """a, b (B, S, D) -> h (B, S, D); h_t = a_t h_{t-1} + b_t, h_0 = b_0.

    The carried state scratch makes the time grid sequential, so blocks
    must divide their dimensions exactly — validated with a clear error
    (``ops.rglru`` pads to a multiple first; direct callers and tuning
    candidates must pass dividing blocks).  ``interpret=None``
    auto-detects, uniformly with the flash/ssd kernels.
    """
    B, S, D = a.shape
    validate_block("rglru", "S", S, "block_t", block_t, divides=True)
    validate_block("rglru", "D", D, "block_d", block_d, divides=True)
    interpret = resolve_interpret(interpret)
    nt = S // block_t
    nd = D // block_d
    kern = functools.partial(_rglru_kernel, block_t=block_t)
    return pl.pallas_call(
        kern,
        grid=(B, nd, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda bb, d, t: (bb, t, d)),
            pl.BlockSpec((1, block_t, block_d), lambda bb, d, t: (bb, t, d)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_d), lambda bb, d, t: (bb, t, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_d,), jnp.float32)],
        interpret=interpret,
    )(a, b)
