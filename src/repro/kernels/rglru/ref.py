"""Oracle for the RG-LRU kernel: sequential linear recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(a, b):
    """a, b (B, S, D) fp32 -> h with h_t = a_t h_{t-1} + b_t, h_{-1} = 0."""
    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    def per_b(ab, bb):
        h0 = jnp.zeros(ab.shape[-1], jnp.float32)
        _, hs = jax.lax.scan(step, h0, (ab.astype(jnp.float32), bb.astype(jnp.float32)))
        return hs

    return jax.vmap(per_b)(a, b).astype(a.dtype)
