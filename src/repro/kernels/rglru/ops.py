"""Public RG-LRU op: gate math in fp32 + kernel dispatch + padding."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.rglru.kernel import rglru_scan_kernel


def rglru(x, a, *, block_t: int = 16, interpret: Optional[bool] = None):
    """Linear recurrence h_t = a_t h_{t-1} + sqrt(1-a_t^2) x_t over (B,S,D).

    Matches repro.models.rglru.rglru_scan with zero initial state.
    """
    B, S, D = x.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a.astype(jnp.float32)), 1e-12)) * x.astype(jnp.float32)
    bt = min(block_t, S)
    pad_t = (bt - S % bt) % bt
    pad_d = (128 - D % 128) % 128 if D > 128 else 0
    af = a.astype(jnp.float32)
    if pad_t or pad_d:
        af = jnp.pad(af, ((0, 0), (0, pad_t), (0, pad_d)))
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_d)))
    h = rglru_scan_kernel(af, b, block_t=bt, block_d=min(128, af.shape[-1]),
                          interpret=interpret)
    return h[:, :S, :D].astype(x.dtype)
