"""Public RG-LRU op: gate math in fp32 + tuned kernel dispatch + padding."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.rglru.kernel import rglru_scan_kernel
from repro.kernels.validate import dtype_name, validate_block


def _tuned_blocks(S: int, D: int, dtype):
    """Tuning-DB lookup keyed on the *unpadded* (S, D) signature (None on
    miss or if a stale entry no longer validates as a bound)."""
    from repro.tuning.db import tuned_params

    t = tuned_params("rglru", f"S{S},D{D}", dtype_name(dtype))
    if not t:
        return None
    try:
        bt = validate_block("rglru", "S", S, "block_t", t["block_t"])
        bd = validate_block("rglru", "D", D, "block_d", t["block_d"])
    except (KeyError, ValueError):
        return None
    return bt, bd


def rglru(x, a, *, block_t: Optional[int] = None,
          block_d: Optional[int] = None, interpret: Optional[bool] = None):
    """Linear recurrence h_t = a_t h_{t-1} + sqrt(1-a_t^2) x_t over (B,S,D).

    Matches repro.models.rglru.rglru_scan with zero initial state.

    ``block_t``/``block_d`` default to ``None``: the tuning DB is
    consulted for this (shape, dtype) at trace time, falling back to
    ``block_t=min(16, S)`` and the lane-width default ``block_d=128``.
    Explicit blocks are validated as bounds (``1 <= block <= dim``) and
    S/D are padded up to multiples so the kernel's divisibility
    requirement always holds; invalid blocks raise, never clamp.
    """
    B, S, D = x.shape
    if block_t is None and block_d is None:
        tuned = _tuned_blocks(S, D, x.dtype)
        if tuned is not None:
            block_t, block_d = tuned
    if block_t is None:
        bt = min(16, S)
    else:
        bt = validate_block("rglru", "S", S, "block_t", block_t)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a.astype(jnp.float32)), 1e-12)) * x.astype(jnp.float32)
    pad_t = (bt - S % bt) % bt
    if block_d is None:
        pad_d = (128 - D % 128) % 128 if D > 128 else 0
        bd = min(128, D + pad_d)
    else:
        bd = validate_block("rglru", "D", D, "block_d", block_d)
        pad_d = (bd - D % bd) % bd
    af = a.astype(jnp.float32)
    if pad_t or pad_d:
        af = jnp.pad(af, ((0, 0), (0, pad_t), (0, pad_d)))
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_d)))
    h = rglru_scan_kernel(af, b, block_t=bt, block_d=bd, interpret=interpret)
    return h[:, :S, :D].astype(x.dtype)
