"""Pure-jnp oracle for the flash attention kernel.

This is deliberately the *naive* materialized-scores formulation (the thing
flash attention avoids); numerically it is the ground truth the kernel must
match.  The model code's XLA path uses repro.models.layers.attention (the
chunked online-softmax variant), itself validated against this oracle.
"""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, mask_type: str = "causal", window: int = 0,
                  q_offset: int = 0, softmax_scale: Optional[float] = None,
                  softcap: float = 0.0):
    """q (BH, Sq, D), k/v (BH, Sk, D) -> (BH, Sq, D), fp32 math."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32))
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qp = q_offset + jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    if mask_type == "causal":
        mask = kp <= qp
    elif mask_type == "local":
        mask = (kp <= qp) & (kp > qp - window)
    else:
        mask = jnp.ones((Sq, Sk), bool)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
