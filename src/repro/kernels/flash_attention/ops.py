"""Public flash-attention op: GQA layout handling + platform dispatch."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bh


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, mask_type: str = "causal", window: int = 0,
                    q_offset: int = 0, softmax_scale: Optional[float] = None,
                    softcap: float = 0.0, block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """q (B, Sq, H, D), k/v (B, Sk, K, D) with H % K == 0 -> (B, Sq, H, D).

    GQA is flattened to (B*H, S, D) by repeating each kv head over its query
    group — the kernel sees plain MHA tiles (on real TPU the repeat is free:
    it lowers to a broadcast in the index map of a production variant; here
    we keep the memory model simple and explicit).
    """
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    assert H % K == 0
    G = H // K
    if interpret is None:
        interpret = not _on_tpu()
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Sk, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Sk, D)
    out = flash_attention_bh(
        qf, kf, vf, mask_type=mask_type, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, softmax_scale=softmax_scale,
        softcap=softcap, interpret=interpret)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
