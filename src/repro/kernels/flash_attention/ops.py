"""Public flash-attention op: GQA layout handling + tuned-block dispatch."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bh
from repro.kernels.validate import dtype_name, validate_block


def _tuned_blocks(Sq: int, Sk: int, D: int, dtype):
    """Tuning-DB lookup for this trace's shape signature (None on miss or
    if a stale entry no longer validates)."""
    from repro.tuning.db import tuned_params

    t = tuned_params("flash_attention", f"Sq{Sq},Sk{Sk},D{D}", dtype_name(dtype))
    if not t:
        return None
    try:
        bq = validate_block("flash_attention", "Sq", Sq, "block_q", t["block_q"])
        bk = validate_block("flash_attention", "Sk", Sk, "block_k", t["block_k"])
    except (KeyError, ValueError):
        return None
    return bq, bk


def flash_attention(q, k, v, *, mask_type: str = "causal", window: int = 0,
                    q_offset: int = 0, softmax_scale: Optional[float] = None,
                    softcap: float = 0.0, block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """q (B, Sq, H, D), k/v (B, Sk, K, D) with H % K == 0 -> (B, Sq, H, D).

    GQA is flattened to (B*H, S, D) by repeating each kv head over its query
    group — the kernel sees plain MHA tiles (on real TPU the repeat is free:
    it lowers to a broadcast in the index map of a production variant; here
    we keep the memory model simple and explicit).

    ``block_q``/``block_k`` default to ``None``: the tuning DB
    (``repro.tuning.db``) is consulted for this (shape, dtype) at trace
    time, falling back to ``min(128, S)`` on a miss.  Explicit blocks are
    validated strictly (ValueError), never clamped.  ``interpret=None``
    resolves in the kernel layer (interpreted off-TPU).
    """
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    assert H % K == 0
    G = H // K
    if block_q is None and block_k is None:
        tuned = _tuned_blocks(Sq, Sk, D, q.dtype)
        if tuned is not None:
            block_q, block_k = tuned
    if block_q is None:
        block_q = min(128, Sq)
    else:
        validate_block("flash_attention", "Sq", Sq, "block_q", block_q)
    if block_k is None:
        block_k = min(128, Sk)
    else:
        validate_block("flash_attention", "Sk", Sk, "block_k", block_k)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Sk, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Sk, D)
    out = flash_attention_bh(
        qf, kf, vf, mask_type=mask_type, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, softmax_scale=softmax_scale,
        softcap=softcap, interpret=interpret)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
