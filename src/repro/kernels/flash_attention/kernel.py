"""Flash attention Pallas TPU kernel.

TPU adaptation of the FlashAttention insight (the paper-stack's hottest
kernel): online-softmax tiling so the S x S score matrix never leaves VMEM.
Unlike the CUDA formulation (warp-level shuffles, shared-memory banking) the
TPU version tiles for the MXU: (block_q x head_dim) @ (head_dim x block_k)
runs on the systolic array; running max / denominator live in VMEM scratch
that persists across the sequential innermost grid dimension.

Grid: (batch*heads, n_q_blocks, n_kv_blocks) — TPU executes the last axis
sequentially per (bh, qi), so scratch accumulators carry across kv blocks.
Causal/local masking prunes fully-masked kv blocks via pl.when.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.validate import resolve_interpret, validate_block

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, block_q: int, block_k: int, seq_k: int,
               mask_type: str, window: int, q_offset: int, softcap: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # block-level prune: skip kv blocks that are entirely masked out
    q_lo = q_offset + qi * block_q
    q_hi = q_lo + block_q - 1
    k_lo = ki * block_k
    if mask_type == "causal":
        live = k_lo <= q_hi
    elif mask_type == "local":
        live = (k_lo <= q_hi) & (ki * block_k + block_k - 1 > q_lo - window)
    else:
        live = True

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale           # (bq, d)
        k = k_ref[0].astype(jnp.float32)                   # (bk, d)
        v = v_ref[0].astype(jnp.float32)                   # (bk, d)
        # sanitize the kv tail: rows past seq_k may be uninitialized (OOB
        # block padding); p is 0 there but 0*NaN would poison the matmul.
        kv_valid = (k_lo + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)) < seq_k
        v = jnp.where(kv_valid, v, 0.0)
        k = jnp.where(kv_valid, k, 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        if mask_type == "causal":
            mask = k_pos <= q_pos
        elif mask_type == "local":
            mask = (k_pos <= q_pos) & (k_pos > q_pos - window)
        else:
            mask = k_pos < seq_k
        mask = mask & (k_pos < seq_k)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _final():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("mask_type", "window", "q_offset", "block_q", "block_k",
                     "softmax_scale", "softcap", "interpret"))
def flash_attention_bh(q, k, v, *, mask_type: str = "causal", window: int = 0,
                       q_offset: int = 0, block_q: int = 128, block_k: int = 128,
                       softmax_scale=None, softcap: float = 0.0,
                       interpret: Optional[bool] = None):
    """q (BH, Sq, D), k/v (BH, Sk, D) -> (BH, Sq, D).  GQA handled in ops.py.

    Blocks need not divide the sequence (the kernel masks the tail) but
    must fit it — an oversized block is rejected, not silently clamped,
    so a measured launch shape is always the requested one.
    ``interpret=None`` auto-detects (interpreted off-TPU), uniformly with
    the rglru/ssd kernels (``kernels.validate.resolve_interpret``).
    """
    BH, Sq, D = q.shape
    _, Sk, _ = k.shape
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    validate_block("flash_attention", "Sq", Sq, "block_q", block_q)
    validate_block("flash_attention", "Sk", Sk, "block_k", block_k)
    interpret = resolve_interpret(interpret)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)

    kern = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_k=block_k, seq_k=Sk,
        mask_type=mask_type, window=window, q_offset=q_offset, softcap=softcap)

    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denominator
            pltpu.VMEM((block_q, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
