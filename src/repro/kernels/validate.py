"""Shared launch-parameter validation for the Pallas kernels.

One helper, three kernels, two constraint kinds:

* **bound** (``divides=False``): a block must fit inside its dimension
  (``1 <= block <= dim``) — flash attention's q/k blocks (the kernel
  masks the tail, so non-dividing blocks are fine) and every ops-level
  block of a kernel that pads (rglru time blocks, ssd chunks).
* **divisibility** (``divides=True``): the kernel-level grids that carry
  scratch state across a sequential axis require the block to divide the
  dimension exactly (rglru's ``S % block_t == 0``, ssd's ``S % chunk``).

Both kinds raise a ``ValueError`` naming the kernel, the offending
dimension, and the nearest valid block — replacing the seed kernels'
bare ``assert``s and silent ``min(block, dim)`` clamps, so a bad tuning
candidate (or a hand-written call) fails loudly instead of measuring a
different launch shape than the caller asked for.  The autotuner's
search space (``repro.tuning.space``) uses the same helper, which is
what guarantees no generated candidate can assert or OOM.

``resolve_interpret`` is the one place Pallas execution mode is decided:
``None`` means auto-detect (interpret off real TPU, interpreted
elsewhere) — previously only ``ops.flash_attention`` auto-detected while
a direct ``flash_attention_bh`` call defaulted to interpreted even on
TPU; now all three kernels resolve it identically at the kernel layer.
"""
from __future__ import annotations

from typing import Optional


def nearest_valid_block(dim: int, block: int, *, divides: bool = False) -> int:
    """The valid block size closest to ``block`` for ``dim``.

    ``divides=False``: clamp into ``[1, dim]``.  ``divides=True``: the
    divisor of ``dim`` nearest to ``block`` (ties go to the larger
    divisor — bigger blocks amortise grid overhead).
    """
    if dim < 1:
        raise ValueError(f"dimension must be positive, got {dim}")
    if not divides:
        return max(1, min(block, dim))
    divisors = [d for d in range(1, dim + 1) if dim % d == 0]
    return min(reversed(divisors), key=lambda d: abs(d - block))


def validate_block(kernel: str, dim_name: str, dim: int,
                   block_name: str, block: int, *,
                   divides: bool = False) -> int:
    """Validate one launch parameter; returns it unchanged when valid.

    Raises ``ValueError`` naming the kernel, the offending dimension,
    and the nearest valid block — the shared contract between the
    tuner's search space and direct kernel callers.
    """
    if not isinstance(block, int) or isinstance(block, bool):
        raise ValueError(f"{kernel}: {block_name} must be an int, "
                         f"got {block!r}")
    if block < 1 or block > dim:
        raise ValueError(
            f"{kernel}: {block_name}={block} is outside [1, {dim_name}={dim}] "
            f"(nearest valid: {nearest_valid_block(dim, block, divides=divides)})")
    if divides and dim % block != 0:
        raise ValueError(
            f"{kernel}: {block_name}={block} does not divide {dim_name}={dim} "
            f"(nearest valid: {nearest_valid_block(dim, block, divides=True)})")
    return block


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Uniform Pallas execution-mode resolution for all three kernels:
    ``None`` -> interpreted everywhere except a real TPU backend."""
    if interpret is None:
        import jax
        return jax.default_backend() != "tpu"
    return bool(interpret)


def dtype_name(dtype) -> str:
    """The tuning-DB dtype tag for an input array dtype (mirrors the
    scenario ``dtype`` axis; unknown dtypes get their jnp name so they
    simply never match a tuned entry)."""
    import jax.numpy as jnp

    if dtype == jnp.float32:
        return "fp32"
    if dtype == jnp.bfloat16:
        return "bf16"
    return str(dtype)
