"""Continuous-batching inference serving engine (the ``task="serve"``
workload — NOT ``repro.runner.worker --serve``, which is the benchmark
pool's worker-protocol flag; see the disambiguation note below).

A minimal production-shaped server: a request queue with virtual-time
arrivals, a batched prefill admission stage, and a batched decode loop
with per-slot completion and refill (continuous batching).  Runs reduced
configs on CPU (examples, tests) and full configs on a TPU mesh via the
same code path.

Admission (PR 8): each loop iteration admits one *wave* — every waiting
request paired with a free slot — through ONE jitted prefill call per
prompt-length bucket (``admission="batched"``, the default).  Prompts
are right-padded into power-of-two length buckets and row counts rounded
to powers of two, so the number of compiled prefill shapes is bounded by
the bucket grid (buckets x log2(slots)), not by the number of distinct
prompt lengths; per-request masks/gathers inside the model make the
padded rows exact, so tokens are byte-identical to the
``admission="single"`` per-request baseline (kept as an engine flag and
scenario axis for A/B measurement — ``benchmarks/loadgen_curve.py``
sweeps both policies side by side).

Layering (ISSUE 3):

* ``ServeEngine`` is the engine proper.  It accepts a prebuilt
  ``repro.core.suite.Built`` (config + model + params) so the
  BenchmarkRunner's arch-build cache is shared between serve cells and
  the train/infer cells of the same arch — the engine never builds
  models itself.
* Request traces come from ``repro.runner.traces``: deterministic load
  profiles (uniform / bursty / mixed arrivals, optionally crossed with a
  prompt-length profile as ``"bursty+bimodal"``) whose arrivals are
  expressed in decode-step *virtual time*, so generated tokens are a pure
  function of (trace spec, params) — identical serially and under sharded
  dispatch.  Per-slot position vectors in the KV cache let one decode
  batch mix prompt lengths; ``capture()`` turns a served trace back into
  a replayable spec.
* Latency distributions (TTFT and per-token p50/p95/p99) are produced by
  ``summarize_metrics`` on the engine's raw per-request timestamps,
  using the shared ``repro.runner.latency`` percentile helper.
* The CLI at the bottom is a thin shell: resolve config -> build ->
  generate trace -> run engine -> print the summary.  Benchmarked runs
  go through ``BenchmarkRunner`` (``Scenario(task="serve")``) instead.

Naming note: "serve" appears twice in this codebase with unrelated
meanings.  THIS module is the inference-serving *workload*.  The
``--serve`` flag of ``repro.runner.worker`` puts a benchmark worker into
its persistent JSONL pool protocol over stdin/stdout pipes, and the
worker's ``--connect HOST:PORT`` flag speaks the same protocol over TCP
to a cluster coordinator (``repro.runner.cluster``) — both are dispatch
transports that can be handed scenarios of any task, including this
one's ``task="serve"`` cells.  Grep accordingly.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \
        --requests 16 --slots 4 --prompt-len 32 --trace bursty
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ParamDef
from repro.runner.latency import latency_summary
from repro.runner.traces import (Request, TraceSpec, cache_len_bound,
                                 capture_spec, generate, save_spec,
                                 tokens_by_rid, tokens_digest)

#: smallest padded prompt-length bucket for batched admission; buckets
#: double from here, so compile count is bounded by
#: log2(max_len / ADMIT_MIN_BUCKET) x log2(slots), not by distinct lengths
ADMIT_MIN_BUCKET = 8

#: valid values of the engine's ``admission`` policy flag
ADMISSIONS = ("batched", "single")


class ServeEngine:
    """Slot-based continuous batching over a shared decode step.

    ``built`` is a ``repro.core.suite.Built`` (or anything with ``cfg`` /
    ``model`` / ``params`` attributes).  The engine jits its admission and
    decode steps once at construction; ``run()`` resets all per-trace
    state, so one engine instance (and its compiled executables) can
    replay any number of traces — the BenchmarkRunner caches engines per
    (build, slots, max_len, admission) exactly like step executables.

    Admission prefills waiting requests *directly into the live cache*:
    each wave gathers every admissible queued request, groups them by
    padded prompt-length bucket, and runs one jitted call per group —
    prefill on a fresh k-row mini cache, per-row last-valid-position
    argmax, then a masked row scatter into the target slots (the per-slot
    ``len`` position vectors land each row at its own prompt length).

    ``admission="batched"`` (default) pads prompts to power-of-two
    buckets (>= ``ADMIT_MIN_BUCKET``) and rounds the batch to a power of
    two, so the compile count is bounded by buckets, not distinct prompt
    lengths.  ``admission="single"`` is the pre-batching baseline kept
    runnable for comparison: one exact-length single-row call per request
    (recompiling per distinct length), token-identical to batched
    admission by construction.  The MoE family always uses exact-length
    groups even under ``"batched"``: expert capacity is sized from the
    token count, so pad tokens would compete with valid tokens for
    capacity slots and could change routing.
    """

    def __init__(self, built, *, slots: int, max_len: int,
                 donate: bool = True, admission: str = "batched"):
        if admission not in ADMISSIONS:
            raise ValueError(f"unknown admission {admission!r} "
                             f"(known: {ADMISSIONS})")
        self.cfg = built.cfg
        self.model = built.model
        self.params = built.params
        self.slots = slots
        self.max_len = max_len
        self.admission = admission
        # vlm prefill writes n_prefix patch tokens ahead of the prompt, so
        # a slot's cache position starts past the prefix after admission
        self._prefix = built.cfg.n_prefix if built.cfg.family == "vlm" else 0
        self._decode = jax.jit(self.model.decode_step,
                               donate_argnums=(2,) if donate else ())
        self._admit = jax.jit(self._admit_impl,
                              donate_argnums=(5,) if donate else ())
        # per-leaf batch axis of every cache leaf, from the declared
        # logical axes — the admission scatter needs it explicitly because
        # a full wave's mini cache has the same row count as the live one
        self._cache_axes = jax.tree.map(
            lambda d: d.axes.index("cache_batch"),
            self.model.cache_defs(slots, max_len),
            is_leaf=lambda v: isinstance(v, ParamDef))
        # distinct (rows, padded_len) shapes ever admitted — the host-side
        # mirror of the jit cache, cumulative over the engine's lifetime
        self._admit_shapes: set = set()
        self._reset()

    def _reset(self) -> None:
        self.cache = self.model.init_cache(self.slots, self.max_len)
        self.slot_req: List[Optional[Request]] = [None] * self.slots
        # host-side mirror of the per-layer "len" vectors: admission sets a
        # row to prefix + prompt_len, every decode step advances all rows.
        # Guarded in run(): an *active* row overflowing max_len would have
        # its KV write clamped to the cache edge, corrupting attention.
        self.slot_pos = np.zeros(self.slots, np.int32)
        self.steps = 0
        self._admit_calls = 0
        self._admit_batches: List[int] = []

    # ---- batched admission ------------------------------------------------

    def _bucket(self, n: int) -> int:
        """Padded prompt length for an ``n``-token prompt."""
        if self.admission == "single" or self.cfg.family == "moe":
            return n          # exact length (see class docstring)
        b = ADMIT_MIN_BUCKET
        while b < n:
            b *= 2
        return min(b, self.max_len - self._prefix)

    def _admit_impl(self, params, tokens, lengths, src, mask, cache):
        """One jitted admission: prefill ``tokens`` (kb, Lpad) with valid
        prefixes ``lengths`` (kb,) on a fresh kb-row mini cache, then
        scatter mini row ``src[s]`` into live-cache row ``s`` wherever
        ``mask[s]`` (``src``/``mask`` are runtime data, so the compile is
        keyed only by the (kb, Lpad) shape).  Returns each admitted row's
        first token and the updated cache."""
        kb = tokens.shape[0]
        mini = self.model.init_cache(kb, self.max_len)
        batch = {"tokens": tokens}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (kb, self.cfg.n_prefix, self.cfg.d_model))
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (kb, self.cfg.enc_seq, self.cfg.d_model))
        logits, mini = self.model.prefill(params, batch, mini,
                                          lengths=lengths)
        first = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)

        def scatter(big, small, ax):
            rows = jnp.take(small, src, axis=ax).astype(big.dtype)
            shape = [1] * big.ndim
            shape[ax] = self.slots
            return jnp.where(mask.reshape(shape), rows, big)

        cache = jax.tree.map(scatter, cache, mini, self._cache_axes)
        return first, cache

    def _admit_wave(self, pairs: List[Tuple[int, Request]]) -> List[int]:
        """Admit a wave of (slot, request) pairs; returns their first
        tokens in pair order.  Batched admission groups the wave by
        prompt-length bucket — one jitted call per group; single admission
        degrades to one exact-length call per request."""
        if self.admission == "single":
            grouped = [[pr] for pr in pairs]
        else:
            by_bucket: Dict[int, List[Tuple[int, Request]]] = {}
            for pr in pairs:
                by_bucket.setdefault(self._bucket(len(pr[1].prompt)),
                                     []).append(pr)
            grouped = [by_bucket[b] for b in sorted(by_bucket)]
        first_by_slot: Dict[int, int] = {}
        for grp in grouped:
            lpad = self._bucket(max(len(r.prompt) for _, r in grp))
            kb = len(grp)
            if self.admission == "batched":
                kb = 1 << (kb - 1).bit_length()   # round rows to pow2
            tokens = np.zeros((kb, lpad), np.int32)
            # dummy rows keep lengths=lpad (their full-garbage state is
            # simply never gathered by src)
            lengths = np.full((kb,), lpad, np.int32)
            src = np.zeros((self.slots,), np.int32)
            mask = np.zeros((self.slots,), bool)
            for i, (s, r) in enumerate(grp):
                tokens[i, : len(r.prompt)] = r.prompt
                lengths[i] = len(r.prompt)
                src[s] = i
                mask[s] = True
            first, self.cache = self._admit(
                self.params, jnp.asarray(tokens), jnp.asarray(lengths),
                jnp.asarray(src), jnp.asarray(mask), self.cache)
            first = np.asarray(first)
            self._admit_calls += 1
            self._admit_batches.append(len(grp))
            self._admit_shapes.add((kb, lpad))
            for i, (s, r) in enumerate(grp):
                self.slot_req[s] = r
                self.slot_pos[s] = self._prefix + len(r.prompt)
                first_by_slot[s] = int(first[i])
        return [first_by_slot[s] for s, _ in pairs]

    def lowered_decode(self):
        """Lower the jitted decode step against the engine's live state —
        the profiler's attribution source (lowering an already-traced call
        is ~1 ms; the caller pays/caches the AOT compile)."""
        toks = jnp.zeros((self.slots, 1), jnp.int32)
        return self._decode.lower(self.params, toks, self.cache)

    def run(self, requests: List[Request], *, hook=None,
            phase_log: Optional[list] = None,
            span_log: Optional[list] = None) -> Dict[str, Any]:
        """Replay a trace; returns throughput + raw latency samples.

        Admission is driven by the decode-step counter (virtual time):
        a request with ``arrival_step=k`` can be admitted only once ``k``
        decode steps have elapsed (the counter fast-forwards when slots
        drain), so slot assignment — and therefore every generated token
        — is deterministic regardless of host speed.  Wall-clock
        timestamps are stamped alongside for the latency metrics.

        ``hook`` is an optional ``RegressionHook`` fired once per decode
        step, so injected-slowdown CI probes work on serve cells too.
        ``phase_log`` is the profiler hook: one ``(dispatch_s, device_s)``
        tuple per batched decode step — the split is taken only when a log
        is passed, so unprofiled replays keep the pre-profiler timing.
        ``span_log`` is the tracing hook: one ``(name, wall_t0, wall_t1)``
        tuple per admission wave ("admit_wave") and batched decode step
        ("decode_step"); wall-clock reads happen only when a list is
        passed, so untraced replays pay nothing.
        """
        self._reset()
        shapes0 = len(self._admit_shapes)
        upcoming = sorted(requests, key=lambda r: (r.arrival_step, r.rid))
        for r in upcoming:
            r.out, r.done = [], False
            r.t_arrival = r.t_first = r.t_done = 0.0
        waiting: List[Request] = []
        next_tok = np.zeros(self.slots, np.int32)
        step = active = done_count = tokens_out = 0
        total = len(upcoming)
        ttft_s: List[float] = []
        tok_lat_s: List[float] = []
        qdepth: List[int] = []
        waves = 0
        t0 = time.perf_counter()
        while done_count < total:
            now = time.perf_counter()
            while upcoming and upcoming[0].arrival_step <= step:
                req = upcoming.pop(0)
                req.t_arrival = now
                waiting.append(req)
            if active == 0 and not waiting:
                # slots drained before the next burst: fast-forward the
                # virtual clock to the next arrival (no idle decode spins)
                step = upcoming[0].arrival_step
                continue
            if waiting:
                # one admission wave: free slots in ascending order take
                # waiting requests FIFO (the same assignment the old
                # per-request loop produced), then prefill per bucket group
                free = [s for s in range(self.slots)
                        if self.slot_req[s] is None or self.slot_req[s].done]
                pairs = list(zip(free, waiting))
                if pairs:
                    del waiting[: len(pairs)]
                    waves += 1
                    tw = time.time() if span_log is not None else 0.0
                    firsts = self._admit_wave(pairs)
                    if span_log is not None:
                        span_log.append(("admit_wave", tw, time.time(),
                                         {"requests": len(pairs)}))
                    tnow = time.perf_counter()
                    for (s, req), tok in zip(pairs, firsts):
                        req.out.append(tok)
                        tokens_out += 1
                        req.t_first = tnow
                        ttft_s.append(tnow - req.t_arrival)
                        next_tok[s] = tok
                        active += 1
                        if len(req.out) >= req.max_new:  # budget of 1: done
                            req.done = True              # at prefill
                            req.t_done = tnow
                            active -= 1
                            done_count += 1
            qdepth.append(len(waiting))
            if active == 0:
                step += 1
                continue
            for s in range(self.slots):
                req = self.slot_req[s]
                if req is None or req.done:
                    continue   # idle rows may overflow harmlessly (clamped
                    #            write, row fully rewritten at next admit)
                if self.slot_pos[s] + 1 > self.max_len:
                    raise RuntimeError(
                        f"KV cache exhausted: slot {s} (rid {req.rid}) at "
                        f"position {int(self.slot_pos[s])} with max_len "
                        f"{self.max_len} — size the engine with "
                        f"traces.cache_len_bound() for the trace")
            tw = time.time() if span_log is not None else 0.0
            ts = time.perf_counter()
            toks = jnp.asarray(next_tok[:, None])
            logits, self.cache = self._decode(self.params, toks, self.cache)
            t_disp = time.perf_counter() if phase_log is not None else 0.0
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            if span_log is not None:
                span_log.append(("decode_step", tw, time.time()))
            if phase_log is not None:
                # dispatch ends when the async decode call returns; the
                # argmax readback above forced the device sync
                phase_log.append((t_disp - ts, time.perf_counter() - t_disp))
            if hook is not None:
                hook.fire()   # inside the timed sample, like harness.measure
            dt = time.perf_counter() - ts
            self.steps += 1
            step += 1
            self.slot_pos += 1   # decode advances every row's len vector
            for s in range(self.slots):
                req = self.slot_req[s]
                if req is None or req.done:
                    continue
                req.out.append(int(nxt[s]))
                tokens_out += 1
                tok_lat_s.append(dt)
                next_tok[s] = nxt[s]
                if len(req.out) >= req.max_new:
                    req.done = True
                    req.t_done = time.perf_counter()
                    active -= 1
                    done_count += 1
        wall = time.perf_counter() - t0
        ab = self._admit_batches
        # fleet metrics: folded ONCE per replay (never per decode step) —
        # admission control-path counters + the end-of-replay KV fill
        from repro.fleet.metrics import registry as metrics_registry
        reg = metrics_registry()
        reg.inc("serve_admit_waves_total", waves)
        reg.inc("serve_admit_calls_total", self._admit_calls)
        reg.inc("serve_bucket_compiles_total",
                len(self._admit_shapes) - shapes0)
        reg.inc("serve_decode_steps_total", self.steps)
        reg.set_gauge("serve_kv_occupancy",
                      float(np.mean(self.slot_pos)) / self.max_len
                      if self.max_len else 0.0)
        return {"requests": total, "decode_steps": self.steps,
                "tokens": tokens_out, "wall_s": wall,
                "tok_per_s": tokens_out / wall if wall else 0.0,
                "ttft_s": ttft_s, "tok_lat_s": tok_lat_s,
                "queue_depth_mean": (sum(qdepth) / len(qdepth)) if qdepth else 0.0,
                "queue_depth_max": max(qdepth) if qdepth else 0,
                "admission": self.admission,
                "admit_calls": self._admit_calls,
                "admit_batch_mean": (sum(ab) / len(ab)) if ab else 0.0,
                "admit_batch_max": max(ab) if ab else 0,
                "admit_shapes": sorted(list(s) for s in self._admit_shapes),
                # prefill shapes first compiled DURING this replay: > 0 means
                # the replay paid admission jits (queue dynamics at this load
                # reached bucket shapes no earlier replay had) and its wall/
                # TTFT samples are not steady-state — rerun to re-measure
                "admit_new_shapes": len(self._admit_shapes) - shapes0,
                "tokens_by_rid": tokens_by_rid(requests)}

    def capture(self, requests: List[Request], *, seed: int = 0,
                source: str = "live") -> TraceSpec:
        """A replayable ``TraceSpec`` of a served trace: per-request prompt
        lengths, arrivals, and budgets pinned, prompt *content* regenerated
        from ``(seed, lengths)`` — so a live run becomes a regression asset
        via the ordinary ``save_spec`` schema (``trace="file:..."``)."""
        return capture_spec(requests, seed=seed, source=source)


def summarize_metrics(out: Dict[str, Any]) -> Dict[str, Any]:
    """The well-known serve metric keys (see ``runner/results.py``) from an
    engine ``run()`` payload: TTFT / per-token latency p50/p95/p99 in us,
    throughput, queue depth, admission counters, and the token digest."""
    summary: Dict[str, Any] = {
        "tok_per_s": out["tok_per_s"],
        "decode_steps": out["decode_steps"],
        "queue_depth_mean": out["queue_depth_mean"],
        "queue_depth_max": out["queue_depth_max"],
        "tokens_digest": tokens_digest(out["tokens_by_rid"]),
    }
    for k in ("admission", "admit_calls", "admit_batch_mean",
              "admit_batch_max", "admit_shapes"):
        if k in out:
            summary[k] = out[k]
    summary.update(latency_summary(out["ttft_s"], "ttft", scale=1e6))
    summary.update(latency_summary(out["tok_lat_s"], "tok_lat", scale=1e6))
    return summary


def built_for_cfg(cfg, seed: int = 0):
    """Build (model + params) for an already-resolved config — the
    non-runner path shared by the ``Server`` shim and the ``--full`` CLI
    (the runner's ``built_for`` caches reduced builds instead)."""
    from repro.core.suite import Built
    from repro.models import build_model
    model = build_model(cfg)
    return Built(cfg=cfg, model=model, params=model.init(jax.random.key(seed)))


class Server(ServeEngine):
    """Compat shim over ``ServeEngine`` for direct (non-runner) callers:
    builds the model from a config, like the pre-runner serving driver.
    Serves through the same bucketed batched-admission path as the
    runner-cached engines (``admission`` passes through)."""

    def __init__(self, cfg, *, slots: int, max_len: int, seed: int = 0,
                 admission: str = "batched"):
        super().__init__(built_for_cfg(cfg, seed), slots=slots,
                         max_len=max_len, admission=admission)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--trace", default="uniform",
                    help="load profile: uniform | bursty | mixed")
    ap.add_argument("--prompt-profile", default="fixed",
                    help="prompt-length profile: fixed | uniform | bimodal "
                         "| longtail")
    ap.add_argument("--capture", default="",
                    help="write a replayable TraceSpec of this run to PATH")
    ap.add_argument("--admission", default="batched", choices=ADMISSIONS,
                    help="prefill admission policy: batched (bucketed "
                         "multi-request prefill) | single (per-request "
                         "baseline)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    from repro.core.suite import build_arch
    from repro.configs import get_arch
    if args.full:
        built = built_for_cfg(get_arch(args.arch))
    else:
        built = build_arch(args.arch)
    spec = TraceSpec(profile=args.trace, requests=args.requests,
                     prompt_len=args.prompt_len, max_new=args.max_new,
                     seed=args.seed, prompt_profile=args.prompt_profile)
    reqs = generate(spec, vocab=built.cfg.vocab)
    prefix = built.cfg.n_prefix if built.cfg.family == "vlm" else 0
    engine = ServeEngine(built, slots=args.slots,
                         max_len=cache_len_bound(reqs, prefix=prefix),
                         admission=args.admission)
    out = engine.run(reqs)
    m = summarize_metrics(out)
    if args.capture:
        save_spec(engine.capture(reqs, seed=args.seed,
                                 source=f"cli:{args.arch}"), args.capture)
        print(f"captured trace spec -> {args.capture}")
    print(f"served {args.requests} requests ({args.trace}): {out['tokens']} tokens "
          f"in {out['wall_s']:.2f}s ({m['tok_per_s']:.1f} tok/s, "
          f"{out['decode_steps']} steps, {args.admission} admission: "
          f"{out['admit_calls']} prefill calls)")
    print(f"  ttft_us    p50={m.get('ttft_p50', 0):.0f} "
          f"p95={m.get('ttft_p95', 0):.0f} p99={m.get('ttft_p99', 0):.0f}")
    print(f"  tok_lat_us p50={m.get('tok_lat_p50', 0):.0f} "
          f"p95={m.get('tok_lat_p95', 0):.0f} p99={m.get('tok_lat_p99', 0):.0f}")
    print(f"  queue_depth mean={m['queue_depth_mean']:.2f} max={m['queue_depth_max']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
