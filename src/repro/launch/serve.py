"""Batched serving driver: continuous-batching decode loop.

A minimal production-shaped server: a request queue, a prefill stage and a
batched decode loop with per-slot completion and refill (continuous
batching).  Runs reduced configs on CPU (examples, tests) and full configs
on a TPU mesh via the same code path.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \
        --requests 16 --slots 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Slot-based continuous batching over a shared decode step."""

    def __init__(self, cfg, *, slots: int, max_len: int, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.key(seed))
        self.slots = slots
        self.max_len = max_len
        self.cache = self.model.init_cache(slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))
        self._prefill_cache = jax.jit(
            lambda p, b, c: self.model.prefill(p, b, c), donate_argnums=(2,))
        self.steps = 0

    def _admit(self, req: Request, slot: int) -> int:
        """Prefill a single request into `slot`; returns first token."""
        # per-slot prefill on a fresh single-row cache, then splice in
        one = self.model.init_cache(1, self.max_len)
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros((1, self.cfg.n_prefix, self.cfg.d_model))
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros((1, self.cfg.enc_seq, self.cfg.d_model))
        logits, one = self._prefill_cache(self.params, batch, one)
        # Caches interact across slots only through the batch dim; splice the
        # new row in.  NOTE: the shared per-layer `len` counter means slots
        # decode in lockstep positions — prompts must share a length (as in
        # this driver).  Per-slot position vectors are a serve-layer upgrade
        # tracked in DESIGN.md.
        self.cache = _splice_cache(self.cache, one, slot)
        self.slot_req[slot] = req
        self.slot_pos[slot] = len(req.prompt)
        return int(jnp.argmax(logits[0, -1]))

    def run(self, requests: List[Request]) -> Dict[str, Any]:
        pending = list(requests)
        active = 0
        t0 = time.perf_counter()
        tokens_out = 0
        # admit initial
        next_tok = np.zeros(self.slots, np.int32)
        for s in range(self.slots):
            if pending:
                req = pending.pop(0)
                tok = self._admit(req, s)
                req.out.append(tok)
                next_tok[s] = tok
                active += 1
        while active > 0:
            toks = jnp.asarray(next_tok[:, None])
            logits, self.cache = self._decode(self.params, toks, self.cache)
            self.steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            for s in range(self.slots):
                req = self.slot_req[s]
                if req is None or req.done:
                    continue
                req.out.append(int(nxt[s]))
                tokens_out += 1
                next_tok[s] = nxt[s]
                if len(req.out) >= req.max_new:
                    req.done = True
                    active -= 1
                    if pending:   # refill the slot (continuous batching)
                        nreq = pending.pop(0)
                        tok = self._admit(nreq, s)
                        nreq.out.append(tok)
                        next_tok[s] = tok
                        active += 1
        wall = time.perf_counter() - t0
        return {"decode_steps": self.steps, "tokens": tokens_out, "wall_s": wall,
                "tok_per_s": tokens_out / wall if wall else 0.0}


def _splice_cache(big, one, slot: int):
    """Write single-row cache `one` into row `slot` of the batched cache."""
    def f(b, s):
        if b.ndim == s.ndim and b.shape == s.shape:
            # per-layer scalars (len): decode advances all slots in lockstep;
            # keep the max so positions stay monotone.
            return jnp.maximum(b, s)
        # find the batch axis: first axis where shapes differ
        for ax in range(b.ndim):
            if b.shape[ax] != s.shape[ax]:
                idx = [0] * b.ndim
                idx[ax] = slot
                return jax.lax.dynamic_update_slice(b, s.astype(b.dtype), tuple(idx))
        return b
    return jax.tree.map(f, big, one)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32), args.max_new)
            for i in range(args.requests)]
    srv = Server(cfg, slots=args.slots, max_len=args.prompt_len + args.max_new + 8)
    out = srv.run(reqs)
    print(f"served {args.requests} requests: {out['tokens']} tokens in "
          f"{out['wall_s']:.2f}s ({out['tok_per_s']:.1f} tok/s, {out['decode_steps']} steps)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
