"""Continuous-batching inference serving engine (the ``task="serve"``
workload — NOT ``repro.runner.worker --serve``, which is the benchmark
pool's worker-protocol flag; see the disambiguation note below).

A minimal production-shaped server: a request queue with virtual-time
arrivals, a prefill stage, and a batched decode loop with per-slot
completion and refill (continuous batching).  Runs reduced configs on CPU
(examples, tests) and full configs on a TPU mesh via the same code path.

Layering (ISSUE 3):

* ``ServeEngine`` is the engine proper.  It accepts a prebuilt
  ``repro.core.suite.Built`` (config + model + params) so the
  BenchmarkRunner's arch-build cache is shared between serve cells and
  the train/infer cells of the same arch — the engine never builds
  models itself.
* Request traces come from ``repro.runner.traces``: deterministic load
  profiles (uniform / bursty / mixed arrivals, optionally crossed with a
  prompt-length profile as ``"bursty+bimodal"``) whose arrivals are
  expressed in decode-step *virtual time*, so generated tokens are a pure
  function of (trace spec, params) — identical serially and under sharded
  dispatch.  Per-slot position vectors in the KV cache let one decode
  batch mix prompt lengths; ``capture()`` turns a served trace back into
  a replayable spec.
* Latency distributions (TTFT and per-token p50/p95/p99) are produced by
  ``summarize_metrics`` on the engine's raw per-request timestamps,
  using the shared ``repro.runner.latency`` percentile helper.
* The CLI at the bottom is a thin shell: resolve config -> build ->
  generate trace -> run engine -> print the summary.  Benchmarked runs
  go through ``BenchmarkRunner`` (``Scenario(task="serve")``) instead.

Naming note: "serve" appears twice in this codebase with unrelated
meanings.  THIS module is the inference-serving *workload*.  The
``--serve`` flag of ``repro.runner.worker`` puts a benchmark worker into
its persistent JSONL pool protocol over stdin/stdout pipes, and the
worker's ``--connect HOST:PORT`` flag speaks the same protocol over TCP
to a cluster coordinator (``repro.runner.cluster``) — both are dispatch
transports that can be handed scenarios of any task, including this
one's ``task="serve"`` cells.  Grep accordingly.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \
        --requests 16 --slots 4 --prompt-len 32 --trace bursty
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runner.latency import latency_summary
from repro.runner.traces import (Request, TraceSpec, cache_len_bound,
                                 capture_spec, generate, save_spec,
                                 tokens_by_rid, tokens_digest)


class ServeEngine:
    """Slot-based continuous batching over a shared decode step.

    ``built`` is a ``repro.core.suite.Built`` (or anything with ``cfg`` /
    ``model`` / ``params`` attributes).  The engine jits its prefill and
    decode steps once at construction; ``run()`` resets all per-trace
    state, so one engine instance (and its compiled executables) can
    replay any number of traces — the BenchmarkRunner caches engines per
    (build, slots, max_len) exactly like step executables.
    """

    def __init__(self, built, *, slots: int, max_len: int,
                 donate: bool = True):
        self.cfg = built.cfg
        self.model = built.model
        self.params = built.params
        self.slots = slots
        self.max_len = max_len
        # vlm prefill writes n_prefix patch tokens ahead of the prompt, so
        # a slot's cache position starts past the prefix after admission
        self._prefix = built.cfg.n_prefix if built.cfg.family == "vlm" else 0
        dargs = (2,) if donate else ()
        self._decode = jax.jit(self.model.decode_step, donate_argnums=dargs)
        self._prefill_cache = jax.jit(
            lambda p, b, c: self.model.prefill(p, b, c), donate_argnums=dargs)
        self._reset()

    def _reset(self) -> None:
        self.cache = self.model.init_cache(self.slots, self.max_len)
        self.slot_req: List[Optional[Request]] = [None] * self.slots
        # host-side mirror of the per-layer "len" vectors: admission sets a
        # row to prefix + prompt_len, every decode step advances all rows.
        # Guarded in run(): an *active* row overflowing max_len would have
        # its KV write clamped to the cache edge, corrupting attention.
        self.slot_pos = np.zeros(self.slots, np.int32)
        self.steps = 0

    def _admit(self, req: Request, slot: int) -> int:
        """Prefill a single request into ``slot``; returns first token."""
        # per-slot prefill on a fresh single-row cache, then splice in
        one = self.model.init_cache(1, self.max_len)
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros((1, self.cfg.n_prefix, self.cfg.d_model))
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros((1, self.cfg.enc_seq, self.cfg.d_model))
        logits, one = self._prefill_cache(self.params, batch, one)
        # Caches interact across slots only through the batch dim; splice
        # the new row in.  The per-layer `len` leaves are per-row vectors,
        # so the fresh row lands at its own prompt length while co-resident
        # slots keep decoding at theirs — one batch can mix prompt lengths.
        self.cache = _splice_cache(self.cache, one, slot)
        self.slot_req[slot] = req
        self.slot_pos[slot] = self._prefix + len(req.prompt)
        return int(jnp.argmax(logits[0, -1]))

    def lowered_decode(self):
        """Lower the jitted decode step against the engine's live state —
        the profiler's attribution source (lowering an already-traced call
        is ~1 ms; the caller pays/caches the AOT compile)."""
        toks = jnp.zeros((self.slots, 1), jnp.int32)
        return self._decode.lower(self.params, toks, self.cache)

    def run(self, requests: List[Request], *, hook=None,
            phase_log: Optional[list] = None) -> Dict[str, Any]:
        """Replay a trace; returns throughput + raw latency samples.

        Admission is driven by the decode-step counter (virtual time):
        a request with ``arrival_step=k`` can be admitted only once ``k``
        decode steps have elapsed (the counter fast-forwards when slots
        drain), so slot assignment — and therefore every generated token
        — is deterministic regardless of host speed.  Wall-clock
        timestamps are stamped alongside for the latency metrics.

        ``hook`` is an optional ``RegressionHook`` fired once per decode
        step, so injected-slowdown CI probes work on serve cells too.
        ``phase_log`` is the profiler hook: one ``(dispatch_s, device_s)``
        tuple per batched decode step — the split is taken only when a log
        is passed, so unprofiled replays keep the pre-profiler timing.
        """
        self._reset()
        upcoming = sorted(requests, key=lambda r: (r.arrival_step, r.rid))
        for r in upcoming:
            r.out, r.done = [], False
            r.t_arrival = r.t_first = r.t_done = 0.0
        waiting: List[Request] = []
        next_tok = np.zeros(self.slots, np.int32)
        step = active = done_count = tokens_out = 0
        total = len(upcoming)
        ttft_s: List[float] = []
        tok_lat_s: List[float] = []
        qdepth: List[int] = []
        t0 = time.perf_counter()
        while done_count < total:
            now = time.perf_counter()
            while upcoming and upcoming[0].arrival_step <= step:
                req = upcoming.pop(0)
                req.t_arrival = now
                waiting.append(req)
            if active == 0 and not waiting:
                # slots drained before the next burst: fast-forward the
                # virtual clock to the next arrival (no idle decode spins)
                step = upcoming[0].arrival_step
                continue
            for s in range(self.slots):
                if not waiting:
                    break
                if self.slot_req[s] is not None and not self.slot_req[s].done:
                    continue
                req = waiting.pop(0)
                tok = self._admit(req, s)
                req.out.append(tok)
                tokens_out += 1
                tnow = time.perf_counter()
                req.t_first = tnow
                ttft_s.append(tnow - req.t_arrival)
                next_tok[s] = tok
                active += 1
                if len(req.out) >= req.max_new:     # budget of 1: done at prefill
                    req.done = True
                    req.t_done = tnow
                    active -= 1
                    done_count += 1
            qdepth.append(len(waiting))
            if active == 0:
                step += 1
                continue
            for s in range(self.slots):
                req = self.slot_req[s]
                if req is None or req.done:
                    continue   # idle rows may overflow harmlessly (clamped
                    #            write, row fully rewritten at next admit)
                if self.slot_pos[s] + 1 > self.max_len:
                    raise RuntimeError(
                        f"KV cache exhausted: slot {s} (rid {req.rid}) at "
                        f"position {int(self.slot_pos[s])} with max_len "
                        f"{self.max_len} — size the engine with "
                        f"traces.cache_len_bound() for the trace")
            ts = time.perf_counter()
            toks = jnp.asarray(next_tok[:, None])
            logits, self.cache = self._decode(self.params, toks, self.cache)
            t_disp = time.perf_counter() if phase_log is not None else 0.0
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            if phase_log is not None:
                # dispatch ends when the async decode call returns; the
                # argmax readback above forced the device sync
                phase_log.append((t_disp - ts, time.perf_counter() - t_disp))
            if hook is not None:
                hook.fire()   # inside the timed sample, like harness.measure
            dt = time.perf_counter() - ts
            self.steps += 1
            step += 1
            self.slot_pos += 1   # decode advances every row's len vector
            for s in range(self.slots):
                req = self.slot_req[s]
                if req is None or req.done:
                    continue
                req.out.append(int(nxt[s]))
                tokens_out += 1
                tok_lat_s.append(dt)
                next_tok[s] = nxt[s]
                if len(req.out) >= req.max_new:
                    req.done = True
                    req.t_done = time.perf_counter()
                    active -= 1
                    done_count += 1
        wall = time.perf_counter() - t0
        return {"requests": total, "decode_steps": self.steps,
                "tokens": tokens_out, "wall_s": wall,
                "tok_per_s": tokens_out / wall if wall else 0.0,
                "ttft_s": ttft_s, "tok_lat_s": tok_lat_s,
                "queue_depth_mean": (sum(qdepth) / len(qdepth)) if qdepth else 0.0,
                "queue_depth_max": max(qdepth) if qdepth else 0,
                "tokens_by_rid": tokens_by_rid(requests)}

    def capture(self, requests: List[Request], *, seed: int = 0,
                source: str = "live") -> TraceSpec:
        """A replayable ``TraceSpec`` of a served trace: per-request prompt
        lengths, arrivals, and budgets pinned, prompt *content* regenerated
        from ``(seed, lengths)`` — so a live run becomes a regression asset
        via the ordinary ``save_spec`` schema (``trace="file:..."``)."""
        return capture_spec(requests, seed=seed, source=source)


def summarize_metrics(out: Dict[str, Any]) -> Dict[str, Any]:
    """The well-known serve metric keys (see ``runner/results.py``) from an
    engine ``run()`` payload: TTFT / per-token latency p50/p95/p99 in us,
    throughput, queue depth, and the token digest."""
    summary: Dict[str, Any] = {
        "tok_per_s": out["tok_per_s"],
        "decode_steps": out["decode_steps"],
        "queue_depth_mean": out["queue_depth_mean"],
        "queue_depth_max": out["queue_depth_max"],
        "tokens_digest": tokens_digest(out["tokens_by_rid"]),
    }
    summary.update(latency_summary(out["ttft_s"], "ttft", scale=1e6))
    summary.update(latency_summary(out["tok_lat_s"], "tok_lat", scale=1e6))
    return summary


def built_for_cfg(cfg, seed: int = 0):
    """Build (model + params) for an already-resolved config — the
    non-runner path shared by the ``Server`` shim and the ``--full`` CLI
    (the runner's ``built_for`` caches reduced builds instead)."""
    from repro.core.suite import Built
    from repro.models import build_model
    model = build_model(cfg)
    return Built(cfg=cfg, model=model, params=model.init(jax.random.key(seed)))


class Server(ServeEngine):
    """Compat shim over ``ServeEngine`` for direct (non-runner) callers:
    builds the model from a config, like the pre-runner serving driver."""

    def __init__(self, cfg, *, slots: int, max_len: int, seed: int = 0):
        super().__init__(built_for_cfg(cfg, seed), slots=slots,
                         max_len=max_len)


def _splice_cache(big, one, slot: int):
    """Write single-row cache `one` into row `slot` of the batched cache.

    Every cache leaf — including the per-layer `len` position vectors — is
    batched over slots, so admission is a plain row write: the fresh row
    (KV contents *and* its position) replaces whatever the retired request
    left behind.  Equal shapes means a single-slot engine: the fresh cache
    replaces the old one wholesale."""
    def f(b, s):
        if b.ndim == s.ndim and b.shape == s.shape:
            return s
        # find the batch axis: first axis where shapes differ
        for ax in range(b.ndim):
            if b.shape[ax] != s.shape[ax]:
                idx = [0] * b.ndim
                idx[ax] = slot
                return jax.lax.dynamic_update_slice(b, s.astype(b.dtype), tuple(idx))
        return b
    return jax.tree.map(f, big, one)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--trace", default="uniform",
                    help="load profile: uniform | bursty | mixed")
    ap.add_argument("--prompt-profile", default="fixed",
                    help="prompt-length profile: fixed | uniform | bimodal "
                         "| longtail")
    ap.add_argument("--capture", default="",
                    help="write a replayable TraceSpec of this run to PATH")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    from repro.core.suite import build_arch
    from repro.configs import get_arch
    if args.full:
        built = built_for_cfg(get_arch(args.arch))
    else:
        built = build_arch(args.arch)
    spec = TraceSpec(profile=args.trace, requests=args.requests,
                     prompt_len=args.prompt_len, max_new=args.max_new,
                     seed=args.seed, prompt_profile=args.prompt_profile)
    reqs = generate(spec, vocab=built.cfg.vocab)
    prefix = built.cfg.n_prefix if built.cfg.family == "vlm" else 0
    engine = ServeEngine(built, slots=args.slots,
                         max_len=cache_len_bound(reqs, prefix=prefix))
    out = engine.run(reqs)
    m = summarize_metrics(out)
    if args.capture:
        save_spec(engine.capture(reqs, seed=args.seed,
                                 source=f"cli:{args.arch}"), args.capture)
        print(f"captured trace spec -> {args.capture}")
    print(f"served {args.requests} requests ({args.trace}): {out['tokens']} tokens "
          f"in {out['wall_s']:.2f}s ({m['tok_per_s']:.1f} tok/s, "
          f"{out['decode_steps']} steps)")
    print(f"  ttft_us    p50={m.get('ttft_p50', 0):.0f} "
          f"p95={m.get('ttft_p95', 0):.0f} p99={m.get('ttft_p99', 0):.0f}")
    print(f"  tok_lat_us p50={m.get('tok_lat_p50', 0):.0f} "
          f"p95={m.get('tok_lat_p95', 0):.0f} p99={m.get('tok_lat_p99', 0):.0f}")
    print(f"  queue_depth mean={m['queue_depth_mean']:.2f} max={m['queue_depth_max']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
