"""Jit-able train / prefill / decode steps with sharding-aware state.

``make_state_defs`` declares (params, opt state) as ParamDef trees so the
launcher can derive NamedShardings without materializing anything —
``jax.eval_shape`` + these defs are all the dry-run needs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import build_model
from repro.models.layers import ParamDef
from repro.optim.adamw import AdamWConfig, OptState, adamw_update, opt_state_defs
from repro.optim.schedule import cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    microbatches: int = 1       # gradient accumulation over the batch dim


def make_state_defs(model) -> Tuple[Any, OptState]:
    pdefs = model.param_defs()
    return pdefs, opt_state_defs(pdefs)


def make_train_step(cfg, hyper: TrainHyper = TrainHyper(),
                    opt_cfg: AdamWConfig = AdamWConfig()):
    """(state, batch) -> (state, metrics); state = (params, opt_state)."""
    model = build_model(cfg)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def train_step(state, batch):
        params, opt = state
        if hyper.microbatches > 1:
            mb = hyper.microbatches
            B = batch["tokens"].shape[0]
            assert B % mb == 0

            def split(x):
                return x.reshape((mb, B // mb) + x.shape[1:])

            mbatch = jax.tree.map(split, batch)

            def acc_body(carry, mb_i):
                (g_acc, l_acc) = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb_i)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(acc_body, (g0, 0.0), mbatch)
            grads = jax.tree.map(lambda g: g / mb, grads)
            metrics = {"loss": loss_sum / mb, "ppl": jnp.exp(loss_sum / mb)}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        lr = cosine_schedule(opt.step, hyper.warmup_steps, hyper.total_steps, hyper.lr)
        params, opt, om = adamw_update(params, grads, opt, opt_cfg, lr=lr)
        return (params, opt), {**metrics, **om, "lr": lr}

    return train_step, model


def make_prefill_step(cfg, max_len: int):
    model = build_model(cfg)

    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        return logits, cache

    return prefill_step, model


def make_decode_step(cfg):
    model = build_model(cfg)

    def serve_step(params, tokens, cache):
        logits, cache = model.decode_step(params, tokens, cache)
        return logits, cache

    return serve_step, model
