"""End-to-end training driver (example application and CI workhorse).

Runs on whatever devices exist: single CPU (reduced configs, real steps —
the measured path used by the regression CI) or a real TPU mesh (full
configs).  Wires together every substrate: data pipeline, model, optimizer,
checkpointing, supervisor (fault tolerance), metrics.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticTokenDataset
from repro.distributed import merge_rules, sharding_ctx, spec_tree
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import TrainHyper, make_state_defs, make_train_step
from repro.models.layers import init_tree
from repro.optim.adamw import adamw_init
from repro.runtime import HeartbeatMonitor, Supervisor


def build_trainer(cfg, *, batch: int, seq: int, hyper: TrainHyper = TrainHyper(),
                  mesh=None, rules=None, seed: int = 0):
    """-> (state, jitted step fn, dataset)."""
    rules = merge_rules(rules)
    with sharding_ctx(mesh, rules):
        step, model = make_train_step(cfg, hyper)
        params = model.init(jax.random.key(seed))
        opt = adamw_init(params)
        state = (params, opt)
        if mesh is not None:
            shardings = spec_tree(make_state_defs(model), mesh, rules)
            state = jax.device_put(state, shardings)
            jstep = jax.jit(step, in_shardings=(shardings, None),
                            out_shardings=(shardings, None), donate_argnums=(0,))
        else:
            jstep = jax.jit(step, donate_argnums=(0,))
    ds = SyntheticTokenDataset(DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=seed))
    return state, jstep, ds, model


def _device_batch(cfg, ds, step_idx: int, seq: int):
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step_idx).items()}
    if cfg.family == "encdec":
        b = batch["tokens"].shape[0]
        key = jax.random.key(step_idx)
        batch["frames"] = jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        b = batch["tokens"].shape[0]
        key = jax.random.key(step_idx)
        batch["patch_embeds"] = jax.random.normal(key, (b, cfg.n_prefix, cfg.d_model)) * 0.02
    return batch


def train(arch: str, *, steps: int, batch: int, seq: int, reduced: bool = True,
          ckpt_dir: Optional[str] = None, save_every: int = 20,
          log_every: int = 10, inject_fault_at: Optional[int] = None,
          seed: int = 0) -> Dict[str, Any]:
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    state, jstep, ds, model = build_trainer(cfg, batch=batch, seq=seq, seed=seed)

    history = []
    t_start = time.perf_counter()

    def one_step(st, i):
        if inject_fault_at is not None and i == inject_fault_at:
            if not getattr(one_step, "_fired", False):
                one_step._fired = True
                raise RuntimeError("injected node failure")
        b = _device_batch(cfg, ds, i, seq)
        st, metrics = jstep(st, b)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i, **m})
            print(f"step {i:5d} loss {m['loss']:.4f} ppl {m['ppl']:.1f} "
                  f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e}")
        return st

    if ckpt_dir:
        ckpt = CheckpointManager(ckpt_dir, keep=2)
        sup = Supervisor(ckpt, save_every=save_every, monitor=HeartbeatMonitor(1))
        restored, rstep = ckpt.restore_latest(state)
        start = 0
        if restored is not None:
            state, start = restored, rstep
            print(f"resumed from step {start}")
        state, _ = sup.run(state, one_step, steps, start_step=start)
        events = sup.events
    else:
        for i in range(steps):
            state = one_step(state, i)
        events = []

    wall = time.perf_counter() - t_start
    return {"history": history, "wall_s": wall, "events": events,
            "final_loss": history[-1]["loss"] if history else None}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true", help="full (assigned) config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--inject-fault-at", type=int, default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    out = train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
                reduced=not args.full, ckpt_dir=args.ckpt_dir,
                inject_fault_at=args.inject_fault_at)
    print(f"done in {out['wall_s']:.1f}s, final loss {out['final_loss']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
