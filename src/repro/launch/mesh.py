"""Production mesh construction.

``make_production_mesh`` is a *function* (never a module-level constant) so
importing this module does not touch jax device state — smoke tests see one
CPU device; only ``dryrun.py`` forces 512 host devices.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Optional[Mesh]:
    """Whatever devices exist, as a 1-D 'data' mesh (CPU smoke paths)."""
    n = len(jax.devices())
    if n == 1:
        return None
    return jax.make_mesh((n,), ("data",))
