import os
import sys
_DUMP_DIR = f"/tmp/repro_hlo_dump_{os.getpid()}"
# The 512 placeholder devices are needed only where cells actually compile:
# the ``python -m repro.launch.dryrun`` subprocess and scripts/dump_cell.py.
# Under pytest this module is imported for its pure helpers (cell_rules,
# input_specs) and the flags must NOT leak into the test process — tests
# measure on the single real CPU device (see tests/conftest.py).
if "pytest" not in sys.modules:
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        f"--xla_dump_to={_DUMP_DIR} --xla_dump_hlo_pass_re=spmd-partitioning"
    )

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The statements above MUST stay first in this module — jax
locks the device count at first backend init, and the production meshes
(16x16 and 2x16x16) need 512 placeholder host devices.  Nothing here
allocates real buffers: inputs are ShapeDtypeStructs, compilation is AOT.

Per cell this emits:
  * memory_analysis()  — per-device bytes: proves the cell fits HBM
  * cost_analysis()    — XLA's per-partition FLOPs/bytes (recorded raw)
  * trip-count-corrected FLOPs/bytes/collective bytes (repro.core.hloanalysis)
  * the three roofline terms (repro.core.roofline)

Run:  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
          --shape train_4k [--multi-pod] [--json out.json]
      PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_arch, get_shape, shape_applicable
from repro.core.hloanalysis import analyze_hlo
from repro.core.roofline import model_flops_estimate, roofline_from_cost
from repro.distributed import merge_rules, sharding_ctx, spec_tree
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_state_defs, make_train_step
from repro.models.layers import ParamDef, abstract_tree


def input_specs(cfg, shape) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    from repro.data.pipeline import make_batch_specs
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}
    return make_batch_specs(cfg, shape)


import jax.numpy as _jnp
OPT_CFG = dict(opt_bf16_probs=True, opt_ce_chunk=512, opt_gate_bf16=True,
               param_dtype=_jnp.bfloat16,   # bf16 weights, fp32 Adam moments:
               # halves FSDP all-gathers, grad reduce-scatters, weight reads
               attn_chunk=512)              # halves peak score-chunk footprint


# Small dense archs where TP16 never pays at train_4k: use the model axis as
# extra data parallelism (DP256 + 2D-FSDP weights, vocab stays TP).  §Perf C.
OPT_TRAIN_DP256 = {"gemma-2b", "paligemma-3b"}

# Prefill cells whose full-length GQA cache must shard over sequence to fit
# (KV heads don't divide the model axis; see cell_rules).
OPT_PREFILL_SEQ_CACHE = {"internlm2-20b", "nemotron-4-15b", "mixtral-8x7b",
                         "whisper-large-v3"}

DP256_RULES: Dict[str, Any] = {
    "act_batch": ("pod", "data", "model"),
    "act_mlp": None, "act_heads": None, "act_kv_heads": None,
    "act_q_seq": None,
    "w_mlp": None, "w_heads": None, "w_kv_heads": None, "w_expert_mlp": None,
    "w_embed": ("data", "model"),
}


# Per-(arch-family, shape-kind) sharding-rule overrides (see DESIGN.md).
def cell_rules(cfg, shape, opt: bool = False) -> Dict[str, Any]:
    rules: Dict[str, Any] = {}
    base_name = cfg.name.replace("-optimized", "")
    if opt and shape.kind == "train" and base_name in OPT_TRAIN_DP256:
        rules.update(DP256_RULES)
    elif opt and cfg.n_heads and cfg.n_heads % 16:
        # heads cannot use the 16-way model axis -> sequence-parallel
        # attention (q positions over 'model'); kv is tiny (MQA) or small.
        # (whisper: train only — at prefill/decode its cross-attention
        # resharding dominates and SP regresses; measured in §Perf.)
        if cfg.family != "encdec" or shape.kind == "train":
            rules["act_q_seq"] = ("model",)
    if shape.kind == "decode":
        # KV heads never divide the 16-way model axis on the assigned archs;
        # shard the cache (and its attention reduction) over sequence instead.
        rules["cache_seq"] = ("model",)
        rules["cache_heads"] = None
        if shape.global_batch < 16:
            # long_500k: batch 1 -> sequence parallelism over data too
            rules["cache_seq"] = ("model",)
            rules["cache_batch"] = None
    if shape.kind == "prefill" and shape.global_batch < 16:
        rules["act_seq"] = ("data",)
    if opt and shape.kind == "prefill" and cfg.name.split("-optimized")[0] in OPT_PREFILL_SEQ_CACHE:
        # KV heads don't divide the model axis: a head-sharded cache
        # replicates 16x on these large-KV archs.  Shard it over sequence
        # (40 -> 6.6 GB/dev on internlm2).  Not applied to MLA (deepseek:
        # tiny latent cache, resharding dominates) or ring-cache archs.
        rules["cache_seq"] = ("model",)
        rules["cache_heads"] = None
    return rules


# Gradient-accumulation factor per arch for train_4k: chosen so the per-
# device live set (params + opt state + microbatch activations + logits)
# fits 16 GB v5e HBM.  Tuned during the baseline sweep (EXPERIMENTS.md).
TRAIN_MICROBATCHES = {
    "gemma-2b": 4,
    "internlm2-20b": 16,
    "nemotron-4-15b": 16,
    "gemma3-12b": 4,
    "deepseek-v2-236b": 16,
    "mixtral-8x7b": 16,
    "whisper-large-v3": 8,
    "paligemma-3b": 4,
    "mamba2-2.7b": 8,
    "recurrentgemma-9b": 4,
}


def _analyze_post_spmd(compiled):
    """Cost the post-SPMD-partitioning, pre-fusion HLO dump.

    The CPU backend legalizes bf16 dots to f32 before fusion, which would
    misprice the TPU target's bytes and collective wire sizes by up to 2x;
    the post-partitioning dump has per-device shapes + collectives with the
    dtypes the program specifies.  Falls back to the compiled module text
    (fused, CPU-legalized) when the dump is unavailable.
    """
    import glob
    files = sorted(glob.glob(os.path.join(_DUMP_DIR, "*after_spmd-partitioning*.txt")),
                   key=os.path.getmtime)
    if files:
        with open(files[-1]) as f:
            return analyze_hlo(f.read(), fused_bytes=True), "post_spmd_partitioning"
    return analyze_hlo(compiled.as_text()), "compiled_fallback"


def _mesh_name(multi_pod: bool) -> str:
    return "2x16x16" if multi_pod else "16x16"


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             rules_override: Optional[Dict[str, Any]] = None,
             opt: bool = False, microbatches: Optional[int] = None,
             verbose: bool = True) -> Dict[str, Any]:
    import dataclasses as _dc
    cfg = get_arch(arch)
    if opt:
        cfg = _dc.replace(cfg, **OPT_CFG)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    out: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": _mesh_name(multi_pod)}
    if not ok:
        out["skipped"] = why
        if verbose:
            print(f"[dryrun] {arch} x {shape_name}: SKIPPED ({why})")
        return out

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rules = merge_rules(cell_rules(cfg, shape, opt), rules_override)

    t0 = time.time()
    with sharding_ctx(mesh, rules):
        if shape.kind == "train":
            from repro.launch.steps import TrainHyper
            mb = microbatches if microbatches is not None else TRAIN_MICROBATCHES.get(arch, 1)
            if opt and arch in OPT_TRAIN_DP256 and microbatches is None:
                mb = 1   # DP256 shards the batch over all 256/512 chips
            step, model = make_train_step(cfg, TrainHyper(microbatches=mb))
            out["microbatches"] = mb
            pdefs, odefs = make_state_defs(model)
            state_defs = (pdefs, odefs)
            state_shardings = spec_tree(state_defs, mesh, rules)
            state_abstract = abstract_tree(state_defs)
            batch = input_specs(cfg, shape)
            batch_shardings = {
                k: NamedSharding(mesh, P(*(("pod", "data") if "pod" in mesh.shape else ("data",))))
                if v.ndim > 1 else NamedSharding(mesh, P())
                for k, v in batch.items()}
            # tokens (B, S): shard batch dim only
            batch_shardings = {
                k: NamedSharding(mesh, P(("pod", "data") if "pod" in mesh.shape else "data"))
                for k in batch}
            jitted = jax.jit(step, in_shardings=(state_shardings, batch_shardings),
                             out_shardings=(state_shardings, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abstract, batch)
        else:
            model_tmp = make_decode_step(cfg)[1]
            max_len = shape.seq_len + (cfg.n_prefix or 0)
            cache_defs = model_tmp.cache_defs(shape.global_batch, max_len)
            cache_shardings = spec_tree(cache_defs, mesh, rules)
            cache_abstract = abstract_tree(cache_defs)
            pdefs = model_tmp.param_defs()
            p_shardings = spec_tree(pdefs, mesh, rules)
            p_abstract = abstract_tree(pdefs)
            if shape.kind == "prefill":
                step, model = make_prefill_step(cfg, shape.seq_len)
                batch = input_specs(cfg, shape)
                dspec = ("pod", "data") if "pod" in mesh.shape else "data"
                bsh = {k: NamedSharding(mesh, P(dspec)) for k in batch}
                jitted = jax.jit(step, in_shardings=(p_shardings, bsh, cache_shardings),
                                 out_shardings=(None, cache_shardings),
                                 donate_argnums=(2,))
                lowered = jitted.lower(p_abstract, batch, cache_abstract)
            else:
                step, model = make_decode_step(cfg)
                toks = input_specs(cfg, shape)["tokens"]
                dspec = ("pod", "data") if "pod" in mesh.shape else "data"
                tsh = NamedSharding(mesh, P(dspec if shape.global_batch >= 16 else None))
                jitted = jax.jit(step, in_shardings=(p_shardings, tsh, cache_shardings),
                                 out_shardings=(None, cache_shardings),
                                 donate_argnums=(2,))
                lowered = jitted.lower(p_abstract, toks, cache_abstract)

        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # [dict] on some jax versions
        ca = ca[0] if ca else {}
    cost, cost_src = _analyze_post_spmd(compiled)
    rl = roofline_from_cost(
        cost, arch=arch, shape=shape_name, mesh=_mesh_name(multi_pod),
        chips=chips, model_flops=model_flops_estimate(cfg, shape))

    out.update({
        "compile_s": round(t1 - t0, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0)),
        },
        "xla_cost_analysis": {"flops_per_partition": float(ca.get("flops", 0.0)),
                              "bytes_per_partition": float(ca.get("bytes accessed", 0.0))},
        "cost_source": cost_src,
        "roofline": rl.to_dict(),
        "hlo_notes": cost.notes[:10],
    })
    if verbose:
        m = out["memory"]
        per_dev = (m["argument_bytes"] + m["temp_bytes"]) / 1e9
        print(f"[dryrun] {arch} x {shape_name} x {out['mesh']}: compiled in {out['compile_s']}s | "
              f"args+temp {per_dev:.2f} GB/dev | "
              f"terms c/m/n = {rl.compute_s*1e3:.1f}/{rl.memory_s*1e3:.1f}/{rl.collective_s*1e3:.1f} ms | "
              f"dominant={rl.dominant} useful={rl.useful_ratio:.2f}")
        print(f"  memory_analysis: {mem}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--rules", default=None, help="JSON dict of logical-rule overrides")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper optimized configuration (see EXPERIMENTS.md §Perf)")
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args(argv)

    rules_override = json.loads(args.rules) if args.rules else None
    results = []
    if args.all:
        cells = [(a, s) for a in sorted(ARCHS) for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    failed = 0
    for arch, shp in cells:
        try:
            results.append(run_cell(arch, shp, multi_pod=args.multi_pod,
                                    rules_override=rules_override, opt=args.opt,
                                    microbatches=args.microbatches))
        except Exception as e:  # noqa: BLE001 — report all failures at end
            failed += 1
            results.append({"arch": arch, "shape": shp, "error": f"{type(e).__name__}: {e}"})
            print(f"[dryrun] {arch} x {shp}: FAILED {type(e).__name__}: {e}", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
