"""Kernel autotuning: detector-driven Pallas launch-parameter search
through the unified runner.

The offline/online autotuner pattern (sweep candidate configs, persist
winners, serve them transparently on later traces) applied to the Pallas
kernels' launch parameters:

* ``tuning.space``  — per-kernel search spaces: valid, VMEM-bounded
  candidates derived from the input shape, encoded as scenario archs;
* ``tuning.sweep``  — case expansion into a ``task="kernel"``
  ``ScenarioMatrix`` dispatched through ``BenchmarkRunner.run_matrix``
  (parallel under ``jobs=N`` / ``cluster=`` for free) + winner
  selection into the DB;
* ``tuning.db``     — the schema-tagged JSON DB ``kernels/*/ops.py``
  consult at trace time when callers pass no explicit block sizes;
* ``tuning.bridge`` — profiler findings (``data_movement_bound`` /
  ``low_util``) -> enqueued tuning jobs, closing profile -> optimize.
"""
from repro.tuning.bridge import (TUNE_RULES, cases_for_record,
                                 cases_from_jobs, drain_queue, enqueue_jobs,
                                 jobs_from_findings, kernels_for_arch,
                                 load_queue)
from repro.tuning.db import TuningDB, tuned_params
from repro.tuning.space import (KernelCase, candidate_id, candidates,
                                default_params, make_case, parse_candidate,
                                parse_case, vmem_bytes)
from repro.tuning.sweep import run_sweep, sweep_matrix

__all__ = [
    "TUNE_RULES", "TuningDB", "KernelCase", "candidate_id", "candidates",
    "cases_for_record", "cases_from_jobs", "default_params", "drain_queue",
    "enqueue_jobs",
    "jobs_from_findings", "kernels_for_arch", "load_queue", "make_case",
    "parse_candidate", "parse_case", "run_sweep", "sweep_matrix",
    "tuned_params", "vmem_bytes",
]
