"""The detector bridge: profiler findings -> enqueued tuning jobs.

Closes the paper's profile -> find -> fix loop (the half PR 4 left open):
``data_movement_bound`` and ``low_util`` findings are exactly the
signatures a better kernel launch shape can move — a memory-bound cell
wants tiles that reuse more per byte, a low-utilization cell wants tiles
that fill the machine — so each such finding on a profiled cell enqueues
tuning jobs for the Pallas kernels its arch *uses* (attention archs ->
flash_attention, ``d_state`` archs -> ssd, ``lru_width`` archs ->
rglru), shaped by the cell's own (batch, seq) and the arch's reduced
config (the config the measured cells actually build).

The queue is a schema-tagged JSON file next to the tuning DB
(``results/tuning_queue.json``): ``benchmarks/profile_report.py`` writes
it after detection, and ``cases_from_jobs`` turns it back into
``KernelCase``s for ``tuning.sweep.run_sweep``.  Jobs carry an
``in_db`` flag so a report can tell "needs sweeping" from "already
tuned, still slow" — the latter is a real finding about the kernel, not
the launch shape.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Union

from repro.tuning import space
from repro.tuning.db import TuningDB, entry_key

QUEUE_SCHEMA_KEY = "tuning_queue"
QUEUE_SCHEMA_VERSION = 1

#: detector rules that enqueue tuning work — the launch-shape-sensitive
#: inefficiency signatures (see module docstring)
TUNE_RULES = ("data_movement_bound", "low_util")


def kernels_for_arch(arch: str) -> List[str]:
    """The Pallas kernels this arch's layers map onto (empty for unknown
    archs and for kernel-cell pseudo-archs — nothing to tune)."""
    from repro.configs import get_arch
    try:
        cfg = get_arch(arch)
    except KeyError:
        return []
    kernels: List[str] = []
    if cfg.family != "ssm":
        kernels.append("flash_attention")   # attention layers
    if cfg.d_state:
        kernels.append("ssd")               # mamba2 mixer layers
    if cfg.lru_width:
        kernels.append("rglru")             # griffin recurrent layers
    return kernels


def cases_for_record(rec: dict) -> List[space.KernelCase]:
    """Tuning cases for one profiled RunResult dict: the cell's own
    (batch, seq, dtype) crossed with its arch's kernel shapes, taken from
    the reduced config — the config the measured cells actually build."""
    from repro.configs import get_arch
    arch, task = rec.get("arch", ""), rec.get("task", "")
    batch, seq = int(rec.get("batch") or 0), int(rec.get("seq") or 0)
    dtype = rec.get("dtype", "fp32")
    if task == "kernel" or batch < 1 or seq < 1 or not kernels_for_arch(arch):
        return []
    cfg = get_arch(arch).reduced()
    cases = []
    for kernel in kernels_for_arch(arch):
        if kernel == "flash_attention":
            cases.append(space.make_case(
                "flash_attention", dtype=dtype, B=batch, S=seq,
                H=cfg.n_heads, K=cfg.n_kv_heads, D=cfg.head_dim))
        elif kernel == "ssd":
            cases.append(space.make_case(
                "ssd", dtype=dtype, B=batch, S=seq, H=cfg.n_ssm_heads,
                P=cfg.ssm_headdim, N=cfg.d_state))
        elif kernel == "rglru":
            cases.append(space.make_case(
                "rglru", dtype=dtype, B=batch, S=seq, D=cfg.lru_width))
    return cases


def jobs_from_findings(findings: Iterable, records: Iterable[dict], *,
                       db: Optional[TuningDB] = None) -> List[dict]:
    """Tuning jobs for the launch-shape-sensitive findings of one detect()
    pass.  Findings come ranked most-severe first and jobs are deduped by
    (case, dtype) keeping the first — so each job's ``source_rule`` /
    ``severity`` reflect the strongest finding that wants it.  ``db``
    (default: the ambient tuning DB) sets each job's ``in_db`` flag."""
    recs: Dict[str, dict] = {}
    for r in records:
        d = r.to_dict() if hasattr(r, "to_dict") else dict(r)
        recs[d.get("name", "")] = d
    if db is None:
        try:
            db = TuningDB.load()
        except ValueError:
            db = TuningDB()
    jobs: List[dict] = []
    seen = set()
    for f in findings:
        fd = f.to_dict() if hasattr(f, "to_dict") else dict(f)
        if fd.get("rule") not in TUNE_RULES:
            continue
        rec = recs.get(fd.get("cell", ""))
        if rec is None:
            continue
        for case in cases_for_record(rec):
            key = (case.case_id, case.dtype)
            if key in seen:
                continue
            seen.add(key)
            jobs.append({
                "kernel": case.kernel,
                "case": case.case_id,
                "signature": case.signature,
                "dtype": case.dtype,
                "source_rule": fd.get("rule"),
                "source_cell": fd.get("cell"),
                "severity": fd.get("severity"),
                "in_db": db.lookup(case.kernel, case.signature,
                                   case.dtype) is not None,
            })
    return jobs


def cases_from_jobs(jobs: Sequence[dict]) -> List[space.KernelCase]:
    """Queue jobs back into sweep input (malformed entries are skipped —
    a hand-edited queue must not kill the sweep)."""
    out = []
    for j in jobs:
        try:
            out.append(space.parse_case(j["case"], dtype=j.get("dtype", "fp32")))
        except (KeyError, TypeError, ValueError):
            continue
    return out


def default_queue_path() -> Path:
    """Next to the tuning DB: ``results/tuning_queue.json`` (or beside an
    ``REPRO_TUNING_DB`` override)."""
    from repro.tuning.db import default_path
    return default_path().parent / "tuning_queue.json"


def enqueue_jobs(jobs: Sequence[dict],
                 path: Optional[Union[str, Path]] = None) -> Path:
    """Merge jobs into the schema-tagged queue file (dedup by (case,
    dtype), new jobs refresh old entries); returns the queue path."""
    p = Path(path) if path is not None else default_queue_path()
    existing = []
    if p.exists():
        try:
            existing = load_queue(p)
        except ValueError:
            existing = []    # wrong tag: a rewrite, not a merge
    merged: Dict = {}
    for j in list(existing) + list(jobs):
        if isinstance(j, dict) and "case" in j:
            merged[(j["case"], j.get("dtype", "fp32"))] = dict(j)
    p.parent.mkdir(parents=True, exist_ok=True)
    payload = {QUEUE_SCHEMA_KEY: QUEUE_SCHEMA_VERSION,
               "jobs": list(merged.values())}
    tmp = p.with_suffix(f".{os.getpid()}.tmp")
    tmp.write_text(json.dumps(payload, indent=1))
    os.replace(tmp, p)
    return p


def drain_queue(runner, *, queue_path: Optional[Union[str, Path]] = None,
                max_candidates: Optional[int] = None) -> dict:
    """Sweep every queued tuning job through ``runner`` and empty the
    queue — the core of ``benchmarks/profile_report --drain-queue``,
    shared with the fleet scheduler's stride-gated drain.

    Queued jobs become kernel micro-bench cells (``cases_from_jobs`` ->
    ``tuning.sweep.run_sweep``); winners land in the ambient tuning DB
    and the queue file is rewritten empty (malformed jobs are dropped
    with it — re-running a detector re-enqueues anything still
    relevant).  Returns ``{"jobs", "cases", "recorded", "db_path",
    "case_rows"}``; ``case_rows`` are the per-case sweep summaries for
    callers that format output."""
    p = Path(queue_path) if queue_path is not None else default_queue_path()
    jobs = load_queue(p)
    cases = cases_from_jobs(jobs)
    if not cases:
        return {"jobs": len(jobs), "cases": 0, "recorded": 0,
                "db_path": "", "case_rows": [], "queue_path": str(p)}
    from repro.tuning.sweep import run_sweep
    summary = run_sweep(cases, runner, max_candidates=max_candidates)
    # all jobs were attempted: rewrite the queue empty (enqueue_jobs
    # merges, so write the schema-tagged empty payload directly)
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_suffix(f".{os.getpid()}.tmp")
    tmp.write_text(json.dumps({QUEUE_SCHEMA_KEY: QUEUE_SCHEMA_VERSION,
                               "jobs": []}))
    os.replace(tmp, p)
    return {"jobs": len(jobs), "cases": len(cases),
            "recorded": summary["recorded"], "db_path": summary["db_path"],
            "case_rows": summary["cases"], "queue_path": str(p)}


def load_queue(path: Optional[Union[str, Path]] = None) -> List[dict]:
    """The queued jobs (empty if no queue file); raises ``ValueError`` on
    a schema-tag mismatch, like ``TuningDB.load``."""
    p = Path(path) if path is not None else default_queue_path()
    if not p.exists():
        return []
    raw = json.loads(p.read_text())
    if not isinstance(raw, dict) or raw.get(QUEUE_SCHEMA_KEY) != QUEUE_SCHEMA_VERSION:
        raise ValueError(f"{p} is not a tuning queue "
                         f"(want {QUEUE_SCHEMA_KEY}={QUEUE_SCHEMA_VERSION})")
    jobs = raw.get("jobs", [])
    return [j for j in jobs if isinstance(j, dict)] if isinstance(jobs, list) else []
