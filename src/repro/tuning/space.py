"""Per-kernel launch-parameter search spaces for the autotuner.

A **case** is one (kernel, shape, dtype) tuning problem; a **candidate**
is a concrete launch-parameter assignment for it.  Both have a stable
string encoding so they ride through the unified runner as an ordinary
``Scenario.arch`` axis (the ``task="kernel"`` micro-bench cells):

    case id        flash_attention@B2,S128,H4,K2,D64
    candidate id   flash_attention@B2,S128,H4,K2,D64@block_q=64,block_k=128

(no ``/`` — the scenario *name* uses ``/`` as its axis separator).

Guarantees the sweep engine builds on:

* every generated candidate is **valid for its shape**: bound-checked
  with the same ``kernels.validate`` helper the ops layer enforces (and
  rglru candidates are chosen from exact divisors, so the kernel's
  sequential-grid divisibility holds without padding);
* every candidate fits a conservative **VMEM footprint bound**
  (``VMEM_BUDGET_BYTES``, half of a TPU core's ~16 MB so double
  buffering fits) — no candidate can assert or OOM;
* the ops-layer **default** parameters are always candidate #0, so a
  sweep's winner is never slower than the default it replaces (argmin
  over a set containing the default, ties to the default);
* generation is deterministic: same case -> same candidate list.

Candidates are *measured through the ops layer* (``bench_callable``),
not the raw kernel: the measured cost then includes the padding /
layout work a served config would actually trigger, and the DB
signature is computed from exactly the shapes the ops layer sees at
trace time.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.kernels.validate import validate_block

#: conservative per-grid-cell VMEM footprint bound (bytes): half of a TPU
#: core's ~16 MB VMEM, leaving room for Pallas double buffering
VMEM_BUDGET_BYTES = 8 * 2 ** 20

#: max candidates per case (the default is always kept; the rest are the
#: largest-tile survivors — big tiles amortise grid overhead, small ones
#: win when the big ones spill)
MAX_CANDIDATES = 8

_DIM_RE = re.compile(r"^([A-Z][a-z]?)(\d+)$")
_PARAM_RE = re.compile(r"^([a-z_]+)=(\d+)$")


@dataclasses.dataclass(frozen=True)
class KernelCase:
    """One (kernel, shape, dtype) tuning problem (hashable)."""
    kernel: str
    dims: Tuple[Tuple[str, int], ...]
    dtype: str = "fp32"

    def __post_init__(self):
        if self.kernel not in KERNELS:
            raise ValueError(f"unknown kernel {self.kernel!r} "
                             f"(known: {tuple(KERNELS)})")
        want = KERNELS[self.kernel]["dims"]
        got = tuple(n for n, _ in self.dims)
        if got != want:
            raise ValueError(f"{self.kernel} case needs dims {want}, got {got}")
        for n, v in self.dims:
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"{self.kernel}: dim {n}={v!r} must be a "
                                 f"positive int")

    def dim(self, name: str) -> int:
        return dict(self.dims)[name]

    @property
    def case_id(self) -> str:
        dims = ",".join(f"{n}{v}" for n, v in self.dims)
        return f"{self.kernel}@{dims}"

    @property
    def signature(self) -> str:
        """The tuning-DB shape signature — the subset of dims the ops
        layer can recompute from its inputs at trace time."""
        return KERNELS[self.kernel]["signature"](dict(self.dims))


def make_case(kernel: str, *, dtype: str = "fp32", **dims) -> KernelCase:
    """Keyword-friendly constructor: ``make_case("rglru", B=1, S=64, D=64)``."""
    want = KERNELS.get(kernel, {}).get("dims", ())
    ordered = tuple((n, dims[n]) for n in want if n in dims)
    if len(ordered) != len(dims) or len(ordered) != len(want):
        raise ValueError(f"{kernel} case needs dims {want}, "
                         f"got {tuple(dims)}")
    return KernelCase(kernel=kernel, dims=ordered, dtype=dtype)


def parse_case(case_id: str, *, dtype: str = "fp32") -> KernelCase:
    parts = case_id.split("@")
    if len(parts) != 2:
        raise ValueError(f"malformed case id {case_id!r} "
                         f"(want 'kernel@DIMS')")
    kernel, dim_s = parts
    dims = []
    for tok in dim_s.split(","):
        m = _DIM_RE.match(tok)
        if not m:
            raise ValueError(f"malformed dim {tok!r} in case id {case_id!r}")
        dims.append((m.group(1), int(m.group(2))))
    return KernelCase(kernel=kernel, dims=tuple(dims), dtype=dtype)


def candidate_id(case: KernelCase, params: Dict[str, int]) -> str:
    order = KERNELS[case.kernel]["params"]
    ps = ",".join(f"{k}={params[k]}" for k in order)
    return f"{case.case_id}@{ps}"


def parse_candidate(cand_id: str, *,
                    dtype: str = "fp32") -> Tuple[KernelCase, Dict[str, int]]:
    parts = cand_id.split("@")
    if len(parts) != 3:
        raise ValueError(f"malformed candidate id {cand_id!r} "
                         f"(want 'kernel@DIMS@PARAMS')")
    case = parse_case("@".join(parts[:2]), dtype=dtype)
    params: Dict[str, int] = {}
    for tok in parts[2].split(","):
        m = _PARAM_RE.match(tok)
        if not m:
            raise ValueError(f"malformed param {tok!r} in candidate id "
                             f"{cand_id!r}")
        params[m.group(1)] = int(m.group(2))
    want = set(KERNELS[case.kernel]["params"])
    if set(params) != want:
        raise ValueError(f"{case.kernel} candidate needs params "
                         f"{sorted(want)}, got {sorted(params)}")
    return case, params


def _pow2s(lo: int, hi: int) -> List[int]:
    out, v = [], 1
    while v <= hi:
        if v >= lo:
            out.append(v)
        v *= 2
    return out


# ---- per-kernel search spaces -------------------------------------------

def _fa_signature(d: Dict[str, int]) -> str:
    return f"Sq{d['S']},Sk{d['S']},D{d['D']}"


def _fa_defaults(d: Dict[str, int]) -> Dict[str, int]:
    return {"block_q": min(128, d["S"]), "block_k": min(128, d["S"])}


def _fa_vmem(d: Dict[str, int], p: Dict[str, int], esize: int) -> int:
    bq, bk, D = p["block_q"], p["block_k"], d["D"]
    blocks = esize * (2 * bq * D + 2 * bk * D)        # q, o, k, v tiles
    scratch = 4 * (2 * bq + bq * D)                   # m, l, acc (fp32)
    inter = 4 * 2 * bq * bk                           # s, p intermediates
    return blocks + scratch + inter


def _fa_candidates(case: KernelCase) -> List[Dict[str, int]]:
    S = case.dim("S")
    lo = 16 if case.dtype == "bf16" else 8            # min sublane tile
    vals = _pow2s(min(lo, S), S)
    out = []
    for bq in vals:
        for bk in vals:
            if abs((bq.bit_length()) - (bk.bit_length())) > 1:
                continue                              # keep pairs squarish
            out.append({"block_q": bq, "block_k": bk})
    return out


def _rglru_signature(d: Dict[str, int]) -> str:
    return f"S{d['S']},D{d['D']}"


def _rglru_defaults(d: Dict[str, int]) -> Dict[str, int]:
    return {"block_t": min(16, d["S"]), "block_d": min(128, d["D"])}


def _rglru_vmem(d: Dict[str, int], p: Dict[str, int], esize: int) -> int:
    bt, bd = p["block_t"], p["block_d"]
    blocks = 4 * 3 * bt * bd                          # a, b, h tiles (fp32)
    inter = 4 * 2 * bt * bt * bd                      # seg, w (L x L x lanes)
    return blocks + inter + 4 * bd                    # + carried state


def _rglru_candidates(case: KernelCase) -> List[Dict[str, int]]:
    S, D = case.dim("S"), case.dim("D")
    # exact divisors: the sequential time grid carries state, so rglru
    # candidates never rely on ops-layer padding
    bts = [v for v in (8, 16, 32, 64) if v <= S and S % v == 0] or [min(16, S)]
    bds = [v for v in (32, 64, 128, 256) if v <= D and D % v == 0] or [min(128, D)]
    return [{"block_t": bt, "block_d": bd} for bt in bts for bd in bds]


def _ssd_signature(d: Dict[str, int]) -> str:
    return f"S{d['S']},P{d['P']},N{d['N']}"


def _ssd_defaults(d: Dict[str, int]) -> Dict[str, int]:
    return {"chunk": min(128, d["S"])}


def _ssd_vmem(d: Dict[str, int], p: Dict[str, int], esize: int) -> int:
    L, P, N = p["chunk"], d["P"], d["N"]
    blocks = esize * (2 * L * P + 2 * L * N + L)      # x, y, B, C, dt tiles
    inter = 4 * 3 * L * L                             # scores, seg, w
    return blocks + inter + 4 * N * P                 # + carried state


def _ssd_candidates(case: KernelCase) -> List[Dict[str, int]]:
    S = case.dim("S")
    lo = 16 if case.dtype == "bf16" else 8
    return [{"chunk": c} for c in (8, 16, 32, 64, 128, 256)
            if lo <= c <= S]


def _fa_bench(case: KernelCase, params: Dict[str, int]):
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import flash_attention
    d = dict(case.dims)
    dt = jnp.bfloat16 if case.dtype == "bf16" else jnp.float32
    B, S, H, K, D = d["B"], d["S"], d["H"], d["K"], d["D"]
    q = jax.random.normal(jax.random.key(1), (B, S, H, D), dt)
    k = jax.random.normal(jax.random.key(2), (B, S, K, D), dt)
    v = jax.random.normal(jax.random.key(3), (B, S, K, D), dt)
    bq, bk = params["block_q"], params["block_k"]

    def step(q, k, v):
        return flash_attention(q, k, v, block_q=bq, block_k=bk)

    return step, (q, k, v)


def _rglru_bench(case: KernelCase, params: Dict[str, int]):
    import jax
    import jax.numpy as jnp
    from repro.kernels.rglru.ops import rglru
    d = dict(case.dims)
    dt = jnp.bfloat16 if case.dtype == "bf16" else jnp.float32
    B, S, D = d["B"], d["S"], d["D"]
    x = jax.random.normal(jax.random.key(4), (B, S, D), dt)
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(5), (B, S, D)) * 2).astype(dt)
    bt, bd = params["block_t"], params["block_d"]

    def step(x, a):
        return rglru(x, a, block_t=bt, block_d=bd)

    return step, (x, a)


def _ssd_bench(case: KernelCase, params: Dict[str, int]):
    import jax
    import jax.numpy as jnp
    from repro.kernels.ssd.ops import ssd
    d = dict(case.dims)
    dt = jnp.bfloat16 if case.dtype == "bf16" else jnp.float32
    B, S, H, P, N = d["B"], d["S"], d["H"], d["P"], d["N"]
    x = jax.random.normal(jax.random.key(6), (B, S, H, P), dt)
    dts = jax.nn.softplus(jax.random.normal(jax.random.key(7), (B, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(jax.random.key(8), (H,)) * 0.3)
    Bm = (jax.random.normal(jax.random.key(9), (B, S, N)) * 0.3).astype(dt)
    Cm = (jax.random.normal(jax.random.key(10), (B, S, N)) * 0.3).astype(dt)
    chunk = params["chunk"]

    def step(x, dts, A, Bm, Cm):
        return ssd(x, dts, A, Bm, Cm, chunk=chunk)

    return step, (x, dts, A, Bm, Cm)


#: the kernel registry: dims order, tunable params, signature/default/
#: candidate/VMEM functions, and the ops-level bench builder
KERNELS: Dict[str, Dict] = {
    "flash_attention": {
        "dims": ("B", "S", "H", "K", "D"),
        "params": ("block_q", "block_k"),
        # bound constraints: the kernel masks the tail, blocks must fit
        "validate": lambda d, p: (
            validate_block("flash_attention", "S", d["S"], "block_q", p["block_q"]),
            validate_block("flash_attention", "S", d["S"], "block_k", p["block_k"])),
        "signature": _fa_signature,
        "defaults": _fa_defaults,
        "candidates": _fa_candidates,
        "vmem": _fa_vmem,
        "bench": _fa_bench,
    },
    "rglru": {
        "dims": ("B", "S", "D"),
        "params": ("block_t", "block_d"),
        "validate": lambda d, p: (
            validate_block("rglru", "S", d["S"], "block_t", p["block_t"]),
            validate_block("rglru", "D", d["D"], "block_d", p["block_d"])),
        "signature": _rglru_signature,
        "defaults": _rglru_defaults,
        "candidates": _rglru_candidates,
        "vmem": _rglru_vmem,
        "bench": _rglru_bench,
    },
    "ssd": {
        "dims": ("B", "S", "H", "P", "N"),
        "params": ("chunk",),
        "validate": lambda d, p: (
            validate_block("ssd", "S", d["S"], "chunk", p["chunk"]),),
        "signature": _ssd_signature,
        "defaults": _ssd_defaults,
        "candidates": _ssd_candidates,
        "vmem": _ssd_vmem,
        "bench": _ssd_bench,
    },
}


def default_params(case: KernelCase) -> Dict[str, int]:
    """The ops-layer fallback parameters for this case — what a DB miss
    serves today, and always candidate #0 of the sweep."""
    return KERNELS[case.kernel]["defaults"](dict(case.dims))


def vmem_bytes(case: KernelCase, params: Dict[str, int]) -> int:
    """Conservative per-grid-cell VMEM footprint estimate (bytes)."""
    esize = 2 if case.dtype == "bf16" else 4
    return KERNELS[case.kernel]["vmem"](dict(case.dims), params, esize)


def candidates(case: KernelCase,
               max_candidates: Optional[int] = None) -> List[Dict[str, int]]:
    """The deterministic candidate list for a case: the ops default first,
    then the largest-tile valid candidates under the VMEM budget, capped
    at ``max_candidates`` (default ``MAX_CANDIDATES``).  Every returned
    candidate passes the shared ``kernels.validate`` bound checks."""
    spec = KERNELS[case.kernel]
    dims = dict(case.dims)
    cap = MAX_CANDIDATES if max_candidates is None else max(1, max_candidates)
    default = default_params(case)
    raw = [default] + spec["candidates"](case)
    seen, out = set(), []
    for p in raw:
        key = tuple(sorted(p.items()))
        if key in seen:
            continue
        seen.add(key)
        if vmem_bytes(case, p) > VMEM_BUDGET_BYTES:
            continue
        try:
            spec["validate"](dims, p)
        except ValueError:
            continue
        out.append(p)
    if not out or out[0] != default:
        # the default must survive filtering: it is what a miss serves, so
        # it must be measured (and it is what today's code runs, so it
        # cannot be over budget in any configuration we ship)
        out = [default] + out
    head, tail = out[0], out[1:]
    tail.sort(key=lambda p: (-_tile_size(p), candidate_id(case, p)))
    return [head] + tail[:cap - 1]


def _tile_size(params: Dict[str, int]) -> int:
    n = 1
    for v in params.values():
        n *= v
    return n


def bench_callable(case: KernelCase,
                   params: Dict[str, int]) -> Tuple[Callable, Tuple]:
    """(step_fn, args) measuring this candidate through the ops layer
    (includes padding/layout cost; deterministic inputs per case)."""
    KERNELS[case.kernel]["validate"](dict(case.dims), params)
    return KERNELS[case.kernel]["bench"](case, params)


def result_extra(case: KernelCase, params: Dict[str, int]) -> Dict:
    """The well-known ``tuning_*`` extras for a kernel cell's RunResult
    (documented in ``runner/results.py``)."""
    return {"tuning_kernel": case.kernel,
            "tuning_case": case.case_id,
            "tuning_signature": case.signature,
            "tuning_params": dict(params),
            "tuning_default": params == default_params(case)}
