"""The persistent tuning DB: swept launch-parameter winners served back to
``kernels/*/ops.py`` at trace time.

A plain schema-tagged JSON file (the ``trace_spec`` idiom from
``runner/traces.py``), keyed by ``(kernel, shape-signature, dtype)``:

    {"tuning_db": 1,
     "entries": {
       "flash_attention|Sq128,Sk128,D64|fp32": {
         "params": {"block_q": 64, "block_k": 128},
         "median_us": 812.4,
         "default_params": {"block_q": 128, "block_k": 128},
         "default_us": 903.1,
         "case": "flash_attention@B2,S128,H4,K2,D64",
         "candidates": 6,
         "ts": 1754550000.0}}}

The shape **signature** is the part of the case the ops layer can
recompute at trace time from its actual inputs (``Sq.../Sk.../D...`` for
flash attention; ``S/D`` for rglru; ``S/P/N`` for ssd) — batch and head
counts are deliberately excluded: they scale the grid, not the per-cell
tile economics, so one swept entry serves every batch size.

Serving path (``tuned_params``): a module-level mtime-invalidated cache,
so consulting the DB on every trace costs one ``stat()`` — and a sweep
finishing in another process is picked up without a restart.  Misses,
unreadable files, and wrong schema tags all serve ``None`` (the ops
layer falls back to its built-in defaults); ``TuningDB.load`` by
contrast raises on a wrong tag, because an explicit load of a
non-tuning-DB file is a caller bug, not a cache miss.

The default location is ``results/tuning_db.json`` under the current
working directory, overridable with ``REPRO_TUNING_DB`` (how tests and
the smoke gate isolate their sweeps).  Stdlib-only on purpose: the ops
modules import this lazily inside their dispatch path and must never
drag benchmark infrastructure into a model trace.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Dict, Optional, Tuple, Union

SCHEMA_KEY = "tuning_db"
SCHEMA_VERSION = 1

PathLike = Union[str, Path]


def default_path() -> Path:
    env = os.environ.get("REPRO_TUNING_DB")
    if env:
        return Path(env)
    return Path.cwd() / "results" / "tuning_db.json"


def entry_key(kernel: str, signature: str, dtype: str) -> str:
    return f"{kernel}|{signature}|{dtype}"


class TuningDB:
    """Read-modify-write handle on one tuning-DB file (the sweep engine's
    side; the trace-time consult path is the module-level ``tuned_params``)."""

    def __init__(self, path: Optional[PathLike] = None):
        self.path = Path(path) if path is not None else default_path()
        self.entries: Dict[str, dict] = {}

    @classmethod
    def load(cls, path: Optional[PathLike] = None) -> "TuningDB":
        """Load an existing DB (empty handle if the file doesn't exist yet);
        raises ``ValueError`` on a schema-tag mismatch."""
        db = cls(path)
        if db.path.exists():
            raw = json.loads(db.path.read_text())
            if not isinstance(raw, dict) or raw.get(SCHEMA_KEY) != SCHEMA_VERSION:
                raise ValueError(
                    f"{db.path} is not a tuning DB "
                    f"(want {SCHEMA_KEY}={SCHEMA_VERSION}, "
                    f"got {raw.get(SCHEMA_KEY) if isinstance(raw, dict) else type(raw).__name__!r})")
            entries = raw.get("entries", {})
            db.entries = dict(entries) if isinstance(entries, dict) else {}
        return db

    def record(self, kernel: str, signature: str, dtype: str, *,
               params: dict, median_us: float,
               default_params: Optional[dict] = None,
               default_us: float = 0.0, case: str = "",
               candidates: int = 0, backend: str = "") -> dict:
        """Store one sweep winner; returns the stored entry.

        ``backend`` is sweep-time provenance (``jax.default_backend()``):
        tile economics tuned on one backend don't transfer, so the consult
        path ignores entries stamped with a different backend.  Empty
        means unknown (pre-provenance entries) and always serves."""
        entry = {"params": dict(params), "median_us": float(median_us),
                 "default_params": dict(default_params or {}),
                 "default_us": float(default_us), "case": case,
                 "candidates": int(candidates), "ts": time.time()}
        if backend:
            entry["backend"] = str(backend)
        self.entries[entry_key(kernel, signature, dtype)] = entry
        return entry

    def lookup(self, kernel: str, signature: str, dtype: str) -> Optional[dict]:
        return self.entries.get(entry_key(kernel, signature, dtype))

    def params(self, kernel: str, signature: str, dtype: str) -> Optional[dict]:
        e = self.lookup(kernel, signature, dtype)
        if not e or not isinstance(e.get("params"), dict):
            return None
        return dict(e["params"])

    def save(self) -> Path:
        """Atomic write (tmp + replace) so a concurrent ``tuned_params``
        reader never sees a torn file."""
        self.path.parent.mkdir(parents=True, exist_ok=True)
        payload = {SCHEMA_KEY: SCHEMA_VERSION, "entries": self.entries}
        tmp = self.path.with_suffix(f".{os.getpid()}.tmp")
        tmp.write_text(json.dumps(payload, indent=1, sort_keys=True))
        os.replace(tmp, self.path)
        _CACHE.pop(str(self.path), None)   # next consult re-reads
        return self.path


#: path -> (mtime_ns, size, entries) — the trace-time consult cache
_CACHE: Dict[str, Tuple[int, int, Dict[str, dict]]] = {}


def invalidate_cache() -> None:
    """Drop the consult cache (tests that swap ``REPRO_TUNING_DB``)."""
    _CACHE.clear()


def tuned_params(kernel: str, signature: str, dtype: str,
                 path: Optional[PathLike] = None) -> Optional[dict]:
    """The trace-time consult: the winning params dict for this
    (kernel, signature, dtype), or ``None`` on any kind of miss —
    no file, unreadable JSON, wrong schema tag, or no matching entry.
    Never raises: a broken DB must degrade to the built-in defaults,
    not break a model trace."""
    p = Path(path) if path is not None else default_path()
    try:
        st = p.stat()
    except OSError:
        return None
    key = str(p)
    stamp = (st.st_mtime_ns, st.st_size)
    cached = _CACHE.get(key)
    if cached is None or cached[:2] != stamp:
        try:
            raw = json.loads(p.read_text())
        except (OSError, ValueError):
            return None
        if not isinstance(raw, dict) or raw.get(SCHEMA_KEY) != SCHEMA_VERSION:
            return None
        entries = raw.get("entries", {})
        if not isinstance(entries, dict):
            entries = {}
        cached = (stamp[0], stamp[1], entries)
        _CACHE[key] = cached
    e = cached[2].get(entry_key(kernel, signature, dtype))
    if not isinstance(e, dict) or not isinstance(e.get("params"), dict):
        return None
    swept_on = e.get("backend", "")
    if swept_on and swept_on != _current_backend():
        # swept on a different backend: its tile choices are noise here —
        # fall back to the built-in defaults rather than serve them
        return None
    return dict(e["params"])


def _current_backend() -> str:
    """``jax.default_backend()``, lazily — this module stays importable
    (and the no-provenance consult path stays jax-free) on a bare stdlib;
    the first backend-stamped entry consulted pays the import."""
    try:
        import jax
        return str(jax.default_backend())
    except Exception:  # noqa: BLE001 — no jax == no way to mismatch
        return ""
