"""The sweep engine: (kernel, shape, dtype) cases -> an ordinary
``ScenarioMatrix`` of ``task="kernel"`` micro-bench cells -> DB winners.

Tuning is deliberately NOT a bespoke timing loop: each candidate becomes
one scenario (``arch`` = the candidate id, see ``tuning.space``) and the
whole sweep dispatches through ``BenchmarkRunner.run_matrix`` — so it is
embarrassingly parallel under ``jobs=N`` and ``cluster=`` for free, each
candidate's time is a normal ``RunResult`` in the ``ResultStore``, and
the measurement protocol (median-of-N, compile excluded, measurement
fence under sharded dispatch) is exactly the one every other table uses.

Winner selection: argmin of ``median_us`` over the case's OK cells, ties
resolved toward the default.  Because the ops default is always
candidate #0 of the search space, the recorded winner can never be
slower than the default it replaces — the tuned-vs-default ratio
(``default_us / winner_us``) is >= 1.0 by construction.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Sequence, Tuple

from repro.runner.results import RunResult
from repro.runner.scenario import ScenarioMatrix
from repro.tuning import space
from repro.tuning.db import TuningDB


def _sweep_backend() -> str:
    """Backend provenance stamped on every recorded winner: tile-size
    economics measured on cpu say nothing about tpu (and vice versa), so
    the consult path (``db.tuned_params``) drops mismatched entries."""
    try:
        import jax
        return str(jax.default_backend())
    except Exception:  # noqa: BLE001 — unstampable, entry serves anywhere
        return ""


def _case_cells(case: space.KernelCase,
                max_candidates: Optional[int] = None) -> List[Tuple[str, Dict[str, int]]]:
    """(candidate id, params) pairs for one case, default first."""
    return [(space.candidate_id(case, p), p)
            for p in space.candidates(case, max_candidates)]


def sweep_matrix(cases: Sequence[space.KernelCase], *,
                 max_candidates: Optional[int] = None) -> ScenarioMatrix:
    """Expand tuning cases into one ``ScenarioMatrix`` of kernel cells.

    The axes are unions across heterogeneous cases (candidate ids x
    batches x seqs x dtypes); exact-name ``filter`` regexes then keep
    precisely one cell per candidate — its own case's (batch, seq,
    dtype) — so the cartesian product never cross-multiplies cases.
    """
    archs: List[str] = []
    batches, seqs, dtypes = [], [], []
    filters: List[str] = []
    for case in cases:
        b, s = case.dim("B"), case.dim("S")
        for cid, _ in _case_cells(case, max_candidates):
            archs.append(cid)
            filters.append(f"^{re.escape(cid)}/kernel/b{b}/s{s}/{case.dtype}/jit$")
        for coll, v in ((batches, b), (seqs, s), (dtypes, case.dtype)):
            if v not in coll:
                coll.append(v)
    return ScenarioMatrix(archs=archs, tasks=("kernel",), batches=batches,
                          seqs=seqs, dtypes=dtypes, modes=("jit",),
                          filter=filters)


def run_sweep(cases: Sequence[space.KernelCase], runner, *,
              db: Optional[TuningDB] = None,
              max_candidates: Optional[int] = None,
              runs: Optional[int] = None,
              warmup: Optional[int] = None,
              save: bool = True) -> Dict:
    """Sweep every case through the runner and record winners in the DB.

    Returns a summary dict (one entry per case: winner params, winner /
    default medians, the tuned-vs-default ratio, and the per-candidate
    results) — what ``benchmarks/runner_bench.py`` persists under its
    ``"tuning"`` section.
    """
    cases = list(cases)
    if db is None:
        db = TuningDB.load()
    matrix = sweep_matrix(cases, max_candidates=max_candidates)
    results = runner.run_matrix(matrix, runs=runs, warmup=warmup)
    by_arch: Dict[str, RunResult] = {r.arch: r for r in results}
    summary: Dict = {"db_path": str(db.path), "cases": []}
    recorded = 0
    for case in cases:
        cells = _case_cells(case, max_candidates)
        default_id, default_params = cells[0]
        rows = []
        for cid, params in cells:
            r = by_arch.get(cid)
            rows.append({
                "candidate": cid, "params": params,
                "default": params == default_params,
                "status": r.status if r else "missing",
                "median_us": r.median_us if r and r.status == "ok" else None,
                "error": (r.error if r else "no result") or None,
            })
        ok = [row for row in rows if row["median_us"] is not None]
        entry = {"case": case.case_id, "kernel": case.kernel,
                 "signature": case.signature, "dtype": case.dtype,
                 "candidates": len(cells), "results": rows}
        if not ok:
            entry["status"] = "error"
            summary["cases"].append(entry)
            continue
        # argmin with ties toward the default: the DB never serves a
        # config that did not beat the default it replaces
        winner = min(ok, key=lambda row: (row["median_us"], not row["default"]))
        default_row = next((row for row in rows if row["default"]), None)
        default_us = default_row["median_us"] if default_row else None
        entry.update(status="ok", winner=winner["params"],
                     winner_us=winner["median_us"], default_us=default_us,
                     ratio=(default_us / winner["median_us"]
                            if default_us and winner["median_us"] else None))
        db.record(case.kernel, case.signature, case.dtype,
                  params=winner["params"], median_us=winner["median_us"],
                  default_params=default_params,
                  default_us=default_us or 0.0,
                  case=case.case_id, candidates=len(cells),
                  backend=_sweep_backend())
        recorded += 1
        summary["cases"].append(entry)
    if save and recorded:
        db.save()
    summary["recorded"] = recorded
    return summary
