"""The paper's CI use case (§4.2) end-to-end: nightly suite run, baseline
store, an injected "bad commit", detection at the 7% threshold, and binary-
search bisection to the culprit.

    PYTHONPATH=src python examples/regression_ci.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

from repro.core.ci import run_nightly  # noqa: E402
from repro.core.harness import RegressionHook  # noqa: E402
from repro.core.regression import Commit, MetricStore, bisect_commits  # noqa: E402
from repro.runner import BenchmarkRunner, Scenario  # noqa: E402


def main() -> int:
    store = MetricStore(tempfile.mktemp(suffix=".json"))
    archs = ["gemma-2b", "mamba2-2.7b"]
    # one runner for the whole CI day: nights and bisection probes share
    # cached arch builds and compiled executables
    runner = BenchmarkRunner(runs=3)

    print("== night 0: record baselines ==")
    rep = run_nightly(store, archs=archs, tasks=("train",), runs=3,
                      update_baseline=True, runner=runner)
    print(f"ran {rep.ran} benchmarks in {rep.wall_s:.1f}s")

    print("\n== night 1: a commit slows gemma-2b training by ~50ms/step ==")
    hooks = {"gemma-2b/train": RegressionHook(slowdown_s=0.05)}
    rep = run_nightly(store, archs=archs, tasks=("train",), runs=3, hooks=hooks,
                      runner=runner)
    print(f"ran {rep.ran} benchmarks in {rep.wall_s:.1f}s (cached executables)")
    for issue in rep.issues:
        print(f"ISSUE: {issue.benchmark} {issue.metric} +{issue.increase:.0%} "
              f"(baseline {issue.baseline:.0f}, observed {issue.observed:.0f})")
    assert any(i.metric == "median_us" for i in rep.issues)

    print("\n== bisect the day's 12 commits ==")
    sc = Scenario(arch="gemma-2b", task="train")
    base = store.baseline(sc.bench)["median_us"]

    def commit_runner(bad):
        def run(_name):
            hook = RegressionHook(slowdown_s=0.05) if bad else None
            return {"median_us": runner.run(sc, runs=2, hook=hook).median_us}
        return run

    commits = [Commit(f"c{i:02d}", i, commit_runner(i >= 8)) for i in range(12)]
    trace: list = []
    # classify at half the regression size the nightly detected, so host
    # noise on shared boxes can't flag a good commit as the culprit
    inc = max(i.increase for i in rep.issues if i.metric == "median_us")
    culprit = bisect_commits(commits, sc.bench, "median_us", base,
                             threshold=max(0.07, inc / 2), trace=trace)
    for t in trace:
        print(" ", t)
    print(f"culprit: {culprit.sha} (found with {len(trace)} measurements of 12 commits)")
    assert culprit.sha == "c08"
    print(f"runner stats: {runner.stats.to_dict()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
