"""The paper's CI use case (§4.2) end-to-end, on the provenance-keyed
nightly workflow: nightly suite run, baseline store, an injected "bad
commit", detection at the 7% threshold, and binary-search bisection to
the culprit.

    PYTHONPATH=src python examples/regression_ci.py [--jobs N]

Each ``run_nightly`` call does two things with the ``MetricStore``:

* ``update``/``detect`` against the **baseline pointer** — the paper's
  original latest-vs-baseline check, unchanged; and
* ``log_result`` every measured record into the **history log** as a
  provenance-stamped time-series point (``extra["prov_commit"]``,
  backend, host... — see ``repro/runner/results.py``), WITHOUT moving
  the baseline pointer.

The second stream is what ``repro.telemetry.history`` consumes: points
group into one series per (scenario, provenance key), so night-over-
night trajectories never mix commits, backends, or hosts — a laptop
rerun of the suite lands in its own series instead of polluting the CI
host's rolling baseline.  After the two nights below, the trajectory
report (rendered at the end, same machinery as
``benchmarks/history_report.py``) shows a >=2-point series per probe
cell with the injected regression visible as its drift finding.

``--jobs N`` shards each night's matrix across N persistent worker
subprocesses (the injected hooks cross the process boundary as plain
slowdown/leak parameters); the pool keeps worker caches warm across
nights.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, "src")

from repro.core.ci import run_nightly  # noqa: E402
from repro.core.harness import RegressionHook  # noqa: E402
from repro.core.regression import Commit, MetricStore, bisect_commits  # noqa: E402
from repro.runner import BenchmarkRunner, Scenario  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=0,
                    help="shard nightly matrix runs across N worker subprocesses")
    args = ap.parse_args(argv)
    # a private mkdtemp dir, not the race-prone mktemp: the directory is
    # ours atomically, so the store path inside it can't be hijacked
    store = MetricStore(os.path.join(
        tempfile.mkdtemp(prefix="regression_ci_"), "store.json"))
    archs = ["gemma-2b", "mamba2-2.7b"]
    # one runner for the whole CI day: nights and bisection probes share
    # cached arch builds and compiled executables (and, with --jobs, the
    # persistent shard workers' caches)
    runner = BenchmarkRunner(runs=3, jobs=args.jobs)
    try:
        return _ci_day(store, archs, runner)
    finally:
        runner.close()       # shard workers must die even on a failed assert


def _ci_day(store, archs, runner) -> int:
    # small probe cells: a ~10ms step means the injected 50ms/step
    # regression is a 4-5x blowup that shared-host timing jitter (easily
    # +-50% on busy boxes) can never mask at the 7% threshold
    probe = dict(tasks=("train",), batches=(1,), seqs=(16,), runs=3)

    print("== night 0: record baselines ==")
    rep = run_nightly(store, archs=archs, update_baseline=True,
                      runner=runner, **probe)
    print(f"ran {rep.ran} benchmarks in {rep.wall_s:.1f}s")

    print("\n== night 1: a commit slows gemma-2b training by ~50ms/step ==")
    hooks = {"gemma-2b/train": RegressionHook(slowdown_s=0.05)}
    rep = run_nightly(store, archs=archs, hooks=hooks, runner=runner,
                      **probe)
    print(f"ran {rep.ran} benchmarks in {rep.wall_s:.1f}s (cached executables)")
    for issue in rep.issues:
        print(f"ISSUE: {issue.benchmark} {issue.metric} +{issue.increase:.0%} "
              f"(baseline {issue.baseline:.0f}, observed {issue.observed:.0f})")
    sc = Scenario(arch="gemma-2b", task="train", batch=1, seq=16)
    assert any(i.benchmark == sc.bench and i.metric == "median_us"
               for i in rep.issues)

    print("\n== bisect the day's 12 commits ==")
    base = store.baseline(sc.bench)["median_us"]

    def commit_runner(bad):
        def run(_name):
            hook = RegressionHook(slowdown_s=0.05) if bad else None
            return {"median_us": runner.run(sc, runs=2, hook=hook).median_us}
        return run

    commits = [Commit(f"c{i:02d}", i, commit_runner(i >= 8)) for i in range(12)]
    trace: list = []
    # classify at half the size of the regression we're hunting — THIS
    # bench's nightly increase, not the max across the suite (another
    # bench's noise blip must not inflate the bisection threshold) — so
    # host noise on shared boxes can't flag a good commit as the culprit
    inc = max(i.increase for i in rep.issues
              if i.benchmark == sc.bench and i.metric == "median_us")
    culprit = bisect_commits(commits, sc.bench, "median_us", base,
                             threshold=max(0.07, inc / 2), trace=trace)
    for t in trace:
        print(" ", t)
    print(f"culprit: {culprit.sha} (found with {len(trace)} measurements of 12 commits)")
    assert culprit.sha == "c08"

    print("\n== provenance-keyed nightly trajectory ==")
    from repro.profiler.report import format_table  # noqa: E402
    from repro.telemetry.history import trajectory  # noqa: E402
    traj = trajectory(store, min_points=2)
    for line in format_table(traj).splitlines():
        print(" ", line)
    assert traj["meta"]["series"], "expected >=2-point provenance series"

    print("\n== fleet triage: drift -> re-measure -> bisect, ranked ==")
    # the same trajectory drift findings, pushed through the fleet
    # service's triage pass: each perf_drift cell is re-measured under
    # the night's hooks (confirm or refute), confirmed ones bisected
    # over the day's commits — the nightly pipeline scripts/fleet.py
    # runs on every tick
    from repro.fleet.triage import triage  # noqa: E402
    scenarios = {sc.name: sc}
    report = triage(traj, runner=runner, scenarios=scenarios, hooks=hooks,
                    commits_for=lambda fd, s: commits,
                    meta={"kind": "regression_ci"})
    for line in format_table(report).splitlines():
        print(" ", line)
    assert any(f["rule"] == "regression_bisected"
               and f["evidence"]["culprit"] == "c08"
               for f in report["findings"]), "triage must re-find c08"
    print(f"runner stats: {runner.stats.to_dict()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
