"""Batched serving example: a continuous-batching cell as a first-class
runner scenario (``task="serve"``) — the serving workload goes through the
same ``BenchmarkRunner`` as train/infer cells, sharing arch builds and
recording latency-distribution metrics.

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys

sys.path.insert(0, "src")

from repro.runner import BenchmarkRunner, Scenario  # noqa: E402


def main() -> int:
    # 10 requests, 24-token prompts, 4 slots, bursty (Poisson) arrivals —
    # the MoE decode path of a reduced mixtral under continuous batching
    sc = Scenario(arch="mixtral-8x7b", task="serve", batch=10, seq=24,
                  slots=4, trace="bursty")
    runner = BenchmarkRunner()
    rr = runner.run(sc, record=False)
    assert rr.status == "ok", rr.error
    ex = rr.extra
    print(f"{sc.name}: {ex['tok_per_s']:.1f} tok/s over "
          f"{ex['decode_steps']} batched decode steps "
          f"(queue depth mean {ex['queue_depth_mean']:.2f}, "
          f"max {ex['queue_depth_max']})")
    print(f"  admission={ex['admission']}: {ex['admit_calls']} jitted "
          f"prefill calls, batch mean {ex['admit_batch_mean']:.2f} "
          f"max {ex['admit_batch_max']}, shapes {ex['admit_shapes']}")
    print(f"  ttft_us    p50={ex['ttft_p50']:.0f} p95={ex['ttft_p95']:.0f} "
          f"p99={ex['ttft_p99']:.0f}")
    print(f"  tok_lat_us p50={ex['tok_lat_p50']:.0f} p95={ex['tok_lat_p95']:.0f} "
          f"p99={ex['tok_lat_p99']:.0f}")
    for rid, toks in enumerate(ex["tokens"][:3]):
        print(f"  request {rid}: {toks}")
    assert all(len(t) >= 1 for t in ex["tokens"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
