"""Batched serving example: continuous batching over a reduced mixtral
(MoE decode path) with slot refill.

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.launch.serve import Request, Server  # noqa: E402


def main() -> int:
    cfg = get_arch("mixtral-8x7b").reduced()
    rng = np.random.default_rng(0)
    requests = [Request(i, rng.integers(0, cfg.vocab, 24).astype(np.int32), max_new=12)
                for i in range(10)]
    server = Server(cfg, slots=4, max_len=64)
    out = server.run(requests)
    print(f"served {len(requests)} requests with 4 slots: "
          f"{out['tokens']} tokens, {out['decode_steps']} batched decode steps, "
          f"{out['tok_per_s']:.1f} tok/s")
    for r in requests[:3]:
        print(f"  request {r.rid}: {r.out}")
    assert all(r.done for r in requests)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
