"""Quickstart: train a reduced gemma-2b for a few hundred steps on CPU.

    PYTHONPATH=src python examples/quickstart.py [--steps 200]

This is the end-to-end driver deliverable in miniature: real data pipeline,
real optimizer, checkpointing, loss goes down.  The same code path scales to
the production mesh via repro.launch.train --full on TPU hosts.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import train  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="gemma-2b")
    args = ap.parse_args()
    out = train(args.arch, steps=args.steps, batch=8, seq=128, reduced=True,
                ckpt_dir="/tmp/repro_quickstart_ckpt", save_every=50)
    print(f"\nfinal loss {out['final_loss']:.4f} after {args.steps} steps "
          f"({out['wall_s']:.1f}s); checkpoints in /tmp/repro_quickstart_ckpt")
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    assert last < first, "loss did not decrease!"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
