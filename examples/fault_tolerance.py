"""Fault-tolerance demo: kill a training run mid-flight, restart, and verify
the resumed run is bit-identical to an uninterrupted one.

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import shutil
import sys

sys.path.insert(0, "src")

from repro.launch.train import train  # noqa: E402
from repro.runtime import elastic_rescale_plan  # noqa: E402


def main() -> int:
    for d in ("/tmp/repro_ft_clean", "/tmp/repro_ft_faulty"):
        shutil.rmtree(d, ignore_errors=True)

    print("== clean run (40 steps) ==")
    clean = train("mamba2-2.7b", steps=40, batch=4, seq=64,
                  ckpt_dir="/tmp/repro_ft_clean", save_every=10)

    print("\n== faulty run: node failure injected at step 23 ==")
    faulty = train("mamba2-2.7b", steps=40, batch=4, seq=64,
                   ckpt_dir="/tmp/repro_ft_faulty", save_every=10,
                   inject_fault_at=23)
    print("supervisor events:", faulty["events"])

    match = abs(clean["final_loss"] - faulty["final_loss"]) < 1e-6
    print(f"\nfinal losses: clean={clean['final_loss']:.6f} "
          f"faulty={faulty['final_loss']:.6f}  bit-identical={match}")
    assert match

    print("\n== elastic rescale plan: pod loses 37 chips ==")
    plan = elastic_rescale_plan(512 - 37, model_parallel=16, global_batch=256,
                                multi_pod=True)
    print(plan)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
