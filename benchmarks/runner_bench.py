"""Runner-level benchmark: model/executable reuse vs the seed path.

Workload: a repeated-arch sweep in the shape regression CI produces every
night — all three tasks of one arch, then the train cell re-measured three
more times (baseline + injection probes).  The seed path rebuilt the model
and re-jitted for every measurement; the unified runner shares one arch
build across tasks and replays cached executables on re-measures.

Emits both wall times and the speedup; numbers land in
``results/runner_bench.json``."""
from __future__ import annotations

import json
import time

from benchmarks.common import emit, results_path
from repro.core.harness import measure
from repro.core.suite import get_benchmark
from repro.runner import BenchmarkRunner, Scenario

ARCH = "gemma-2b"
BATCH, SEQ = 2, 32


def _workload(fast: bool):
    tasks = ("train", "infer_decode") if fast else ("train", "infer_prefill", "infer_decode")
    sweep = [Scenario(arch=ARCH, task=t, batch=BATCH, seq=SEQ) for t in tasks]
    probes = [Scenario(arch=ARCH, task="train", batch=BATCH, seq=SEQ)] * (2 if fast else 3)
    return sweep + probes


def seed_path(scenarios, runs: int) -> float:
    """The pre-runner protocol: fresh build + fresh jit per measurement."""
    t0 = time.perf_counter()
    for sc in scenarios:
        bench = get_benchmark(sc.arch, sc.task)
        step, args, donate = bench.make(batch=sc.batch, seq=sc.seq)
        measure(bench.name, step, args, donate, runs=runs)
    return time.perf_counter() - t0


def runner_path(scenarios, runs: int) -> tuple:
    runner = BenchmarkRunner(runs=runs)
    t0 = time.perf_counter()
    for sc in scenarios:
        rr = runner.run(sc, record=False)
        if rr.status != "ok":
            raise RuntimeError(f"{sc.name}: {rr.error}")
    return time.perf_counter() - t0, runner.stats


def main(fast: bool = False, runner=None) -> None:
    runs = 2 if fast else 3
    scenarios = _workload(fast)
    seed_s = seed_path(scenarios, runs)
    runner_s, stats = runner_path(scenarios, runs)
    speedup = seed_s / runner_s if runner_s else 0.0
    emit("runner_bench/seed_path_s", seed_s * 1e6, f"{len(scenarios)}_measurements")
    emit("runner_bench/runner_path_s", runner_s * 1e6,
         f"model_builds={stats.model_builds};exec_cache_hits={stats.executable_cache_hits}")
    emit("runner_bench/reuse_speedup", 0.0, f"{speedup:.2f}x")
    with open(results_path("runner_bench.json"), "w") as f:
        json.dump({"scenarios": [s.name for s in scenarios], "runs": runs,
                   "seed_path_s": seed_s, "runner_path_s": runner_s,
                   "speedup": speedup, "runner_stats": stats.to_dict()},
                  f, indent=1)


if __name__ == "__main__":
    main()
