"""Runner-level benchmarks: reuse vs the seed path, and serial-vs-sharded
dispatch on a multi-arch sweep.

Part 1 — reuse (PR 1): a repeated-arch sweep in the shape regression CI
produces every night — all three tasks of one arch, then the train cell
re-measured three more times (baseline + injection probes).  The seed path
rebuilt the model and re-jitted for every measurement; the unified runner
shares one arch build across tasks and replays cached executables on
re-measures.

Part 2 — sharded dispatch (``run_matrix(..., jobs=N)``): a multi-arch
sweep measured three ways, all with ``runs``/warmup/compile-warmup held
identical —

    serial        in-process ``run_matrix`` (no fault containment: one
                  segfaulting cell kills the sweep);
    isolated      ``isolate=True`` — one fresh subprocess per cell, the
                  pre-sharding way to make crashy cells recoverable; pays
                  interpreter startup + arch rebuild for EVERY cell;
    sharded       ``jobs=N`` persistent workers — same per-cell fault
                  containment as ``isolated``, but each worker amortises
                  its startup and keeps arch-build/executable caches hot
                  across its shard.

The headline ``shard_speedup`` is isolated/sharded — the two dispatch
modes with equal crash-containment guarantees.  ``serial/sharded`` is also
reported; how far it can exceed 1.0 is bounded by the host's real parallel
capacity, so we probe that too (``parallel_capacity``: aggregate
throughput of N busy processes vs 1 — ~1.1 on a hyperthread pair, ~N on N
real cores) and report it alongside.

Part 3 — serve throughput: one small continuous-batching cell per sweep
arch (``task="serve"``, bursty trace) dispatched through the same sharded
pool, reporting tok/s per cell and the sweep wall next to the
serial/isolated/sharded walls.

Part 4 — profiling overhead: the same cell measured unprofiled then
profiled (``profile=True``) on a warm executable cache; the reported
ratio of median step times is the profiler's measurement tax (the
acceptance bound is <10% — the phase split is two extra perf_counter
reads per step, and the attribution compile happens outside the timed
loop), so overhead regressions show up in the perf trajectory.

Part 5 — scheduling strategies on a skew-weighted matrix: four
equal-*guessed*-weight build-key groups where an injected slowdown makes
the first-ranked group far heavier than the task-weight table believes.
Static LPT (placed up front by the wrong guess) stacks a second group
behind the slow one; dynamic stealing lets the free worker drain the
tail; cluster ``local:2`` runs the same dynamic schedule over the socket
transport (its delta over stealing is coordinator + worker-startup
overhead).  The ``steal_win`` row is static/stealing wall — > 1.0
whenever guessed and actual cost diverge, which is the load-balance case
the deque exists for.

Part 6 — kernel autotuning (``repro.tuning``): flash-attention + rglru
(+ ssd when not ``--fast``) launch-parameter sweeps dispatched as
``task="kernel"`` cells through the same sharded pool, winners recorded
in the tuning DB (``results/tuning_db.json``), and the tuned-vs-default
median ratio reported per kernel.  The ratio is >= 1.0 by construction —
the ops default is always a swept candidate and the winner is the argmin
(ties to the default), so the DB never serves a config slower than the
default it replaces.  The detector bridge is then demonstrated end to
end: the three sweep archs are profiled, ``low_util`` is forced to fire
(``util_rel=1.0`` flags every below-median cell — deterministic with 3+
distinct cells), and the resulting findings enqueue tuning jobs into
``results/tuning_queue.json``.

Part 7 — admission policies: the same queue-forming trace (bursty
bimodal arrivals, offered load compressed so admission waves actually
form) replayed under ``admission="batched"`` (one jitted prefill per
wave, bucketed padded shapes) and ``admission="single"`` (the
one-prefill-per-request baseline).  Reported: the TTFT p99 ratio, the
jitted prefill-call counts, and the token-digest equality gate — batched
admission must be a pure scheduling change, byte-identical tokens.

Part 8 — cluster capacity pipelining: the same single-build-key matrix
dispatched to ONE cluster worker at ``--capacity 1`` (strict
request/response round trips) and ``--capacity 2`` (the coordinator
keeps two cells of the group in flight, so protocol latency + result
marshalling overlap the worker's compute).  The capacity-2 run is span-
traced end to end and the stitched Chrome trace is persisted as the
*explanatory artifact* (``results/capacity_trace.json``): the wall-clock
ratio says whether pipelining pays, the trace shows exactly where —
dispatch spans overlapping on the coordinator lane vs back-to-back.

Part 9 — metrics-registry overhead: the same warm cell measured with
the fleet metrics registry (``repro.fleet.metrics``) enabled — the
default; every execution feeds it — and disabled.  The registry's
``record_result`` is a handful of dict increments under one lock,
entirely outside the timed measurement loop, so both the median-step
ratio and the end-to-end ``run()`` wall ratio must be ~1.0x — the
"near-zero cost when unexported" acceptance bound, kept in the perf
trajectory like the profiler tax of part 4.

Numbers land in ``results/runner_bench.json``."""
from __future__ import annotations

import gc
import json
import multiprocessing
import time

from benchmarks.common import emit, results_path
from repro.core.harness import RegressionHook, measure
from repro.core.suite import get_benchmark
from repro.runner import BenchmarkRunner, Scenario, ScenarioMatrix
from repro.profiler import Thresholds, detect
from repro.tuning import (TuningDB, enqueue_jobs, jobs_from_findings,
                          make_case, run_sweep, sweep_matrix)

ARCH = "gemma-2b"
BATCH, SEQ = 2, 32

SWEEP_ARCHS = ["gemma-2b", "mamba2-2.7b", "recurrentgemma-9b", "mixtral-8x7b"]
JOBS = 2


def _workload(fast: bool):
    tasks = ("train", "infer_decode") if fast else ("train", "infer_prefill", "infer_decode")
    sweep = [Scenario(arch=ARCH, task=t, batch=BATCH, seq=SEQ) for t in tasks]
    probes = [Scenario(arch=ARCH, task="train", batch=BATCH, seq=SEQ)] * (2 if fast else 3)
    return sweep + probes


def seed_path(scenarios, runs: int) -> float:
    """The pre-runner protocol: fresh build + fresh jit per measurement."""
    t0 = time.perf_counter()
    for sc in scenarios:
        bench = get_benchmark(sc.arch, sc.task)
        step, args, donate = bench.make(batch=sc.batch, seq=sc.seq)
        measure(bench.name, step, args, donate, runs=runs)
    return time.perf_counter() - t0


def runner_path(scenarios, runs: int) -> tuple:
    runner = BenchmarkRunner(runs=runs)
    t0 = time.perf_counter()
    for sc in scenarios:
        rr = runner.run(sc, record=False)
        if rr.status != "ok":
            raise RuntimeError(f"{sc.name}: {rr.error}")
    return time.perf_counter() - t0, runner.stats


# ---- part 2: dispatch-mode comparison -------------------------------------

def _burn(out, seconds: float, barrier=None) -> None:
    if barrier is not None:    # children sync up so their windows overlap
        barrier.wait()
    count, t0 = 0, time.perf_counter()
    while time.perf_counter() - t0 < seconds:
        count += 1
    out.value = count


def parallel_capacity(n: int = JOBS, seconds: float = 1.5) -> float:
    """Aggregate busy-loop throughput of ``n`` processes vs 1 — the host's
    real parallel headroom (hyperthreads and cgroup quotas both cap it).
    Spawned, not forked (this process has a live multithreaded JAX), and
    barrier-gated so the children's burn windows truly overlap despite
    uneven interpreter start-up."""
    ctx = multiprocessing.get_context("spawn")
    single = ctx.Value("d")
    _burn(single, seconds)
    barrier = ctx.Barrier(n)
    vals = [ctx.Value("d") for _ in range(n)]
    procs = [ctx.Process(target=_burn, args=(v, seconds, barrier))
             for v in vals]
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    return sum(v.value for v in vals) / max(single.value, 1.0)


def _sweep_matrix(fast: bool) -> ScenarioMatrix:
    archs = SWEEP_ARCHS[:2] if fast else SWEEP_ARCHS
    return ScenarioMatrix(archs=archs, tasks=("train", "infer_decode"),
                          batches=(BATCH,), seqs=(SEQ,))


def _serve_matrix(fast: bool) -> ScenarioMatrix:
    """A small serving cell per sweep arch: the serve-throughput row."""
    archs = SWEEP_ARCHS[:1] if fast else SWEEP_ARCHS[:2]
    return ScenarioMatrix(archs=archs, tasks=("serve",),
                          batches=(6,), seqs=(SEQ // 2,), slots=(2,),
                          traces=("bursty",))


def scenario_matrices(fast: bool = False):
    """The matrices this benchmark executes (``benchmarks.run --list`` hook)."""
    return [_sweep_matrix(fast), _serve_matrix(fast), _skew_matrix(fast),
            _tuning_matrix(fast), _capacity_matrix(fast)]


# ---- part 6: kernel autotuning --------------------------------------------

def _tuning_cases(fast: bool):
    """Small tuning cases sized like the probe cells above: one per Pallas
    kernel (ssd only on the full run — its interpret-mode chunks are the
    slowest cells of the sweep)."""
    cases = [make_case("flash_attention", B=2, S=64, H=2, K=2, D=32),
             make_case("rglru", B=1, S=64, D=64)]
    if not fast:
        cases.append(make_case("ssd", B=1, S=64, H=2, P=16, N=16))
    return cases


def _tuning_candidates(fast: bool) -> int:
    return 3 if fast else 6


def _tuning_matrix(fast: bool) -> ScenarioMatrix:
    return sweep_matrix(_tuning_cases(fast),
                        max_candidates=_tuning_candidates(fast))


# ---- part 8: cluster capacity pipelining ----------------------------------

def _capacity_matrix(fast: bool) -> ScenarioMatrix:
    """One build-key group of several cheap cells: a single worker owns
    the whole group, so any wall-clock gap between capacity 1 and 2 is
    pure dispatch pipelining (not scheduling or cache effects)."""
    return ScenarioMatrix(archs=[ARCH], tasks=("train", "infer_decode"),
                          batches=(1, 2), seqs=(8 if fast else 16,))


def capacity_path(matrix: ScenarioMatrix, *, capacity: int,
                  tracer=None) -> float:
    """Wall time of the matrix through one ``local:1`` cluster worker
    advertising ``capacity`` in-flight cells; optionally span-traced."""
    from repro.runner.cluster.scheduler import ClusterScheduler
    from repro.telemetry.spans import NULL_TRACER
    tr = tracer or NULL_TRACER
    sched = ClusterScheduler("local:1", runs=1, warmup=0, compile_warmup=0,
                             measure_fence=False, capacity=capacity)
    t0 = time.perf_counter()
    try:
        root = None
        if tr.enabled:
            tr.begin_trace()
            root = tr.start("matrix", kind="matrix", cells=len(matrix),
                            transport=f"cluster:local:1;capacity={capacity}")
        results, _ = sched.run(matrix.expand(), hooks={}, tracer=tracer,
                               trace_parent=root)
        if root is not None:
            tr.finish(root)
    finally:
        sched.close()
    wall = time.perf_counter() - t0
    bad = [rr for rr in results if rr.status != "ok"]
    if bad:
        raise RuntimeError(f"{bad[0].name}: {bad[0].error}")
    return wall


# ---- part 5: static LPT vs stealing vs cluster ----------------------------

def _skew_matrix(fast: bool) -> ScenarioMatrix:
    """Four build-key groups of ONE arch (dtypes x reduced-config modes,
    one train cell each, roughly equal real cost) with EQUAL guessed
    weights — only the hook below skews the actual cost, so the
    static-vs-stealing gap isolates the scheduling decision.  (Mixing
    archs here buries the effect: their real cost spread dwarfs the
    injected skew.)"""
    return ScenarioMatrix(archs=[ARCH], tasks=("train",),
                          batches=(1,), seqs=(8 if fast else 16,),
                          dtypes=("fp32", "bf16"),
                          modes=("jit_donated", "jit_noremat"))


def _skew_hooks(matrix: ScenarioMatrix, slow_s: float) -> dict:
    """Slow the FIRST-ranked group's cell: on equal weights the ranking is
    first-appearance order, so static LPT seeds it on shard 0 and then —
    trusting the wrong guess — stacks the third group behind it, while a
    stealing worker that drew a fast group drains the tail instead."""
    first = matrix.expand()[0]
    return {first.name: RegressionHook(slowdown_s=slow_s)}


def sched_path(matrix: ScenarioMatrix, hooks: dict, *, jobs: int = 0,
               steal: bool = True, cluster: str = "") -> float:
    """Wall time of one dispatch strategy; runs=1/warmup=0/compile_warmup=0
    so the injected slowdown fires exactly once per measured cell."""
    runner = BenchmarkRunner(runs=1, warmup=0, compile_warmup=0, jobs=jobs,
                             steal=steal, cluster=cluster,
                             measure_fence=False)
    t0 = time.perf_counter()
    try:
        results = runner.run_matrix(matrix, hooks=hooks)
    finally:
        runner.close()
    wall = time.perf_counter() - t0
    bad = [rr for rr in results if rr.status != "ok"]
    if bad:
        raise RuntimeError(f"{bad[0].name}: {bad[0].error}")
    return wall


def dispatch_path(matrix: ScenarioMatrix, runs: int, *, jobs: int = 0,
                  isolate: bool = False) -> tuple:
    # fence off: this measures dispatch throughput, not per-cell latency
    runner = BenchmarkRunner(runs=runs, jobs=jobs, isolate=isolate,
                             measure_fence=False)
    t0 = time.perf_counter()
    try:
        results = runner.run_matrix(matrix)
    finally:
        runner.close()
    wall = time.perf_counter() - t0
    bad = [rr for rr in results if rr.status != "ok"]
    if bad:
        raise RuntimeError(f"{bad[0].name}: {bad[0].error}")
    stats = runner.stats
    del runner, results
    gc.collect()     # drop cached builds/executables before the next mode
    return wall, stats


def main(fast: bool = False, runner=None) -> None:
    runs = 2 if fast else 3
    scenarios = _workload(fast)
    seed_s = seed_path(scenarios, runs)
    runner_s, stats = runner_path(scenarios, runs)
    speedup = seed_s / runner_s if runner_s else 0.0
    emit("runner_bench/seed_path_s", seed_s * 1e6, f"{len(scenarios)}_measurements")
    emit("runner_bench/runner_path_s", runner_s * 1e6,
         f"model_builds={stats.model_builds};exec_cache_hits={stats.executable_cache_hits}")
    emit("runner_bench/reuse_speedup", 0.0, f"{speedup:.2f}x")

    matrix = _sweep_matrix(fast)
    serial_s, _ = dispatch_path(matrix, runs)
    isolated_s, _ = dispatch_path(matrix, runs, isolate=True)
    sharded_s, shard_stats = dispatch_path(matrix, runs, jobs=JOBS)
    capacity = parallel_capacity(JOBS)
    shard_speedup = isolated_s / sharded_s if sharded_s else 0.0
    serial_ratio = serial_s / sharded_s if sharded_s else 0.0
    emit("runner_bench/sweep_serial_s", serial_s * 1e6, f"{len(matrix)}_cells")
    emit("runner_bench/sweep_isolated_s", isolated_s * 1e6, "subprocess_per_cell")
    emit("runner_bench/sweep_sharded_s", sharded_s * 1e6,
         f"jobs={JOBS};worker_model_builds={shard_stats.model_builds}")
    emit("runner_bench/shard_speedup_vs_isolated", 0.0, f"{shard_speedup:.2f}x")
    emit("runner_bench/shard_ratio_vs_serial", 0.0,
         f"{serial_ratio:.2f}x;host_parallel_capacity={capacity:.2f}")

    # serve-throughput row: continuous-batching cells dispatched through the
    # same sharded pool as the step sweep above (fence off: throughput run)
    serve_matrix = _serve_matrix(fast)
    serve_runner = BenchmarkRunner(jobs=JOBS, measure_fence=False)
    t0 = time.perf_counter()
    try:
        serve_results = serve_runner.run_matrix(serve_matrix)
    finally:
        serve_runner.close()
    serve_wall = time.perf_counter() - t0
    serve_rows = []
    for rr in serve_results:
        if rr.status != "ok":
            raise RuntimeError(f"{rr.name}: {rr.error}")
        serve_rows.append({"name": rr.name,
                           "tok_per_s": rr.extra["tok_per_s"],
                           "ttft_p50_us": rr.extra.get("ttft_p50"),
                           "tok_lat_p99_us": rr.extra.get("tok_lat_p99"),
                           "shard": rr.extra.get("shard")})
        emit(f"runner_bench/serve_tok_per_s/{rr.arch}", 0.0,
             f"{rr.extra['tok_per_s']:.1f}tok_s;trace={rr.extra['trace']};"
             f"slots={rr.extra['slots']}")
    emit("runner_bench/serve_sharded_s", serve_wall * 1e6,
         f"jobs={JOBS};{len(serve_rows)}_serve_cells")

    # profiling overhead: unprofiled vs profiled median step time on a
    # warm executable (fresh compile settled by the first run)
    prof_runner = BenchmarkRunner(runs=max(3, runs))
    sc = Scenario(arch=ARCH, task="train", batch=BATCH, seq=SEQ)
    prof_runner.run(sc, record=False)                        # compile + settle
    base_rr = prof_runner.run(sc, record=False)
    prof_rr = prof_runner.run(sc, record=False, profile=True)
    overhead = (prof_rr.median_us / base_rr.median_us
                if base_rr.median_us else 0.0)
    emit("runner_bench/profile_overhead", 0.0,
         f"{overhead:.3f}x;profiled={prof_rr.median_us:.0f}us;"
         f"base={base_rr.median_us:.0f}us")
    del prof_runner
    gc.collect()

    # metrics-registry overhead: the same warm-cell protocol as the
    # profiler tax above, enabled vs disabled registry; run() wall is
    # timed too because record_result lands outside the measured loop
    from repro.fleet.metrics import set_enabled
    met_runner = BenchmarkRunner(runs=max(3, runs))
    met_runner.run(sc, record=False)                     # compile + settle
    t0 = time.perf_counter()
    on_rr = met_runner.run(sc, record=False)
    on_wall = time.perf_counter() - t0
    prev_enabled = set_enabled(False)
    try:
        t0 = time.perf_counter()
        off_rr = met_runner.run(sc, record=False)
        off_wall = time.perf_counter() - t0
    finally:
        set_enabled(prev_enabled)
    metrics_ratio = (on_rr.median_us / off_rr.median_us
                     if off_rr.median_us else 0.0)
    metrics_wall_ratio = on_wall / off_wall if off_wall else 0.0
    emit("runner_bench/metrics_overhead", 0.0,
         f"{metrics_ratio:.3f}x;wall={metrics_wall_ratio:.3f}x;"
         f"enabled={on_rr.median_us:.0f}us;disabled={off_rr.median_us:.0f}us")
    del met_runner
    gc.collect()

    # scheduling strategies: static LPT vs dynamic stealing vs cluster
    # local:2 on the skew-weighted matrix (see module docstring, part 5)
    # the slowdown must make the hooked group cost ~2x a normal group
    # (build + jit ~8-10s here): that is the regime where static LPT
    # stacks a second group behind the slow one and stealing does not
    skew_matrix = _skew_matrix(fast)
    slow_s = 18.0 if fast else 22.0
    hooks = _skew_hooks(skew_matrix, slow_s)
    static_s = sched_path(skew_matrix, hooks, jobs=JOBS, steal=False)
    steal_s = sched_path(skew_matrix, hooks, jobs=JOBS, steal=True)
    cluster_s = sched_path(skew_matrix, hooks, cluster=f"local:{JOBS}")
    steal_win = static_s / steal_s if steal_s else 0.0
    cluster_ratio = cluster_s / steal_s if steal_s else 0.0
    emit("runner_bench/sched_static_lpt_s", static_s * 1e6,
         f"jobs={JOBS};{len(skew_matrix)}_cells;slow_cell={slow_s:.0f}s")
    emit("runner_bench/sched_stealing_s", steal_s * 1e6, f"jobs={JOBS}")
    emit("runner_bench/sched_cluster_s", cluster_s * 1e6,
         f"local:{JOBS};socket_transport")
    emit("runner_bench/steal_win_vs_static", 0.0,
         f"{steal_win:.2f}x;cluster_vs_steal={cluster_ratio:.2f}x")

    # kernel autotuning: per-kernel candidate sweeps through the sharded
    # pool, winners recorded in the tuning DB, tuned-vs-default ratio per
    # kernel (fence ON here — candidate medians must be comparable, so the
    # timed loops serialize while builds/compiles still overlap)
    cases = _tuning_cases(fast)
    tuning_db = TuningDB.load(results_path("tuning_db.json"))
    tune_runner = BenchmarkRunner(runs=max(3, runs), jobs=JOBS)
    t0 = time.perf_counter()
    try:
        tuning = run_sweep(cases, tune_runner, db=tuning_db,
                           max_candidates=_tuning_candidates(fast))
    finally:
        tune_runner.close()
    tuning_wall = time.perf_counter() - t0
    for row in tuning["cases"]:
        if row["status"] != "ok":
            raise RuntimeError(f"tuning sweep failed for {row['case']}")
        winner = " ".join(f"{k}={v}" for k, v in row["winner"].items())
        emit(f"runner_bench/tuning_ratio/{row['kernel']}", 0.0,
             f"{row['ratio']:.2f}x;winner={winner};"
             f"default_us={row['default_us']:.0f}")
    emit("runner_bench/tuning_sweep_s", tuning_wall * 1e6,
         f"jobs={JOBS};{sum(r['candidates'] for r in tuning['cases'])}"
         f"_candidates;db={tuning['db_path']}")

    # detector bridge: profile the three kernel-bearing archs, force
    # low_util to fire (util_rel=1.0 flags every below-median cell —
    # deterministic once 3+ cells have distinct utilizations), and turn
    # the findings into enqueued tuning jobs
    bridge_runner = BenchmarkRunner(runs=max(3, runs))
    bridge_recs = [bridge_runner.run(Scenario(arch=a, task="train", batch=1,
                                              seq=16, mode="jit"),
                                     record=False, profile=True)
                   for a in ("gemma-2b", "mamba2-2.7b", "recurrentgemma-9b")]
    del bridge_runner
    gc.collect()
    bridge_findings = detect(bridge_recs, Thresholds(util_rel=1.0))
    tuning_jobs = jobs_from_findings(bridge_findings, bridge_recs,
                                     db=tuning_db)
    queue_path = results_path("tuning_queue.json")
    enqueue_jobs(tuning_jobs, queue_path)
    emit("runner_bench/tuning_jobs", 0.0,
         f"n={len(tuning_jobs)};findings={len(bridge_findings)};"
         f"queue={queue_path}")

    # admission policies: batched wave prefill vs per-request baseline on
    # the same queue-forming trace (loadgen at a compressed offered load —
    # native bursty arrivals rarely queue >1 request against free slots)
    adm_runner = BenchmarkRunner(measure_fence=False)
    adm_cells = {}
    try:
        for adm in ("batched", "single"):
            sc = Scenario(arch=ARCH, task="loadgen", batch=8, seq=16,
                          slots=4, trace="bursty+bimodal", load=4.0,
                          admission=adm)
            rr = adm_runner.run(sc, record=False)
            if rr.status != "ok":
                raise RuntimeError(f"{sc.name}: {rr.error}")
            ex = rr.extra
            adm_cells[adm] = {"name": rr.name,
                              "ttft_p99_us": ex["ttft_p99"],
                              "tok_per_s": ex["tok_per_s"],
                              "prefill_calls": ex["admit_calls"],
                              "admit_batch_mean": ex["admit_batch_mean"],
                              "admit_batch_max": ex["admit_batch_max"],
                              "admit_shapes": ex["admit_shapes"],
                              "tokens_digest": ex["tokens_digest"]}
    finally:
        del adm_runner
        gc.collect()
    adm_digest_ok = (adm_cells["batched"]["tokens_digest"]
                     == adm_cells["single"]["tokens_digest"])
    adm_ttft_ratio = (adm_cells["batched"]["ttft_p99_us"]
                      / adm_cells["single"]["ttft_p99_us"]
                      if adm_cells["single"]["ttft_p99_us"] else 0.0)
    emit("runner_bench/admission_ttft_p99_ratio", 0.0,
         f"{adm_ttft_ratio:.2f}x;digests_match={adm_digest_ok};"
         f"prefill_calls={adm_cells['batched']['prefill_calls']}"
         f"vs{adm_cells['single']['prefill_calls']};"
         f"batch_max={adm_cells['batched']['admit_batch_max']}")

    # cluster capacity pipelining: one worker, strict round trips vs two
    # cells in flight; the traced capacity-2 run is the explanatory
    # artifact (see module docstring, part 8)
    from repro.telemetry.export import save_trace
    from repro.telemetry.spans import Tracer
    cap_matrix = _capacity_matrix(fast)
    cap1_s = capacity_path(cap_matrix, capacity=1)
    cap_tracer = Tracer()
    cap2_s = capacity_path(cap_matrix, capacity=2, tracer=cap_tracer)
    cap_trace_path = results_path("capacity_trace.json")
    save_trace(cap_tracer.export(), cap_trace_path)
    cap_ratio = cap1_s / cap2_s if cap2_s else 0.0
    emit("runner_bench/capacity1_s", cap1_s * 1e6,
         f"local:1;{len(cap_matrix)}_cells")
    emit("runner_bench/capacity2_s", cap2_s * 1e6,
         f"local:1;pipelined;trace={cap_trace_path}")
    emit("runner_bench/capacity_pipelining_win", 0.0, f"{cap_ratio:.2f}x")

    with open(results_path("runner_bench.json"), "w") as f:
        json.dump({"scenarios": [s.name for s in scenarios], "runs": runs,
                   "seed_path_s": seed_s, "runner_path_s": runner_s,
                   "speedup": speedup, "runner_stats": stats.to_dict(),
                   "sweep": {"cells": [s.name for s in matrix],
                             "jobs": JOBS, "serial_s": serial_s,
                             "isolated_s": isolated_s, "sharded_s": sharded_s,
                             "shard_speedup_vs_isolated": shard_speedup,
                             "shard_ratio_vs_serial": serial_ratio,
                             "host_parallel_capacity": capacity,
                             "sharded_stats": shard_stats.to_dict()},
                   "serve": {"jobs": JOBS, "wall_s": serve_wall,
                             "cells": serve_rows},
                   "profile": {"cell": sc.name,
                               "base_median_us": base_rr.median_us,
                               "profiled_median_us": prof_rr.median_us,
                               "overhead_ratio": overhead},
                   "metrics": {"cell": sc.name,
                               "enabled_median_us": on_rr.median_us,
                               "disabled_median_us": off_rr.median_us,
                               "overhead_ratio": metrics_ratio,
                               "wall_ratio": metrics_wall_ratio},
                   "scheduling": {"cells": [s.name for s in skew_matrix],
                                  "jobs": JOBS, "slow_cell_s": slow_s,
                                  "static_lpt_s": static_s,
                                  "stealing_s": steal_s,
                                  "cluster_local_s": cluster_s,
                                  "steal_win_vs_static": steal_win,
                                  "cluster_ratio_vs_steal": cluster_ratio},
                   "admission": {"cells": adm_cells,
                                 "digests_match": adm_digest_ok,
                                 "ttft_p99_ratio": adm_ttft_ratio},
                   "capacity": {"cells": [s.name for s in cap_matrix],
                                "capacity1_s": cap1_s,
                                "capacity2_s": cap2_s,
                                "pipelining_win": cap_ratio,
                                "trace_path": str(cap_trace_path),
                                "trace_spans": len(cap_tracer.spans)},
                   "tuning": {"jobs": JOBS, "wall_s": tuning_wall,
                              "db_path": tuning["db_path"],
                              "cases": tuning["cases"],
                              "recorded": tuning["recorded"],
                              "bridge": {
                                  "profiled": [rr.name for rr in bridge_recs],
                                  "findings": [
                                      {"rule": fi.rule, "cell": fi.cell,
                                       "severity": fi.severity}
                                      for fi in bridge_findings],
                                  "enqueued": tuning_jobs,
                                  "queue_path": str(queue_path)}}},
                  f, indent=1)


if __name__ == "__main__":
    main()
