"""Latency-vs-offered-load curves: ``task="loadgen"`` cells through the
unified runner, swept over the ``loads`` axis for BOTH admission
policies side by side, post-processed into per-policy saturation knees.

Each cell replays the same mixed-prompt-length trace against the serve
engine with its virtual arrival clock scaled by the offered load; TTFT
and per-token p99 climb as the queue saturates while tok/s flattens —
``repro.runner.loadgen.find_knee`` marks the last load that still bought
throughput.  The ``admissions`` axis runs every load twice: ``batched``
(one jitted prefill per admission wave, bucketed padded shapes) against
``single`` (the one-prefill-per-request baseline).  Batched admission
only has something to batch once the queue forms — the high-load half of
the sweep, which is exactly where the knee lives — so the comparison
reads as "how much saturation headroom does wave prefill buy".  The two
policies must also agree token-for-token: the digest check below is the
numerical-equivalence gate, run on every swept load.

Rows + per-policy knees land in ``results/loadgen_curve.json`` under the
schema consumed by ``repro.runner.loadgen.auto_slots`` (the knee-driven
``slots="auto"`` resolver), and a summary record carrying ``knee_load``
/ ``knee_tok_s`` (batched curve — the production policy) in its
``extra`` is appended to the shared ResultStore so CI baselines can
track the knee like any other scalar.

    PYTHONPATH=src python -m benchmarks.loadgen_curve [--fast] [--jobs N]
"""
from __future__ import annotations

import json
import time

from benchmarks.common import emit, make_runner, results_path
from repro.runner.loadgen import CURVE_SCHEMA, DEFAULT_SLOTS, find_knee
from repro.runner.results import RunResult
from repro.runner.scenario import ScenarioMatrix

ARCH = "gemma-2b"
TRACE = "bursty+bimodal"
LOADS_FULL = (0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0)
LOADS_FAST = (0.5, 1.0, 2.0, 4.0)


def scenario_matrices(fast: bool = False):
    """The matrices this table executes (``benchmarks.run --list`` hook)."""
    requests, prompt = (8, 8) if fast else (16, 16)
    return [ScenarioMatrix(archs=[ARCH], tasks=("loadgen",),
                           batches=(requests,), seqs=(prompt,),
                           slots=(DEFAULT_SLOTS,), traces=(TRACE,),
                           loads=LOADS_FAST if fast else LOADS_FULL,
                           admissions=("batched", "single"))]


def _row(rr) -> dict:
    ex = rr.extra
    return {"name": rr.name, "arch": rr.arch, "slots": ex["slots"],
            "trace": ex["trace"], "load": ex["offered_load"],
            "split": ex.get("split", ""), "requests": rr.runs,
            "admission": ex["admission"],
            "admit_calls": ex["admit_calls"],
            "admit_batch_mean": ex["admit_batch_mean"],
            "admit_batch_max": ex["admit_batch_max"],
            "tok_per_s": ex["tok_per_s"],
            "decode_steps": ex["decode_steps"],
            "queue_depth_mean": ex["queue_depth_mean"],
            "queue_depth_max": ex["queue_depth_max"],
            "prompt_len_p50": ex.get("prompt_len_p50"),
            "prompt_len_p95": ex.get("prompt_len_p95"),
            "tokens_digest": ex["tokens_digest"],
            **{k: ex[k] for k in ("ttft_p50", "ttft_p95", "ttft_p99",
                                  "tok_lat_p50", "tok_lat_p95",
                                  "tok_lat_p99") if k in ex}}


def _at_load(rows, load):
    return next(r for r in rows if r["load"] == load)


def main(fast: bool = False, runner=None) -> None:
    runner = runner or make_runner()
    [matrix] = scenario_matrices(fast)
    by_adm = {"batched": [], "single": []}
    for rr in runner.run_matrix(matrix):
        if rr.status != "ok":
            emit(f"loadgen/{rr.name}", 0.0,
                 f"status={rr.status};error={(rr.error or '')[:60]}")
            continue
        ex = rr.extra
        emit(f"loadgen/{rr.name}", rr.median_us,
             f"load={ex['offered_load']:g};tok_per_s={ex['tok_per_s']:.1f};"
             f"ttft_p99={ex['ttft_p99']:.0f};tok_lat_p99={ex['tok_lat_p99']:.0f};"
             f"qmax={ex['queue_depth_max']};admit_calls={ex['admit_calls']};"
             f"admit_batch_max={ex['admit_batch_max']}")
        by_adm[ex["admission"]].append(_row(rr))

    # numerical-equivalence gate: batched admission must generate the
    # byte-identical token streams of the per-request baseline, per load
    digests_match = bool(by_adm["batched"]) and all(
        b["tokens_digest"] == _at_load(by_adm["single"], b["load"])["tokens_digest"]
        for b in by_adm["batched"])

    curves = {}
    for adm, rows in by_adm.items():
        knee = find_knee(rows)
        at_knee = _at_load(rows, knee["knee_load"]) if rows else {}
        curves[adm] = {"knee": knee,
                       "ttft_p99_at_knee": at_knee.get("ttft_p99", 0.0),
                       "admit_calls_total": sum(r["admit_calls"] for r in rows)}
        emit(f"loadgen/knee/{adm}", knee["knee_tok_s"],
             f"knee_load={knee['knee_load']:g};"
             f"ttft_p99_at_knee={at_knee.get('ttft_p99', 0.0):.0f}")

    bk, sk = curves["batched"]["knee"], curves["single"]["knee"]
    ttft_ratio = (curves["batched"]["ttft_p99_at_knee"]
                  / curves["single"]["ttft_p99_at_knee"]
                  if curves["single"]["ttft_p99_at_knee"] else 0.0)
    comparison = {
        "digests_match": digests_match,
        "knee_load_batched": bk["knee_load"], "knee_load_single": sk["knee_load"],
        "knee_tok_s_ratio": (bk["knee_tok_s"] / sk["knee_tok_s"]
                             if sk["knee_tok_s"] else 0.0),
        "ttft_p99_ratio_at_knee": ttft_ratio,
        "prefill_calls_batched": curves["batched"]["admit_calls_total"],
        "prefill_calls_single": curves["single"]["admit_calls_total"],
    }
    emit("loadgen/admission_comparison", 0.0,
         f"digests_match={digests_match};"
         f"knee={bk['knee_load']:g}vs{sk['knee_load']:g};"
         f"tok_s_ratio={comparison['knee_tok_s_ratio']:.2f}x;"
         f"ttft_p99_ratio={ttft_ratio:.2f}x;"
         f"prefill_calls={comparison['prefill_calls_batched']}"
         f"vs{comparison['prefill_calls_single']}")

    if runner.store is not None and by_adm["batched"]:
        # the batched curve's summary as an ordinary record: knee metrics
        # under extra, latest-wins like any emitted scalar (results.py docs)
        rows = by_adm["batched"]
        runner.store.append(RunResult(
            name=f"{ARCH}/loadgen_curve", bench=f"{ARCH}/loadgen",
            arch=ARCH, task="loadgen", batch=rows[0]["requests"],
            seq=0, dtype="fp32", mode="jit_donated", status="ok",
            median_us=0.0, mean_us=0.0, p10_us=0.0, p90_us=0.0,
            compile_us=0.0, runs=len(rows), wall_s=0.0, ts=time.time(),
            extra={"knee_load": bk["knee_load"],
                   "knee_tok_s": bk["knee_tok_s"],
                   "admission": "batched",
                   "loads": [r["load"] for r in rows],
                   "curve_tok_per_s": [r["tok_per_s"] for r in rows],
                   "comparison": comparison}))
    with open(results_path("loadgen_curve.json"), "w") as f:
        json.dump({"schema": CURVE_SCHEMA, "arch": ARCH,
                   "slots": DEFAULT_SLOTS, "fast": fast,
                   "rows": by_adm["batched"] + by_adm["single"],
                   "curves": curves, "comparison": comparison}, f, indent=1)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--jobs", type=int, default=0,
                    help="shard the loadgen sweep across N workers")
    args = ap.parse_args()
    r = make_runner(jobs=args.jobs)
    try:
        main(fast=args.fast, runner=r)
    finally:
        r.close()
