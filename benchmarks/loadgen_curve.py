"""Latency-vs-offered-load curve: ``task="loadgen"`` cells through the
unified runner, swept over the ``loads`` axis, post-processed into the
saturation knee.

Each cell replays the same mixed-prompt-length trace against the serve
engine with its virtual arrival clock scaled by the offered load; TTFT
and per-token p99 climb as the queue saturates while tok/s flattens —
``repro.runner.loadgen.find_knee`` marks the last load that still bought
throughput.  Sharded loadgen (``--jobs N`` / ``cluster=``) comes free
from ordinary matrix dispatch; add ``splits`` to fan one trace across
workers.

Rows + knee land in ``results/loadgen_curve.json``, and a summary record
carrying ``knee_load`` / ``knee_tok_s`` in its ``extra`` is appended to
the shared ResultStore so CI baselines can track the knee like any other
scalar.

    PYTHONPATH=src python -m benchmarks.loadgen_curve [--fast] [--jobs N]
"""
from __future__ import annotations

import json
import time

from benchmarks.common import emit, make_runner, results_path
from repro.runner.loadgen import find_knee
from repro.runner.results import RunResult
from repro.runner.scenario import ScenarioMatrix

LOADS_FULL = (0.5, 1.0, 2.0, 4.0, 8.0)
LOADS_FAST = (0.5, 1.0, 2.0, 4.0)


def scenario_matrices(fast: bool = False):
    """The matrices this table executes (``benchmarks.run --list`` hook)."""
    requests, prompt = (8, 8) if fast else (16, 16)
    return [ScenarioMatrix(archs=["gemma-2b"], tasks=("loadgen",),
                           batches=(requests,), seqs=(prompt,), slots=(2,),
                           traces=("bursty+bimodal",),
                           loads=LOADS_FAST if fast else LOADS_FULL)]


def main(fast: bool = False, runner=None) -> None:
    runner = runner or make_runner()
    [matrix] = scenario_matrices(fast)
    rows = []
    for rr in runner.run_matrix(matrix):
        if rr.status != "ok":
            emit(f"loadgen/{rr.name}", 0.0,
                 f"status={rr.status};error={(rr.error or '')[:60]}")
            continue
        ex = rr.extra
        emit(f"loadgen/{rr.name}", rr.median_us,
             f"load={ex['offered_load']:g};tok_per_s={ex['tok_per_s']:.1f};"
             f"ttft_p99={ex['ttft_p99']:.0f};tok_lat_p99={ex['tok_lat_p99']:.0f};"
             f"qmax={ex['queue_depth_max']}")
        rows.append({"name": rr.name, "arch": rr.arch, "slots": ex["slots"],
                     "trace": ex["trace"], "load": ex["offered_load"],
                     "split": ex.get("split", ""), "requests": rr.runs,
                     "tok_per_s": ex["tok_per_s"],
                     "decode_steps": ex["decode_steps"],
                     "queue_depth_mean": ex["queue_depth_mean"],
                     "queue_depth_max": ex["queue_depth_max"],
                     "prompt_len_p50": ex.get("prompt_len_p50"),
                     "prompt_len_p95": ex.get("prompt_len_p95"),
                     "tokens_digest": ex["tokens_digest"],
                     **{k: ex[k] for k in ("ttft_p50", "ttft_p95", "ttft_p99",
                                           "tok_lat_p50", "tok_lat_p95",
                                           "tok_lat_p99") if k in ex}})
    knee = find_knee(rows)
    emit("loadgen/knee", knee["knee_tok_s"], f"knee_load={knee['knee_load']:g}")
    if runner.store is not None and rows:
        # the curve's summary as an ordinary record: knee metrics under
        # extra, latest-wins like any emitted scalar (see results.py docs)
        runner.store.append(RunResult(
            name="gemma-2b/loadgen_curve", bench="gemma-2b/loadgen",
            arch="gemma-2b", task="loadgen", batch=rows[0]["requests"],
            seq=0, dtype="fp32", mode="jit_donated", status="ok",
            median_us=0.0, mean_us=0.0, p10_us=0.0, p90_us=0.0,
            compile_us=0.0, runs=len(rows), wall_s=0.0, ts=time.time(),
            extra={"knee_load": knee["knee_load"],
                   "knee_tok_s": knee["knee_tok_s"],
                   "loads": [r["load"] for r in rows],
                   "curve_tok_per_s": [r["tok_per_s"] for r in rows]}))
    with open(results_path("loadgen_curve.json"), "w") as f:
        json.dump({"fast": fast, "rows": rows, "knee": knee}, f, indent=1)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--jobs", type=int, default=0,
                    help="shard the loadgen sweep across N workers")
    args = ap.parse_args()
    r = make_runner(jobs=args.jobs)
    try:
        main(fast=args.fast, runner=r)
    finally:
        r.close()
