"""§Roofline table (beyond-paper deliverable): per (arch x shape) cell the
three roofline terms, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio and
the one-line what-would-move-it note, from the dry-run sweep results."""
from __future__ import annotations

import json

from benchmarks.common import emit, load_dryrun, make_runner, results_path

FALLBACK_CELLS = [("gemma-2b", "train_4k")]

NOTES = {
    "compute": "shard the replicated attention heads / raise MXU utilization",
    "memory": "keep attention/softmax tiles in VMEM (flash kernel), bf16 intermediates",
    "collective": "overlap FSDP all-gathers with compute; reduce wire dtype",
}


def main(fast: bool = False, runner=None) -> None:
    runner = runner or make_runner()
    results = load_dryrun()
    if results is None:
        results = runner.dryrun_cells(FALLBACK_CELLS)
    rows = []
    for r in results:
        if "roofline" not in r:
            if "skipped" in r:
                emit(f"roofline/{r['arch']}/{r['shape']}", 0.0, "skipped=" + r["skipped"][:40])
            continue
        rl = r["roofline"]
        emit(f"roofline/{r['arch']}/{r['shape']}", rl["step_time_upper_s"] * 1e6,
             f"c={rl['compute_s']*1e3:.1f}ms;m={rl['memory_s']*1e3:.1f}ms;"
             f"n={rl['collective_s']*1e3:.1f}ms;dom={rl['dominant']};"
             f"useful={rl['useful_ratio']:.2f};fix={NOTES[rl['dominant']][:38]}")
        rows.append(rl)
    with open(results_path("roofline_table.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
