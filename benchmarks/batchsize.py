"""Paper §2.2 batch-size configuration: doubling search for the inference
batch size that maximizes decode throughput (measured, reduced configs).
The search probes run through the shared ``BenchmarkRunner``, so all batch
sizes of an arch reuse one model build."""
from __future__ import annotations

import json

from benchmarks.common import emit, make_runner, results_path
from repro.core.batchsearch import search_batch_size
from repro.core.suite import build_suite

ARCHS = ["gemma-2b", "mamba2-2.7b", "mixtral-8x7b"]


def main(fast: bool = False, runner=None) -> None:
    runner = runner or make_runner()
    out = {}
    for b in build_suite(tasks=("infer_decode",), archs=ARCHS[: 1 if fast else 3]):
        best, hist = search_batch_size(b, seq=32, max_batch=16 if fast else 32,
                                       runner=runner)
        out[b.name] = {"best_batch": best, "history": hist}
        last = hist[-1] if hist else {}
        emit(f"batchsize/{b.name}", last.get("median_us", 0.0),
             f"best_batch={best};points={len(hist)}")
    with open(results_path("batchsize.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
