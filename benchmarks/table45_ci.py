"""Paper §4.2 / Tables 4-5: CI regression detection + nightly bisection.

End-to-end demo with REAL measurements: record a baseline, inject two
regression classes (runtime inflation via a slow hook, memory bloat via a
leaked buffer), verify detection at the 7% threshold, then bisect a
synthetic day of 12 commits to the culprit in O(log n) measurements.

Every measurement is one scenario re-run through the shared
``BenchmarkRunner`` — the executable cache means the ~10 re-measures of
the same cell (baseline, two injections, bisection probes) compile once."""
from __future__ import annotations

import json
import tempfile

from benchmarks.common import emit, make_runner, results_path
from repro.core.harness import RegressionHook
from repro.core.regression import Commit, MetricStore, bisect_commits, detect
from repro.runner.scenario import Scenario


def _ok(rr):
    """CI math needs real numbers: a failed measurement must fail the table
    loudly, not flow through as median_us=0."""
    if rr.status != "ok":
        raise RuntimeError(f"{rr.name}: {rr.error}")
    return rr


def main(fast: bool = False, runner=None) -> None:
    runner = runner or make_runner()
    sc = Scenario(arch="gemma-2b", task="train", batch=2, seq=32)
    store = MetricStore(tempfile.mktemp(suffix=".json"))

    base = _ok(runner.run(sc, runs=4))
    store.update(sc.bench, base.metrics())
    emit("table45/baseline", base.median_us,
         f"recorded;executable_reused={base.cache.get('executable_reused', False)}")

    # regression class 1: runtime inflation (paper PR #61056 et al.)
    slow = _ok(runner.run(sc, runs=4, hook=RegressionHook(slowdown_s=0.03)))
    issues = detect(store, sc.bench, {"median_us": slow.median_us})
    emit("table45/runtime_inflation", slow.median_us,
         f"detected={bool(issues)};increase={issues[0].increase:.2f}" if issues else "detected=False")

    # regression class 2: memory bloat (paper PR #85447)
    bloat = _ok(runner.run(sc, runs=4, hook=RegressionHook(leak_bytes=1 << 22)))
    issues_m = detect(store, sc.bench,
                      {"host_peak_bytes": bloat.host_peak_bytes,
                       "device_bytes_delta": bloat.device_bytes_delta},
                      metrics=("host_peak_bytes", "device_bytes_delta"))
    emit("table45/memory_bloat", 0.0, f"detected={bool(issues_m)}")

    # nightly bisection over a synthetic commit day — the runner's executable
    # cache turns each probe into a pure re-measure (no rebuild, no re-jit)
    def commit_runner(bad):
        def run(_bench):
            h = RegressionHook(slowdown_s=0.03) if bad else None
            return {"median_us": _ok(runner.run(sc, runs=2, hook=h)).median_us}
        return run

    commits = [Commit(sha=f"c{i:02d}", timestamp=i, run=commit_runner(i >= 8)) for i in range(12)]
    trace: list = []
    # bisect hunts a regression whose size the nightly already measured —
    # classify at half that size so host noise can't flag a good commit
    threshold = max(0.07, issues[0].increase / 2) if issues else 0.07
    culprit = bisect_commits(commits, sc.bench, "median_us", base.median_us,
                             threshold=threshold, trace=trace)
    emit("table45/bisect", 0.0,
         f"culprit={culprit.sha if culprit else None};measured={len(trace)}_of_12")
    emit("table45/runner_reuse", 0.0,
         f"executable_cache_hits={runner.stats.executable_cache_hits};"
         f"model_builds={runner.stats.model_builds}")
    with open(results_path("table45_ci.json"), "w") as f:
        json.dump({"trace": trace, "culprit": culprit.sha if culprit else None,
                   "runtime_issues": [i.to_dict() for i in issues],
                   "memory_issues": [i.to_dict() for i in issues_m],
                   "runner_stats": runner.stats.to_dict()}, f, indent=1)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--jobs", type=int, default=0,
                    help="runner shard setting (CLI parity with benchmarks.run);"
                         " this table's single-cell baseline/injection/bisect"
                         " re-measures are inherently serial and always run"
                         " in-process — sharding applies to matrix sweeps")
    args = ap.parse_args()
    _runner = make_runner(jobs=args.jobs)
    try:
        main(fast=args.fast, runner=_runner)
    finally:
        _runner.close()
