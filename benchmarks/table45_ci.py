"""Paper §4.2 / Tables 4-5: CI regression detection + nightly bisection.

End-to-end demo with REAL measurements: record a baseline, inject two
regression classes (runtime inflation via a slow hook, memory bloat via a
leaked buffer), verify detection at the 7% threshold, then bisect a
synthetic day of 12 commits to the culprit in O(log n) measurements."""
from __future__ import annotations

import json
import tempfile

from benchmarks.common import emit, results_path
from repro.core.harness import RegressionHook, measure
from repro.core.regression import Commit, MetricStore, bisect_commits, detect
from repro.core.suite import build_suite


def main(fast: bool = False) -> None:
    bench = build_suite(tasks=("train",), archs=["gemma-2b"])[0]
    step, args, donate = bench.make(batch=2, seq=32)
    store = MetricStore(tempfile.mktemp(suffix=".json"))

    base = measure(bench.name, step, args, donate, runs=4)
    store.update(bench.name, {"median_us": base.median_us,
                              "host_peak_bytes": base.host_peak_bytes})
    emit("table45/baseline", base.median_us, "recorded")

    # regression class 1: runtime inflation (paper PR #61056 et al.)
    slow = measure(bench.name, step, args, donate, runs=4,
                   hook=RegressionHook(slowdown_s=0.03))
    issues = detect(store, bench.name, {"median_us": slow.median_us})
    emit("table45/runtime_inflation", slow.median_us,
         f"detected={bool(issues)};increase={issues[0].increase:.2f}" if issues else "detected=False")

    # regression class 2: memory bloat (paper PR #85447)
    bloat = measure(bench.name, step, args, donate, runs=4,
                    hook=RegressionHook(leak_bytes=1 << 22))
    issues_m = detect(store, bench.name,
                      {"host_peak_bytes": bloat.host_peak_bytes,
                       "device_bytes_delta": bloat.device_bytes_delta},
                      metrics=("host_peak_bytes", "device_bytes_delta"))
    emit("table45/memory_bloat", 0.0, f"detected={bool(issues_m)}")

    # nightly bisection over a synthetic commit day
    def runner(bad):
        def run(_bench):
            h = RegressionHook(slowdown_s=0.03) if bad else None
            m = measure(bench.name, step, args, donate, runs=2, hook=h)
            return {"median_us": m.median_us}
        return run

    commits = [Commit(sha=f"c{i:02d}", timestamp=i, run=runner(i >= 8)) for i in range(12)]
    trace: list = []
    culprit = bisect_commits(commits, bench.name, "median_us", base.median_us, trace=trace)
    emit("table45/bisect", 0.0,
         f"culprit={culprit.sha if culprit else None};measured={len(trace)}_of_12")
    with open(results_path("table45_ci.json"), "w") as f:
        json.dump({"trace": trace, "culprit": culprit.sha if culprit else None,
                   "runtime_issues": [i.to_dict() for i in issues],
                   "memory_issues": [i.to_dict() for i in issues_m]}, f, indent=1)


if __name__ == "__main__":
    main()
