"""Paper Figs. 1-2 + Table 2: execution-time breakdown per benchmark and per
domain, derived from the dry-run roofline terms (compute / HBM / ICI).
Fallback cells compile through the runner's cached dry-run path, so cells
shared with fig5/roofline cost one subprocess total."""
from __future__ import annotations

import json

from benchmarks.common import emit, load_dryrun, make_runner, results_path
from repro.core.breakdown import breakdown_rows, domain_table

FALLBACK_CELLS = [("gemma-2b", "train_4k"), ("mamba2-2.7b", "train_4k"),
                  ("gemma-2b", "decode_32k")]


def main(fast: bool = False, runner=None) -> None:
    runner = runner or make_runner()
    results = load_dryrun()
    if results is None:
        results = runner.dryrun_cells(FALLBACK_CELLS[: 2 if fast else 3])
    rows = breakdown_rows(results)
    for r in rows:
        emit(f"fig12/{r['arch']}/{r['shape']}", 0.0,
             f"compute={r['compute_frac']:.2f};memory={r['memory_frac']:.2f};"
             f"collective={r['collective_frac']:.2f};dominant={r['dominant']}")
    for kind, flt in [("train", lambda r: r["shape"].startswith("train")),
                      ("inference", lambda r: not r["shape"].startswith("train"))]:
        for d in domain_table(rows, flt):
            emit(f"table2/{kind}/{d['domain']}", 0.0,
                 f"n={d['n']};compute={d['compute_frac']:.2f};memory={d['memory_frac']:.2f};"
                 f"collective={d['collective_frac']:.2f}")
    with open(results_path("fig12_breakdown.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
