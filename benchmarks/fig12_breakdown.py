"""Paper Figs. 1-2 + Table 2: execution-time breakdown per benchmark and per
domain.  Two provenances, labeled per row (``source=measured|analytic``):

* **measured** (preferred): a profiled runner sweep over the measured
  suite cells — real phase timelines + op-class attribution from
  ``src/repro/profiler/`` (the paper's profiler-driven Figs. 1-2, on the
  hardware we actually have);
* **analytic** (production-shape fallback): the dry-run roofline terms
  for the full-config cells this container can only compile, not run.
  Fallback cells compile through the runner's cached dry-run path, so
  cells shared with fig5/roofline cost one subprocess total.

Everything executes through the shared ``BenchmarkRunner`` — the measured
sweep honors ``--jobs``/``--isolate``/session filters like every other
table, and the dry-run path reuses the store-level cell cache."""
from __future__ import annotations

import json

from benchmarks.common import emit, load_dryrun, make_runner, results_path
from repro.core.breakdown import (breakdown_rows, domain_table,
                                  measured_breakdown_rows)
from repro.runner import ScenarioMatrix

FALLBACK_CELLS = [("gemma-2b", "train_4k"), ("mamba2-2.7b", "train_4k"),
                  ("gemma-2b", "decode_32k")]

MEASURED_ARCHS = ["gemma-2b", "mamba2-2.7b"]


def _measured_matrix(fast: bool = False) -> ScenarioMatrix:
    return ScenarioMatrix(archs=MEASURED_ARCHS[: 1 if fast else 2],
                          tasks=("train", "infer_decode"),
                          batches=(2,), seqs=(32,))


def scenario_matrices(fast: bool = False):
    """The matrices this table executes (``benchmarks.run --list`` hook)."""
    return [_measured_matrix(fast)]


def _emit_rows(rows, prefix: str) -> None:
    for r in rows:
        overhead = ""
        if r["source"] == "measured":
            overhead = (f";dispatch={r['dispatch_frac']:.2f}"
                        f";idle={r['idle_frac']:.2f}")
        emit(f"{prefix}/{r['arch']}/{r['shape']}", 0.0,
             f"source={r['source']};compute={r['compute_frac']:.2f};"
             f"memory={r['memory_frac']:.2f};"
             f"collective={r['collective_frac']:.2f};"
             f"dominant={r['dominant']}{overhead}")


def main(fast: bool = False, runner=None) -> None:
    runner = runner or make_runner()
    # measured rows first: a profiled sweep through the shared runner
    # (sharded under --jobs exactly like any other matrix)
    measured = runner.run_matrix(_measured_matrix(fast), profile=True)
    rows = measured_breakdown_rows(measured)
    # analytic fallback for the production shapes we can only compile
    results = load_dryrun()
    if results is None:
        results = runner.dryrun_cells(FALLBACK_CELLS[: 2 if fast else 3])
    rows += breakdown_rows(results)
    _emit_rows(rows, "fig12")
    for kind, flt in [("train", lambda r: r["shape"].startswith("train")),
                      ("inference", lambda r: not r["shape"].startswith("train"))]:
        for src in ("measured", "analytic"):
            sel = [r for r in rows if r["source"] == src]
            for d in domain_table(sel, flt):
                emit(f"table2/{kind}/{d['domain']}", 0.0,
                     f"source={src};n={d['n']};compute={d['compute_frac']:.2f};"
                     f"memory={d['memory_frac']:.2f};"
                     f"collective={d['collective_frac']:.2f}")
    with open(results_path("fig12_breakdown.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
