"""Paper Table 1 + §2.3: suite overview and API-surface coverage.

Reports, per benchmark: domain, task, criteria, measured step time on the
reduced config, and the primitive/StableHLO surface; plus the suite-level
coverage multiple vs the single-dense-LM baseline (the paper's "2.3x
MLPerf" claim, reproduced quantitatively)."""
from __future__ import annotations

import json

from benchmarks.common import emit, results_path
from repro.core.coverage import coverage_report
from repro.core.harness import measure
from repro.core.suite import build_suite


def main(fast: bool = False) -> None:
    tasks = ("train", "infer_decode") if fast else ("train", "infer_prefill", "infer_decode")
    benches = build_suite(tasks=tasks)
    rep = coverage_report(benches, batch=1, seq=16)
    rows = []
    for b in benches:
        step, args, donate = b.make(batch=2, seq=32)
        m = measure(b.name, step, args, donate, runs=3)
        surf = rep["per_benchmark"][b.name]
        emit(f"table1/{b.name}", m.median_us,
             f"domain={b.domain};criteria={b.criteria};prims={surf['n_primitives']};hlo_ops={surf['n_stablehlo_ops']}")
        rows.append({"benchmark": b.name, "domain": b.domain, "criteria": b.criteria,
                     "median_us": m.median_us, **{k: surf[k] for k in ("n_primitives", "n_stablehlo_ops")}})
    emit("table1/coverage_x_primitives", 0.0, f"{rep['coverage_x_primitives']:.2f}x_vs_single_dense_LM")
    emit("table1/coverage_x_stablehlo", 0.0, f"{rep['coverage_x_stablehlo']:.2f}x_vs_single_dense_LM")
    with open(results_path("table1_suite.json"), "w") as f:
        json.dump({"rows": rows, "coverage": {k: rep[k] for k in rep if k != "per_benchmark"}}, f, indent=1)


if __name__ == "__main__":
    main()
