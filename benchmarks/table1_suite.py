"""Paper Table 1 + §2.3: suite overview and API-surface coverage.

Reports, per benchmark: domain, task, criteria, measured step time on the
reduced config, and the primitive/StableHLO surface; plus the suite-level
coverage multiple vs the single-dense-LM baseline (the paper's "2.3x
MLPerf" claim, reproduced quantitatively).

Measurement goes through the shared ``BenchmarkRunner``: the coverage
tracer and the timing pass reuse one arch build each, every row lands in
the persistent ResultStore, and the timing sweep is one ``run_matrix``
call — shardable across worker subprocesses with ``--jobs N``."""
from __future__ import annotations

import json

from benchmarks.common import emit, make_runner, results_path
from repro.configs import ARCHS
from repro.core.coverage import coverage_report
from repro.core.suite import get_benchmark
from repro.runner.scenario import ScenarioMatrix


def scenario_matrices(fast: bool = False):
    """The matrices this table executes (``benchmarks.run --list`` hook)."""
    tasks = ("train", "infer_decode") if fast else ("train", "infer_prefill", "infer_decode")
    return [ScenarioMatrix(archs=sorted(ARCHS), tasks=tasks, batches=(2,), seqs=(32,))]


def main(fast: bool = False, runner=None) -> None:
    runner = runner or make_runner()
    [matrix] = scenario_matrices(fast)
    scenarios = runner.select(matrix)
    benches = [get_benchmark(s.arch, s.task) for s in scenarios]
    rep = coverage_report(benches, batch=1, seq=16, runner=runner)
    rows = []
    for b, rr in zip(benches, runner.run_matrix(matrix, runs=3)):
        if rr.status != "ok":
            emit(f"table1/{b.name}", 0.0, f"status={rr.status};error={(rr.error or '')[:60]}")
            continue
        surf = rep["per_benchmark"][b.name]
        emit(f"table1/{b.name}", rr.median_us,
             f"domain={b.domain};criteria={b.criteria};prims={surf['n_primitives']};hlo_ops={surf['n_stablehlo_ops']}")
        rows.append({"benchmark": b.name, "domain": b.domain, "criteria": b.criteria,
                     "median_us": rr.median_us, **{k: surf[k] for k in ("n_primitives", "n_stablehlo_ops")}})
    emit("table1/coverage_x_primitives", 0.0, f"{rep['coverage_x_primitives']:.2f}x_vs_single_dense_LM")
    emit("table1/coverage_x_stablehlo", 0.0, f"{rep['coverage_x_stablehlo']:.2f}x_vs_single_dense_LM")
    with open(results_path("table1_suite.json"), "w") as f:
        json.dump({"rows": rows, "coverage": {k: rep[k] for k in rep if k != "per_benchmark"}}, f, indent=1)


if __name__ == "__main__":
    main()
