"""Paper Fig. 5 + Table 3: cross-accelerator projection from the compiled
roofline terms (A100-like vs MI210-like profiles; also v5e vs v4)."""
from __future__ import annotations

import json

from benchmarks.common import emit, load_dryrun, make_runner, results_path
from repro.core.hardware import HW_PROFILES
from repro.core.hwcompare import hardware_ratio_table

FALLBACK_CELLS = [("gemma-2b", "train_4k"), ("mamba2-2.7b", "train_4k")]


def main(fast: bool = False, runner=None) -> None:
    runner = runner or make_runner()
    results = load_dryrun()
    if results is None:
        results = runner.dryrun_cells(FALLBACK_CELLS)
    for pair in [("a100_like", "mi210_like"), ("tpu_v5e", "tpu_v4")]:
        rows = hardware_ratio_table(results, *pair)
        wins = {pair[0]: 0, pair[1]: 0}
        for r in rows:
            emit(f"fig5/{pair[0]}_vs_{pair[1]}/{r['arch']}/{r['shape']}", 0.0,
                 f"ratio={r['ratio']:.3f};winner={r['winner']};dominant={r['dominant']}")
            wins[r["winner"]] += 1
        emit(f"fig5/{pair[0]}_vs_{pair[1]}/wins", 0.0,
             f"{pair[0]}={wins[pair[0]]};{pair[1]}={wins[pair[1]]}")
        with open(results_path(f"fig5_{pair[0]}_vs_{pair[1]}.json"), "w") as f:
            json.dump(rows, f, indent=1)
    # Table 3 analogue: the profiles themselves
    for name, hw in HW_PROFILES.items():
        emit(f"table3/{name}", 0.0,
             f"bf16_tflops={hw.peak_flops_bf16/1e12:.0f};fp32_tflops={hw.peak_flops_fp32/1e12:.1f};"
             f"hbm_gbs={hw.hbm_bw/1e9:.0f};link_gbs={hw.link_bw/1e9:.0f}")


if __name__ == "__main__":
    main()
