"""Paper Figs. 3-4: execution-mode ("compiler") comparison — eager vs jit
variants, reporting time / host-mem / device-mem ratios (T/CM/GM).

One ``ScenarioMatrix`` over arch x mode drives the whole figure; the
runner shares each arch's build across its eager/jit/jit_donated cells."""
from __future__ import annotations

import json

from benchmarks.common import emit, make_runner, results_path
from repro.core.compilers import ratio_table
from repro.runner.scenario import ScenarioMatrix

ARCHS_FULL = ["gemma-2b", "mixtral-8x7b", "mamba2-2.7b", "recurrentgemma-9b",
              "internlm2-20b", "whisper-large-v3"]
ARCHS_FAST = ["gemma-2b", "mamba2-2.7b"]


def scenario_matrices(fast: bool = False):
    """The matrices this figure executes (``benchmarks.run --list`` hook)."""
    archs = ARCHS_FAST if fast else ARCHS_FULL
    modes = ("eager", "jit", "jit_donated") if fast else \
            ("eager", "jit", "jit_donated", "jit_unrolled", "jit_noremat")
    return [ScenarioMatrix(archs=archs, tasks=("train",), batches=(2,),
                           seqs=(48,), modes=modes)]


def main(fast: bool = False, runner=None) -> None:
    runner = runner or make_runner()
    [matrix] = scenario_matrices(fast)
    results = {}
    for rr in runner.run_matrix(matrix, runs=3):
        if rr.status != "ok":
            emit(f"fig34/{rr.bench}/{rr.mode}", 0.0,
                 f"status={rr.status};error={(rr.error or '')[:60]}")
            continue
        results.setdefault(rr.bench, {})[rr.mode] = rr
        emit(f"fig34/{rr.bench}/{rr.mode}", rr.median_us,
             f"host_peak={rr.host_peak_bytes};compile_us={rr.compile_us:.0f}")
    rows = ratio_table(results, base="jit")
    # time_ratio for the eager rows is eager/jit — i.e. the jit speedup
    speedups = [r["time_ratio"] for r in rows if r["mode"] == "eager" and r["time_ratio"]]
    if speedups:
        import math
        geo = math.exp(sum(math.log(s) for s in speedups) / len(speedups))
        emit("fig34/jit_speedup_vs_eager_geomean", 0.0, f"{geo:.2f}x")
    with open(results_path("fig34_compilers.json"), "w") as f:
        json.dump({k: {mm: m.to_dict() for mm, m in v.items()} for k, v in results.items()},
                  f, indent=1)


if __name__ == "__main__":
    main()
