"""Paper Figs. 3-4: execution-mode ("compiler") comparison — eager vs jit
variants, reporting time / host-mem / device-mem ratios (T/CM/GM)."""
from __future__ import annotations

import json

from benchmarks.common import emit, results_path
from repro.core.compilers import compare_modes, ratio_table
from repro.core.suite import build_suite

ARCHS_FULL = ["gemma-2b", "mixtral-8x7b", "mamba2-2.7b", "recurrentgemma-9b",
              "internlm2-20b", "whisper-large-v3"]
ARCHS_FAST = ["gemma-2b", "mamba2-2.7b"]


def main(fast: bool = False) -> None:
    archs = ARCHS_FAST if fast else ARCHS_FULL
    results = {}
    for b in build_suite(tasks=("train",), archs=archs):
        modes = ("eager", "jit", "jit_donated") if fast else \
                ("eager", "jit", "jit_donated", "jit_unrolled", "jit_noremat")
        results[b.name] = compare_modes(b, batch=2, seq=48, runs=3, modes=modes)
        for mode, m in results[b.name].items():
            emit(f"fig34/{b.name}/{mode}", m.median_us,
                 f"host_peak={m.host_peak_bytes};compile_us={m.compile_us:.0f}")
    rows = ratio_table(results, base="jit")
    # time_ratio for the eager rows is eager/jit — i.e. the jit speedup
    speedups = [r["time_ratio"] for r in rows if r["mode"] == "eager" and r["time_ratio"]]
    if speedups:
        import math
        geo = math.exp(sum(math.log(s) for s in speedups) / len(speedups))
        emit("fig34/jit_speedup_vs_eager_geomean", 0.0, f"{geo:.2f}x")
    with open(results_path("fig34_compilers.json"), "w") as f:
        json.dump({k: {mm: m.to_dict() for mm, m in v.items()} for k, v in results.items()},
                  f, indent=1)


if __name__ == "__main__":
    main()
