"""Provenance-keyed nightly trajectory report over the result history.

Reads NOTHING but ``ResultStore.history()`` — the append-only JSONL run
log every ``run_matrix`` call and every ``core/ci.run_nightly`` night
appends provenance-stamped records to — and renders the
``repro.telemetry.history`` view of it:

* one time series per (scenario name, provenance key), where the
  provenance key is ``<commit>[+dirty]/<backend>/<host>`` from the
  ``extra["prov_*"]`` stamps, so a laptop's cpu numbers never mix into a
  TPU host's baseline;
* rolling-median baselines and drift findings per series (the paper's
  7% ``core/regression`` threshold), ranked into the same report shape
  the profiler uses (``profiler/report.py``);
* CSV rows per series (``benchmarks.common.emit`` contract), the human
  table on comment lines, and the full JSON in
  ``results/history_report.json``.

    PYTHONPATH=src python -m benchmarks.history_report [--store PATH]
        [--min-points K] [--window W]

With the default store (``results/store``) a ``--fast`` suite run plus
two ``run_nightly`` nights is already enough material for a >=2-point
trajectory per probe cell.
"""
from __future__ import annotations

import json

from benchmarks.common import emit, results_path
from repro.profiler.report import format_table
from repro.runner.results import ResultStore
from repro.telemetry.history import trajectory


def main(fast: bool = False, runner=None, store_path: str = "",
         window: int = 5, min_points: int = 2) -> dict:
    """Build + persist the trajectory report; returns the report dict.

    ``fast``/``runner`` exist for the ``benchmarks.run`` table contract
    but are unused: this report executes nothing — it only reads the
    history log the other tables (and nightly CI) already wrote."""
    del fast, runner
    store = ResultStore(store_path or results_path("store"))
    report = trajectory(store, window=window, min_points=min_points)
    for s in report["meta"]["series"]:
        first, last = s["first_median_us"], s["last_median_us"]
        emit(f"history_report/{s['name']}", last or 0.0,
             f"points={s['points']};ok={s['ok']};"
             f"trend={s['trend']:+.1%};prov={s['provenance']}")
    emit("history_report/series", 0.0,
         f"n={len(report['meta']['series'])};"
         f"drifts={len(report['findings'])};"
         f"corrupt_lines={store.corrupt_lines}")
    with open(results_path("history_report.json"), "w") as f:
        json.dump(report, f, indent=1)
    for line in format_table(report).splitlines():
        print(f"# {line}")
    return report


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", default="",
                    help="ResultStore path (default results/store)")
    ap.add_argument("--window", type=int, default=5,
                    help="rolling-baseline window (ok points)")
    ap.add_argument("--min-points", type=int, default=2,
                    help="series below this many points are omitted")
    args = ap.parse_args()
    main(store_path=args.store, window=args.window,
         min_points=args.min_points)
