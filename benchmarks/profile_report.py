"""Profile-driven inefficiency findings over the measured suite (the
paper's use case 1: profile the benchmarks, find the optimization
targets).

Sweeps a step matrix + a serve cell through the shared BenchmarkRunner
with ``profile=True`` (sharded under ``--jobs`` like every table), runs
the rule-based detectors (``repro.profiler.detectors``) over the profiled
RunResults, and emits a ranked findings report — CSV rows per finding,
a human table on stderr-safe comment lines, and the full JSON (records'
prof summaries + findings + tallies) in ``results/profile_report.json``.

    PYTHONPATH=src python -m benchmarks.profile_report [--fast] [--jobs N]

``--drain-queue`` closes the detect -> tune loop from the other side:
instead of profiling, it turns the queued jobs a previous report wrote
to ``results/tuning_queue.json`` into an actual launch-parameter sweep
(``repro.tuning.run_sweep``) and empties the queue — winners land in the
tuning DB, where the ops layer serves them on the next trace.
"""
from __future__ import annotations

import json

from benchmarks.common import emit, make_runner, results_path
from repro.profiler import build_report, detect, format_table
from repro.runner import ScenarioMatrix
from repro.tuning import enqueue_jobs, jobs_from_findings
from repro.tuning import drain_queue as tuning_drain_queue

STEP_ARCHS = ["gemma-2b", "mamba2-2.7b", "recurrentgemma-9b", "mixtral-8x7b"]


def _step_matrix(fast: bool = False) -> ScenarioMatrix:
    return ScenarioMatrix(archs=STEP_ARCHS[: 2 if fast else 4],
                          tasks=("train", "infer_decode"),
                          batches=(2,), seqs=(32,))


def _serve_matrix(fast: bool = False) -> ScenarioMatrix:
    # a bursty trace over few slots: the queue-saturation detector's beat
    return ScenarioMatrix(archs=["gemma-2b"], tasks=("serve",),
                          batches=(4 if fast else 8,), seqs=(8,),
                          slots=(2,), traces=("bursty",))


def scenario_matrices(fast: bool = False):
    """The matrices this report executes (``benchmarks.run --list`` hook)."""
    return [_step_matrix(fast), _serve_matrix(fast)]


def _prof_summary(rec: dict) -> dict:
    """A record's profile, minus the bulky timeline (JSON report diet)."""
    extra = rec.get("extra") or {}
    keep = {k: v for k, v in extra.items()
            if k.startswith("prof_") and k != "prof_timeline"}
    return {"name": rec["name"], "status": rec["status"],
            "median_us": rec.get("median_us"),
            "compile_us": rec.get("compile_us"),
            "shard": extra.get("shard"), **keep}


def drain_queue(runner=None, queue_path=None) -> dict:
    """Sweep every queued tuning job and empty the queue.

    Thin formatter over ``repro.tuning.drain_queue`` (the core is in the
    tuning layer so the fleet scheduler drains the same queue on its own
    cadence): emits the CSV rows and human comments this script's
    contract promises."""
    queue_path = queue_path or results_path("tuning_queue.json")
    out = tuning_drain_queue(runner or make_runner(), queue_path=queue_path)
    emit("profile_report/drain_queue", 0.0,
         f"jobs={out['jobs']};cases={out['cases']};queue={queue_path}")
    if not out["cases"]:
        print(f"# tuning queue empty ({queue_path}); nothing to drain")
        return {"jobs": out["jobs"], "cases": 0}
    for c in out["case_rows"]:
        ratio = c.get("ratio")
        note = f"status={c['status']}"
        if ratio:
            note += f";ratio={ratio:.3f}"
        emit(f"profile_report/drained/{c['case']}",
             c.get("winner_us") or 0.0, note)
    print(f"# drained {out['cases']} tuning jobs -> {out['db_path']} "
          f"({out['recorded']} winners recorded)")
    return {"jobs": out["jobs"], "cases": out["cases"],
            "recorded": out["recorded"], "db": out["db_path"]}


def main(fast: bool = False, runner=None) -> None:
    runner = runner or make_runner()
    results = runner.run_matrix(_step_matrix(fast), profile=True)
    results += runner.run_matrix(_serve_matrix(fast), profile=True)
    recs = [rr.to_dict() for rr in results]
    findings = detect(recs)
    report = build_report(recs, findings,
                          meta={"fast": fast,
                                "cells": [r["name"] for r in recs]})
    for f in report["findings"]:
        emit(f"profile_report/{f['rule']}/{f['cell']}", 0.0,
             f"severity={f['severity']};score={f['score']:.2f}")
    emit("profile_report/findings", 0.0,
         f"n={len(report['findings'])};"
         f"crit={report['by_severity'].get('crit', 0)};"
         f"warn={report['by_severity'].get('warn', 0)};"
         f"info={report['by_severity'].get('info', 0)};"
         f"profiled={report['cells_profiled']}/{report['cells']}")
    # detector -> autotuner bridge: data_movement_bound / low_util findings
    # become tuning jobs for the Pallas kernels their arch uses, enqueued
    # for the next sweep (repro.tuning.run_sweep over cases_from_jobs)
    jobs = jobs_from_findings(findings, recs)
    queue_path = results_path("tuning_queue.json")
    if jobs:
        enqueue_jobs(jobs, queue_path)
    emit("profile_report/tuning_jobs", 0.0,
         f"n={len(jobs)};queue={queue_path}")
    report["tuning_jobs"] = jobs
    report["tuning_queue"] = str(queue_path)
    report["profiles"] = [_prof_summary(r) for r in recs]
    with open(results_path("profile_report.json"), "w") as f:
        json.dump(report, f, indent=1)
    for line in format_table(report).splitlines():
        print(f"# {line}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--jobs", type=int, default=0,
                    help="shard the profiled sweep across N workers")
    ap.add_argument("--drain-queue", action="store_true",
                    help="sweep results/tuning_queue.json jobs instead of "
                         "profiling, then empty the queue")
    args = ap.parse_args()
    r = make_runner(jobs=args.jobs)
    try:
        if args.drain_queue:
            drain_queue(runner=r)
        else:
            main(fast=args.fast, runner=r)
    finally:
        r.close()
