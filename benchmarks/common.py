"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Any, Dict, List, Optional

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(REPO, "results")


def results_path(name: str) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    return os.path.join(RESULTS, name)


def load_dryrun(multi_pod: bool = False) -> Optional[List[Dict[str, Any]]]:
    p = results_path("dryrun_multi.json" if multi_pod else "dryrun_single.json")
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return None


def run_dryrun_subprocess(arch: str, shape: str, *, multi_pod: bool = False,
                          rules: Optional[dict] = None,
                          timeout: int = 1200) -> Dict[str, Any]:
    """Dry-run in a subprocess so THIS process keeps 1 CPU device."""
    out = results_path(f"_cell_{arch}_{shape}{'_mp' if multi_pod else ''}.json")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--json", out]
    if multi_pod:
        cmd.append("--multi-pod")
    if rules:
        cmd += ["--rules", json.dumps(rules)]
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(cmd, env=env, capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"dryrun {arch}x{shape} failed:\n{r.stderr[-2000:]}")
    with open(out) as f:
        return json.load(f)[0]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The run.py CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")
