"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(REPO, "results")


def results_path(name: str) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    return os.path.join(RESULTS, name)


def load_dryrun(multi_pod: bool = False) -> Optional[List[Dict[str, Any]]]:
    p = results_path("dryrun_multi.json" if multi_pod else "dryrun_single.json")
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return None


def make_runner(runs: int = 3, **kw):
    """The benchmark harness's shared BenchmarkRunner, persisting RunResults
    to ``results/store`` (runs.jsonl + latest.json)."""
    from repro.runner import BenchmarkRunner, ResultStore
    return BenchmarkRunner(store=ResultStore(results_path("store")),
                           runs=runs, **kw)


def run_dryrun_subprocess(arch: str, shape: str, *, multi_pod: bool = False,
                          rules: Optional[dict] = None,
                          timeout: int = 1200) -> Dict[str, Any]:
    """Dry-run in a subprocess so THIS process keeps 1 CPU device."""
    from repro.runner import dryrun_cell_subprocess
    return dryrun_cell_subprocess(arch, shape, multi_pod=multi_pod,
                                  rules=rules, timeout=timeout)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The run.py CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")
