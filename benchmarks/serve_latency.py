"""Serving-latency table: continuous-batching workloads as first-class
scenario cells (``task="serve"``) through the unified runner.

Per (arch x slots x trace) cell we report the latency distribution a
production user compares — TTFT and per-token p50/p95/p99 plus tok/s —
computed by the serve engine (``repro.launch.serve``) over deterministic
load profiles (``repro.runner.traces``).  The sweep is one ``run_matrix``
call: it shards across ``--jobs N`` workers like every other table, and
every cell lands in the shared ResultStore under the well-known serve
extra keys (``repro/runner/results.py``).

Rows land in ``results/serve_latency.json``.
"""
from __future__ import annotations

import json

from benchmarks.common import emit, make_runner, results_path
from repro.runner.scenario import ScenarioMatrix

ARCHS_FULL = ["gemma-2b", "mixtral-8x7b", "mamba2-2.7b"]
ARCHS_FAST = ["gemma-2b"]


def scenario_matrices(fast: bool = False):
    """The matrices this table executes (``benchmarks.run --list`` hook)."""
    archs = ARCHS_FAST if fast else ARCHS_FULL
    slots = (2,) if fast else (2, 4)
    traces = ("uniform", "bursty") if fast else ("uniform", "bursty", "mixed")
    requests, prompt = (6, 8) if fast else (16, 16)
    return [ScenarioMatrix(archs=archs, tasks=("serve",), batches=(requests,),
                           seqs=(prompt,), slots=slots, traces=traces)]


def main(fast: bool = False, runner=None) -> None:
    runner = runner or make_runner()
    [matrix] = scenario_matrices(fast)
    rows = []
    for rr in runner.run_matrix(matrix):
        if rr.status != "ok":
            emit(f"serve/{rr.name}", 0.0,
                 f"status={rr.status};error={(rr.error or '')[:60]}")
            continue
        ex = rr.extra
        emit(f"serve/{rr.name}", rr.median_us,
             f"tok_per_s={ex['tok_per_s']:.1f};ttft_p50={ex['ttft_p50']:.0f};"
             f"ttft_p99={ex['ttft_p99']:.0f};tok_lat_p99={ex['tok_lat_p99']:.0f};"
             f"qmax={ex['queue_depth_max']}")
        rows.append({"name": rr.name, "arch": rr.arch, "slots": ex["slots"],
                     "trace": ex["trace"], "requests": rr.runs,
                     "admission": ex["admission"],
                     "admit_calls": ex["admit_calls"],
                     "admit_batch_mean": ex["admit_batch_mean"],
                     "tok_per_s": ex["tok_per_s"],
                     "decode_steps": ex["decode_steps"],
                     "queue_depth_mean": ex["queue_depth_mean"],
                     "queue_depth_max": ex["queue_depth_max"],
                     "tokens_digest": ex["tokens_digest"],
                     **{k: ex[k] for k in ("ttft_p50", "ttft_p95", "ttft_p99",
                                           "tok_lat_p50", "tok_lat_p95",
                                           "tok_lat_p99") if k in ex}})
    with open(results_path("serve_latency.json"), "w") as f:
        json.dump({"fast": fast, "rows": rows}, f, indent=1)


if __name__ == "__main__":
    main()
