"""Benchmark harness entry point: one function per paper table/figure, all
executed through the unified ``repro.runner.BenchmarkRunner``.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]
        [--filter RE ...] [--exclude RE ...] [--isolate] [--jobs N]
        [--cluster local:N|HOST:PORT] [--profile] [--list]
        [--trace-out PATH]

``--list`` prints the scenario names each matrix-driven table would run
(after filter/exclude/skip selection) and exits without executing —
cheap debugging for sharded sweeps.

One ``BenchmarkRunner`` + ``ResultStore`` (``results/store``) is shared by
every table: arch builds, compiled executables, and dry-run cells are
reused across figures, and every measurement lands as a versioned
``RunResult`` (schema documented in ``repro/runner/results.py``) in the
JSONL run log with a latest-pointer for ``scripts/report_tables.py``.

``--filter`` / ``--exclude`` are regexes over scenario names
("arch/task/bN/sN/dtype/mode"), applied to the measured-suite tables —
the torchbench driver's model-selection semantics.  ``--isolate`` runs
each scenario in its own subprocess (fault containment for crashy cells);
``--jobs N`` shards every ``run_matrix`` sweep across N persistent worker
subprocesses (see ``repro/runner/pool.py``); ``--cluster local:N`` (or
``--cluster HOST:PORT`` with workers launched elsewhere via ``python -m
repro.runner.worker --connect HOST:PORT``) dispatches every sweep across
socket-connected cluster workers instead (see ``repro/runner/cluster/``).

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced sweep for CI")
    ap.add_argument("--only", default=None)
    ap.add_argument("--list", action="store_true",
                    help="print the selected scenario names (post "
                         "filter/exclude/skip) without executing anything")
    ap.add_argument("--filter", action="append", default=[],
                    help="regex over scenario names; keep matches")
    ap.add_argument("--exclude", action="append", default=[],
                    help="regex over scenario names; drop matches")
    ap.add_argument("--isolate", action="store_true",
                    help="one subprocess per scenario (fault containment)")
    ap.add_argument("--jobs", type=int, default=0,
                    help="shard matrix sweeps across N worker subprocesses")
    ap.add_argument("--cluster", default="",
                    help="dispatch matrix sweeps across cluster workers: "
                         "'local:N' spawns N localhost workers, 'HOST:PORT' "
                         "binds the coordinator there for external "
                         "worker --connect processes")
    ap.add_argument("--profile", action="store_true",
                    help="measured profiling on every matrix cell: phase "
                         "timelines + op-class attribution under "
                         "extra['prof_*'] (src/repro/profiler/)")
    ap.add_argument("--refresh", action="store_true",
                    help="recompile cached dry-run cells (after config/model changes)")
    ap.add_argument("--trace-out", default="",
                    help="trace every run_matrix call and write one "
                         "stitched Chrome trace-event JSON (Perfetto-"
                         "loadable) here; also prints a text flame "
                         "summary (src/repro/telemetry/)")
    args = ap.parse_args(argv)

    from benchmarks import (batchsize, fig5_hardware, fig12_breakdown,
                            fig34_compilers, history_report, loadgen_curve,
                            profile_report, roofline, runner_bench,
                            serve_latency, table1_suite, table45_ci)
    from benchmarks.common import make_runner
    runner = make_runner(isolate=args.isolate, jobs=args.jobs,
                         cluster=args.cluster, profile=args.profile)
    runner.default_filter = tuple(args.filter)
    runner.default_exclude = tuple(args.exclude)
    runner.dryrun_refresh = args.refresh
    if args.trace_out:
        from repro.telemetry.spans import Tracer
        runner.tracer = Tracer()
    tables = {
        "table1_suite": table1_suite.main,         # Table 1 + coverage (§2.3)
        "fig12_breakdown": fig12_breakdown.main,   # Figs 1-2 + Table 2
        "fig34_compilers": fig34_compilers.main,   # Figs 3-4
        "fig5_hardware": fig5_hardware.main,       # Fig 5 + Table 3
        "table45_ci": table45_ci.main,             # §4.2, Tables 4-5
        "batchsize": batchsize.main,               # §2.2 batch-size search
        "roofline": roofline.main,                 # §Roofline deliverable
        "serve_latency": serve_latency.main,       # serving-latency table
        "loadgen_curve": loadgen_curve.main,       # TTFT/p99 vs offered load
        "profile_report": profile_report.main,     # measured inefficiency findings
        "runner_bench": runner_bench.main,         # runner reuse speedup
        "history_report": history_report.main,     # provenance trajectories
    }
    if args.list:
        # sharded-sweep debugging: show exactly which cells each table's
        # matrices select under the session --filter/--exclude, zero
        # execution.  Tables without a scenario_matrices hook (dry-run /
        # single-probe tables) are reported as such.
        for name, fn in tables.items():
            if args.only and name != args.only:
                continue
            mod = sys.modules[fn.__module__]
            hook = getattr(mod, "scenario_matrices", None)
            if hook is None:
                print(f"# {name}: no scenario matrix (dry-run or probe cells)")
                continue
            for matrix in hook(fast=args.fast):
                for sc in runner.select(matrix):
                    print(f"{name} {sc.name}")
        return 0
    failed = 0
    try:
        for name, fn in tables.items():
            if args.only and name != args.only:
                continue
            print(f"# === {name} ===", flush=True)
            t0 = time.time()
            try:
                fn(fast=args.fast, runner=runner)
                print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
            except Exception:
                failed += 1
                print(f"# {name} FAILED:\n{traceback.format_exc()}", file=sys.stderr, flush=True)
    finally:
        runner.close()
    if args.trace_out and runner.tracer.spans:
        from repro.telemetry.export import flame_summary, save_trace
        save_trace(runner.tracer.export(), args.trace_out)
        print(f"# trace: {len(runner.tracer.spans)} spans -> "
              f"{args.trace_out}", flush=True)
        print("\n".join("# " + ln for ln in
                        flame_summary(runner.tracer.spans,
                                      max_depth=4).splitlines()),
              flush=True)
    print(f"# runner stats: {runner.stats.to_dict()}", flush=True)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
