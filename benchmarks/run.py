"""Benchmark harness entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced sweep for CI")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    from benchmarks import (batchsize, fig5_hardware, fig12_breakdown,
                            fig34_compilers, roofline, table1_suite, table45_ci)
    tables = {
        "table1_suite": table1_suite.main,         # Table 1 + coverage (§2.3)
        "fig12_breakdown": fig12_breakdown.main,   # Figs 1-2 + Table 2
        "fig34_compilers": fig34_compilers.main,   # Figs 3-4
        "fig5_hardware": fig5_hardware.main,       # Fig 5 + Table 3
        "table45_ci": table45_ci.main,             # §4.2, Tables 4-5
        "batchsize": batchsize.main,               # §2.2 batch-size search
        "roofline": roofline.main,                 # §Roofline deliverable
    }
    failed = 0
    for name, fn in tables.items():
        if args.only and name != args.only:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            fn(fast=args.fast)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failed += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}", file=sys.stderr, flush=True)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
